"""Sharding rules for every architecture on the production mesh.

Mesh axes: ``(data=16, model=16)`` single pod; ``(pod=2, data=16, model=16)``
multi-pod. Policy (DESIGN.md §6):

- vocab (embedding / lm-head) over ``model``;
- attention heads over ``model`` **iff the head count divides the axis**
  (qwen2-vl's 28 and phi4's 24 heads don't divide 16 — those attention
  weights stay replicated within the model axis; FFN still shards);
- FFN d_ff over ``model`` (column→row parallel pair);
- MoE experts over the flat EP axis (``('data','model')`` when
  E % (data·model) == 0, e.g. deepseek's 256; else ``('model',)``,
  e.g. phi3.5's 16). The ``pod`` axis never joins EP;
- batch over ``(pod, data)``;
- KV caches: batch over ``data``(+``pod``), sequence over ``model``
  (kv-head counts rarely divide 16; a seq-sharded cache turns decode into
  GSPMD flash-decode with partial-softmax all-reduces). ``long_500k``
  (batch 1) shards sequence over ``(data, model)``;
- SSM state: batch over data, heads over model.

Specs are built against ``jax.eval_shape`` of the real initializers, so
every rule is divisibility-checked against actual leaf shapes.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tf
from repro.models.layers import ParallelContext


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def ep_axes_for(cfg, mesh) -> tuple[str, ...] | None:
    """Flat expert-parallel axis for a MoE config on this mesh."""
    if cfg.moe is None:
        return None
    e = cfg.moe.n_experts
    dm = _axis_size(mesh, "data") * _axis_size(mesh, "model")
    if e % dm == 0:
        return ("data", "model")
    if e % _axis_size(mesh, "model") == 0:
        return ("model",)
    return None  # reduced configs fall back to dense dispatch


def _divides(n: int, k: int) -> bool:
    return n > 0 and k > 0 and n % k == 0


def _leaf_spec(path, shape, cfg, mesh, ep) -> P:
    """Rule table keyed on the trailing dict key of the param path."""
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    in_experts = "experts" in names
    m = _axis_size(mesh, "model")
    nd = len(shape)

    def spec(**at):  # build a P with axis names at given (negative) dims
        out = [None] * nd
        for pos, ax in at.items():
            out[int(pos)] = ax
        return P(*out)

    if in_experts:
        # (count, E, d, f) — experts over the flat EP axis.
        if ep is not None and _divides(shape[-3], _ep_size(mesh, ep)):
            return spec(**{"-3": ep})
        return P()
    if name == "embed":
        return spec(**{"0": "model"}) if _divides(shape[0], m) else P()
    if name == "lm_head":
        return spec(**{"1": "model"}) if _divides(shape[1], m) else P()
    if name in ("wq", "wk", "wv"):          # (…, d, H, hd): heads at -2
        return spec(**{"-2": "model"}) if _divides(shape[-2], m) else P()
    if name == "wo":                        # (…, H, hd, d): heads at -3
        return spec(**{"-3": "model"}) if _divides(shape[-3], m) else P()
    if name in ("wq_b", "wk_b", "wv_b"):    # (…, r, H, dh): heads at -2
        return spec(**{"-2": "model"}) if _divides(shape[-2], m) else P()
    if name == "wq_a":                      # (…, d, r)
        return spec(**{"-1": "model"}) if _divides(shape[-1], m) else P()
    if name in ("w_gate", "w_up"):          # (…, d, f): d_ff at -1
        return spec(**{"-1": "model"}) if _divides(shape[-1], m) else P()
    if name == "w_down":                    # (…, f, d): d_ff at -2
        return spec(**{"-2": "model"}) if _divides(shape[-2], m) else P()
    if name == "in_proj":                   # mamba (…, d, zxbcdt)
        return spec(**{"-1": "model"}) if _divides(shape[-1], m) else P()
    if name == "out_proj":                  # mamba (…, d_inner, d)
        return spec(**{"-2": "model"}) if _divides(shape[-2], m) else P()
    if name in ("conv_w", "conv_b"):        # (…, K, cdim) / (…, cdim)
        return spec(**{"-1": "model"}) if _divides(shape[-1], m) else P()
    return P()  # norms, router, biases, frontend_proj, A_log, D, dt_bias


def _ep_size(mesh, ep) -> int:
    n = 1
    for ax in ep:
        n *= _axis_size(mesh, ax)
    return n


def param_specs(cfg, mesh):
    """PartitionSpec pytree matching ``init_params(cfg)``."""
    shapes = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    ep = ep_axes_for(cfg, mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf.shape, cfg, mesh, ep), shapes)


# ---------------------------------------------------------------------------
# Caches and inputs
# ---------------------------------------------------------------------------

def _batch_axes(mesh) -> tuple[str, ...]:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def cache_specs(cfg, mesh, batch: int, cap: int, src_len: int = 0):
    """PartitionSpec pytree matching ``init_cache``."""
    dp = _batch_axes(mesh)
    nb = 1
    for ax in dp:
        nb *= _axis_size(mesh, ax)
    m = _axis_size(mesh, "model")

    batch_ax = dp if _divides(batch, nb) else (
        ("data",) if _divides(batch, _axis_size(mesh, "data")) else None)
    if batch_ax is None and batch == 1:
        seq_ax: object = ("data", "model")   # long_500k: seq over both
    else:
        seq_ax = "model"

    def leaf(path, leaf_shape):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        shape = leaf_shape.shape
        if name == "len":
            return P()
        if name in ("k", "v", "xk", "xv", "ckv", "k_rope"):
            # (count, B, S, …): batch at 1, seq at 2.
            out = [None] * len(shape)
            if batch_ax is not None:
                out[1] = batch_ax
            seq = shape[2]
            n_seq = m if seq_ax == "model" else nb * m
            if _divides(seq, n_seq):
                out[2] = seq_ax
            return P(*out)
        if name == "conv":                    # (count, B, K-1, cdim)
            out = [None] * len(shape)
            if batch_ax is not None:
                out[1] = batch_ax
            if _divides(shape[-1], m):
                out[-1] = "model"
            return P(*out)
        if name == "state":                   # (count, B, H, hd, N)
            out = [None] * len(shape)
            if batch_ax is not None:
                out[1] = batch_ax
            if _divides(shape[2], m):
                out[2] = "model"
            return P(*out)
        return P()

    shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, batch, cap, src_len=src_len))
    return jax.tree_util.tree_map_with_path(leaf, shapes)


def input_sharding(cfg, mesh, batch: int):
    """Spec for token / frame / embed inputs: batch over (pod, data)."""
    dp = _batch_axes(mesh)
    nb = 1
    for ax in dp:
        nb *= _axis_size(mesh, ax)
    if _divides(batch, nb):
        return P(dp)
    if _divides(batch, _axis_size(mesh, "data")):
        return P(("data",))
    return P()


def make_pc(cfg, mesh, moe_impl: str = "ep", aurora_rounds=None,
            flash_block: int = 1024) -> ParallelContext:
    """ParallelContext for this (config, mesh)."""
    dp = _batch_axes(mesh)
    ep = ep_axes_for(cfg, mesh)
    token_axes = tuple(mesh.axis_names)      # pod stays out of ep collectives
    impl = moe_impl if (cfg.moe is not None and ep is not None) else "dense"
    return ParallelContext(
        mesh=mesh, data_axes=dp, model_axis="model", ep_axes=ep,
        token_axes=token_axes, aurora_rounds=aurora_rounds, moe_impl=impl,
        flash_block=flash_block)
