"""Partition rules: ModelConfig × mesh → PartitionSpecs."""

from .rules import (cache_specs, ep_axes_for, input_sharding, make_pc,
                    param_specs)

__all__ = ["cache_specs", "ep_axes_for", "input_sharding", "make_pc",
           "param_specs"]
