"""Mixture-of-Experts layer: router, capacity dispatch, expert FFN, combine.

Four dispatch implementations share the same routing/capacity semantics:

- ``dense``  — local gather/scatter (reference; smoke tests, single device).
- ``kernel`` — sort-based ragged dispatch feeding the fused Pallas grouped
               FFN (``repro.kernels.moe_gmm``): tokens are argsorted by
               expert id, per-expert group offsets come from
               ``searchsorted``, and capacity is enforced by rank within the
               group — no (T·k, E) one-hot, no cumsum over experts. The
               serving engines' decode hot path (``kernels=True``).
- ``ep``     — expert-parallel ``shard_map`` with a monolithic
               ``lax.all_to_all`` (the production baseline the paper starts
               from; see ``repro.distributed.alltoall``).
- ``aurora`` — expert-parallel ``shard_map`` where the all-to-all is replaced
               by the paper's contention-free schedule: a static sequence of
               ``lax.ppermute`` permutation rounds (Thm 4.2 / BvN), computed
               host-side by ``repro.core.schedule`` from historical traffic.

Routing follows the assigned architectures: softmax top-k (phi3.5-moe) and
DeepSeek-V3 sigmoid scoring with normalized top-k gates, an optional shared
expert, and leading dense layers. The Switch-style load-balance auxiliary loss
is returned for training.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.errors import FaultError
from .layers import (KernelConfig, NO_PARALLEL, ParallelContext, ffn_apply,
                     init_ffn)


# ---------------------------------------------------------------------------
# Expert replication (hot-expert copies; placement-only)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicationSpec:
    """Physical layout of replicated experts.

    ``counts[e]`` copies of logical expert e sit contiguously in the widened
    physical expert array (physical slots ``base[e] .. base[e]+counts[e]-1``
    all hold byte-identical weights). Routing stays in the LOGICAL frame —
    the router keeps E columns and capacity/keep/drop decisions are computed
    exactly as without replication — then each kept (token, expert, rank)
    lands on replica ``rank % counts[e]`` at bucket position
    ``rank // counts[e]`` (the deterministic shard-of-token rule). Replicas
    are pure copies, so the routed function is provably unchanged: the same
    tokens reach the same weights with the same gates; only WHERE they are
    computed moves. Hashable (tuple field), so it can ride on the frozen
    ``ParallelContext`` as a jit-static.
    """

    counts: tuple[int, ...]

    def __post_init__(self):
        if not self.counts or any(int(c) < 1 for c in self.counts):
            raise ValueError(f"replica counts must be >= 1, "
                             f"got {self.counts}")

    @property
    def n_logical(self) -> int:
        return len(self.counts)

    @property
    def n_phys(self) -> int:
        return sum(self.counts)

    @property
    def base(self) -> tuple[int, ...]:
        """First physical slot of each logical expert."""
        out, acc = [], 0
        for c in self.counts:
            out.append(acc)
            acc += c
        return tuple(out)

    @property
    def phys_to_logical(self) -> tuple[int, ...]:
        return tuple(e for e, c in enumerate(self.counts) for _ in range(c))

    @property
    def is_identity(self) -> bool:
        return all(c == 1 for c in self.counts)

    @classmethod
    def from_counts(cls, counts) -> "ReplicationSpec | None":
        """None for the identity layout (no replication)."""
        spec = cls(counts=tuple(int(c) for c in counts))
        return None if spec.is_identity else spec


def _is_experts_leaf(path) -> bool:
    names = [p.key for p in path if hasattr(p, "key")]
    return "experts" in names


def replicate_moe_params(params, spec: ReplicationSpec, axis: int = 1):
    """Widen every MoE layer's expert leaves to ``spec.n_phys`` physical
    experts (replicas are gathered copies). Full-model stacked-segment
    leaves are (layer_count, E, ...), so the expert axis defaults to 1 —
    the same leaf addressing as ``serving.colocated.apply_pairing``; pass
    ``axis=0`` for a standalone ``init_moe`` layer dict. Router leaves are
    untouched: routing stays logical."""
    gather = jnp.asarray(spec.phys_to_logical)

    def widen(path, leaf):
        if _is_experts_leaf(path):
            return jnp.take(leaf, gather, axis=axis)
        return leaf
    return jax.tree_util.tree_map_with_path(widen, params)


def dereplicate_moe_params(params, spec: ReplicationSpec, axis: int = 1):
    """Exact inverse of ``replicate_moe_params``: keep each logical expert's
    home copy (replicas are byte-identical, so this loses nothing)."""
    gather = jnp.asarray(spec.base)

    def narrow(path, leaf):
        if _is_experts_leaf(path):
            return jnp.take(leaf, gather, axis=axis)
        return leaf
    return jax.tree_util.tree_map_with_path(narrow, params)


def replica_arrays(spec: ReplicationSpec):
    """(base (E,), counts (E,)) as int32 device arrays for dispatch remaps."""
    return (jnp.asarray(spec.base, jnp.int32),
            jnp.asarray(spec.counts, jnp.int32))


def shrink_replication(spec: ReplicationSpec | None,
                       drop_phys) -> "ReplicationSpec | None":
    """Failover shrink: the physical slots in ``drop_phys`` are gone (their
    device died or their weights are corrupt); return the layout with those
    copies removed. Lossless as long as every logical expert keeps at least
    one copy — replicas are byte-identical — otherwise ``FaultError``: the
    last copy of an expert's weights cannot be shrunk away. Returns None
    when the survivor layout is the identity (no replication left)."""
    if spec is None:
        raise FaultError(
            f"cannot drop physical expert slots {sorted(set(drop_phys))}: "
            "no replication is active, every slot is a last copy")
    drop = {int(p) for p in drop_phys}
    for p in drop:
        if not 0 <= p < spec.n_phys:
            raise FaultError(f"physical slot {p} out of "
                             f"range({spec.n_phys})")
    p2l = spec.phys_to_logical
    counts = list(spec.counts)
    for p in drop:
        counts[p2l[p]] -= 1
    for e, c in enumerate(counts):
        if c < 1:
            raise FaultError(
                f"expert {e} would lose its last copy (dropping "
                f"{sorted(drop)} from counts {spec.counts}) — failover "
                "is only lossless while one replica survives")
    return ReplicationSpec.from_counts(counts)


def repair_moe_params(params, spec: ReplicationSpec | None, bad_phys,
                      axis: int = 1):
    """Overwrite corrupt physical expert slots from a healthy replica.

    ``bad_phys`` lists physical slots whose weights are unusable (NaN
    injection, bit flips). Each is re-cloned from another copy of the same
    LOGICAL expert — byte-identical by the replication invariant, so the
    routed function is exactly restored. ``FaultError`` when some logical
    expert has no healthy copy left (including the unreplicated case,
    where every logical expert has exactly one slot)."""
    bad = {int(p) for p in bad_phys}
    n_phys = spec.n_phys if spec is not None else None
    if n_phys is None:
        if bad:
            raise FaultError(
                f"cannot repair physical slots {sorted(bad)}: no "
                "replication is active, there is no healthy copy to clone")
        return params
    for p in bad:
        if not 0 <= p < n_phys:
            raise FaultError(f"physical slot {p} out of range({n_phys})")
    base, counts = spec.base, spec.counts
    src = list(range(n_phys))
    for p in bad:
        e = spec.phys_to_logical[p]
        healthy = [q for q in range(base[e], base[e] + counts[e])
                   if q not in bad]
        if not healthy:
            raise FaultError(
                f"expert {e} has no healthy copy left among physical slots "
                f"{list(range(base[e], base[e] + counts[e]))}")
        src[p] = healthy[0]
    gather = jnp.asarray(src)

    def heal(path, leaf):
        if _is_experts_leaf(path):
            return jnp.take(leaf, gather, axis=axis)
        return leaf
    return jax.tree_util.tree_map_with_path(heal, params)


def init_moe(key, d_model: int, moe, dtype) -> dict:
    """Parameters of one MoE layer (router + stacked experts + shared)."""
    k_r, k_e, k_s = jax.random.split(key, 3)
    ek = jax.random.split(k_e, moe.n_experts)
    experts = jax.vmap(lambda k: init_ffn(k, d_model, moe.d_ff, dtype))(ek)
    p = {
        "router": jax.random.normal(k_r, (d_model, moe.n_experts),
                                    jnp.float32) * d_model ** -0.5,
        "experts": experts,  # each leaf: (E, ...)
    }
    if moe.n_shared_experts:
        p["shared"] = init_ffn(k_s, d_model,
                               moe.shared_d_ff or moe.d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def route(router_w, x, moe):
    """Token→expert assignment.

    x: (T, d). Returns (gates (T,k), idx (T,k) int32, aux_loss scalar).
    """
    logits = (x.astype(jnp.float32) @ router_w)          # (T, E)
    if moe.router == "sigmoid":                          # DeepSeek-V3 style
        scores = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(scores, moe.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
        probs = jax.nn.softmax(logits, axis=-1)          # aux loss statistics
    else:                                                # softmax top-k
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, moe.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    e = moe.n_experts
    f = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(f * p_mean)
    return gates.astype(x.dtype), idx.astype(jnp.int32), aux


def capacity(n_tokens: int, top_k: int, n_experts: int, cf: float,
             multiple: int = 8) -> int:
    """Static per-expert capacity for a token group of ``n_tokens``.

    Clamped above by ``n_tokens``: top-k experts are distinct per token, so
    one source group can never send more than ``n_tokens`` rows to a single
    expert. At decode (1–2 tokens per device) this shrinks the all-to-all
    buffers 4–8× versus the lane-aligned minimum AND makes dispatch
    drop-free (§Perf iteration 4).
    """
    c = int(n_tokens * top_k * cf / n_experts) + 1
    c = max(multiple, -(-c // multiple) * multiple)
    return min(c, max(n_tokens, 1))


def dispatch_indices(idx, n_experts: int, cap: int):
    """Assignment → capacity-bucket coordinates (one-hot reference).

    idx: (T, k). Returns (slot (T,k) int32 position inside the expert bucket,
    keep (T,k) bool — False means the token overflowed and is dropped).
    Position assignment is token-order per expert (GShard semantics).
    """
    t, k = idx.shape
    flat = idx.reshape(-1)                               # (T*k,) token-major
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot            # slots before me
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < cap
    return slot.reshape(t, k).astype(jnp.int32), keep.reshape(t, k)


def sort_dispatch(idx, n_experts: int, cap: int):
    """Sort-based ragged dispatch — ``dispatch_indices`` without the
    O(T·k·E) one-hot + cumsum.

    Tokens are argsorted by expert id (stable sort: ties break in token
    order, exactly GShard's position assignment), per-expert group offsets
    come from a ``searchsorted`` over the sorted ids, and a token's bucket
    slot is its rank within its group (sorted position minus group offset).

    idx: (T, k) routed expert ids. Returns
      order (T*k,) int32 — flat assignment ids in expert-sorted order
      sizes (E,)   int32 — routed rows per expert (capacity drops included:
                           this is OFFERED traffic, free routing counts)
      slot  (T, k) int32 — rank within the expert group (== the one-hot
                           path's bucket position, bit for bit)
      keep  (T, k) bool  — rank < cap (False = overflowed, dropped)
    """
    t, k = idx.shape
    flat = idx.reshape(-1)                               # (T*k,) token-major
    order = jnp.argsort(flat, stable=True).astype(jnp.int32)
    sorted_e = flat[order]
    offsets = jnp.searchsorted(
        sorted_e, jnp.arange(n_experts, dtype=sorted_e.dtype),
        side="left").astype(jnp.int32)                   # (E,) group starts
    sizes = jnp.diff(offsets, append=jnp.int32(t * k))   # segment sizes
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - offsets[sorted_e]
    slot = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = slot < cap
    return order, sizes, slot.reshape(t, k), keep.reshape(t, k)


def routed_counts(idx, n_experts: int):
    """(T, k) routed expert ids → (T, E) float32 per-token choice histogram.

    Capacity drops included — this measures OFFERED dispatch traffic, the
    quantity the deployment planner consumes. One scatter-add (no (T·k, E)
    one-hot); shared by the dense and kernel dispatch paths.
    """
    t, k = idx.shape
    rows = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    return jnp.zeros((t, n_experts), jnp.float32).at[
        rows, idx.reshape(-1)].add(1.0)


def _experts_ffn(experts, xb, act: str):
    """Apply expert e's FFN to its capacity bucket. xb: (E, C, d)."""
    return jax.vmap(lambda p, x: ffn_apply(p, x, act))(experts, xb)


# ---------------------------------------------------------------------------
# Dense (reference) dispatch — single device / smoke tests
# ---------------------------------------------------------------------------

def moe_apply_dense(p, x, moe, act: str,
                    pc: ParallelContext = NO_PARALLEL,
                    return_counts: bool = False):
    """Reference MoE layer. x: (..., d) → (y, aux).

    ``return_counts=True`` appends a (..., E) float32 per-token histogram of
    routed expert choices (capacity drops included — it measures OFFERED
    dispatch traffic, the quantity the deployment planner consumes)."""
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)                                # (T, d)
    t = xt.shape[0]
    gates, idx, aux = route(p["router"], xt, moe)
    cap = capacity(t, moe.top_k, moe.n_experts, moe.capacity_factor)
    slot, keep = dispatch_indices(idx, moe.n_experts, cap)

    # Scatter tokens into (E, C, d) buckets. Under replication the routing
    # above ran in the LOGICAL frame (same capacity, same drops); only the
    # bucket coordinates move: rank r of expert e lands on replica r % r_e
    # at position r // r_e (collision-free, never adds drops).
    tok_ids = jnp.broadcast_to(jnp.arange(t)[:, None], idx.shape)
    e_f, s_f, t_f = idx.reshape(-1), slot.reshape(-1), tok_ids.reshape(-1)
    spec = pc.moe_replication
    if spec is not None:
        base, reps = replica_arrays(spec)
        r_f = reps[e_f]
        e_f = base[e_f] + s_f % r_f
        s_f = s_f // r_f
        n_phys = spec.n_phys
    else:
        n_phys = moe.n_experts
    buf = jnp.zeros((n_phys, cap, d), xt.dtype)
    safe_s = jnp.where(keep.reshape(-1), s_f, cap - 1)
    contrib = jnp.where(keep.reshape(-1)[:, None], xt[t_f], 0.0)
    buf = buf.at[e_f, safe_s].add(contrib)  # each kept slot hit exactly once

    out_buf = _experts_ffn(p["experts"], buf, act)       # (E', C, d)

    # Gather back and combine with gates.
    picked = out_buf[e_f, safe_s]                        # (T*k, d)
    picked = jnp.where(keep.reshape(-1)[:, None], picked, 0.0)
    y = jnp.zeros_like(xt).at[t_f].add(
        picked * gates.reshape(-1)[:, None])
    if "shared" in p:
        y = y + ffn_apply(p["shared"], xt, act, pc)
    if return_counts:
        counts = routed_counts(idx, moe.n_experts)               # (T, E)
        return (y.reshape(shape), aux,
                counts.reshape(shape[:-1] + (moe.n_experts,)))
    return y.reshape(shape), aux


# ---------------------------------------------------------------------------
# Kernel dispatch — sort-based ragged layout feeding the Pallas grouped FFN
# ---------------------------------------------------------------------------

def moe_apply_kernel(p, x, moe, act: str,
                     pc: ParallelContext = NO_PARALLEL,
                     return_counts: bool = False):
    """Kernelized MoE layer: same routing/capacity semantics as the dense
    reference, different machinery. x: (..., d) → (y, aux[, counts]).

    Dispatch is the sort-based ragged layout (``sort_dispatch``); compute is
    one of three statically-chosen backends:

    - Pallas ``moe_gmm`` with ``group_sizes`` (TPU, or interpret mode for
      validation): capacity buckets scattered through ONE gather, empty
      expert blocks skipped in-kernel.
    - compact pure-jnp (CPU decode shapes, where 2·T·k <= E·C): the FFN runs
      over exactly the T·k routed rows with per-row gathered expert weights
      — no (E, C, d) buffer exists at all, so none of the garbage-row
      compute the dense path pays at decode.
    - bucketed pure-jnp (CPU prefill shapes): the same zero-padded buckets
      as the kernel, through ``ref.moe_ffn_ref(group_sizes=...)``.

    All three drop the same tokens and combine with the same gates, so
    logits match the dense path to float tolerance.
    """
    from repro.kernels import ops as kops
    from repro.kernels.moe_gmm import align_capacity

    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)                                # (T, d)
    t = xt.shape[0]
    k, e = moe.top_k, moe.n_experts
    gates, idx, aux = route(p["router"], xt, moe)
    cap = capacity(t, k, e, moe.capacity_factor)
    kc = pc.kernels or KernelConfig()

    order, sizes, slot, keep = sort_dispatch(idx, e, cap)
    keep_f = keep.reshape(-1)
    e_f = idx.reshape(-1)
    t_f = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    experts = p["experts"]

    # Replication: routing/capacity ran in the LOGICAL frame above; remap
    # each kept rank to (replica r % r_e, position r // r_e). ``home`` keeps
    # the compact path exact — every replica is a byte-copy of its home.
    spec = pc.moe_replication
    if spec is not None:
        base, reps = replica_arrays(spec)
        s_f = slot.reshape(-1)
        pe_f = base[e_f] + s_f % reps[e_f]               # physical expert
        ps_f = s_f // reps[e_f]                          # physical position
        home_f = base[e_f]
        n_phys = spec.n_phys
    else:
        pe_f, ps_f, home_f = e_f, slot.reshape(-1), e_f
        n_phys = e

    compact = not kops.use_pallas(kc.interpret) and 2 * t * k <= e * cap
    if compact:
        # Decode-sized: gather each routed row's expert weights and run a
        # batched matvec over the compact (T·k, d) layout.
        xg = xt[t_f]                                     # (T*k, d)
        hg = jnp.einsum("rd,rdf->rf", xg, experts["w_gate"][home_f],
                        preferred_element_type=jnp.float32)
        hu = jnp.einsum("rd,rdf->rf", xg, experts["w_up"][home_f],
                        preferred_element_type=jnp.float32)
        act_fn = jax.nn.gelu if act == "geglu" else jax.nn.silu
        h = (act_fn(hg) * hu).astype(xt.dtype)
        picked = jnp.einsum("rf,rfd->rd", h, experts["w_down"][home_f],
                            preferred_element_type=jnp.float32
                            ).astype(xt.dtype)           # (T*k, d)
    else:
        # Bucketed: pad capacity so the kernel grid tiles it, scatter the
        # SORTED tokens with one index build (dropped ranks scatter out of
        # range and vanish), leave unfilled rows pointing at a zero pad row.
        cap_pad = align_capacity(cap, kc.block_c)
        pe_sorted = pe_f[order]
        pr_sorted = ps_f[order]
        keep_sorted = keep_f[order]
        dest = jnp.where(keep_sorted,
                         pe_sorted * cap_pad + pr_sorted, n_phys * cap_pad)
        src = jnp.full((n_phys * cap_pad,), t, jnp.int32).at[dest].set(
            order // k, mode="drop")
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        buf = x_pad[src].reshape(n_phys, cap_pad, d)
        group_sizes = jnp.minimum(sizes, cap)            # logical frame
        if spec is not None:
            # Physical group g (replica j of expert e, r_e copies) holds the
            # ranks ≡ j (mod r_e) below the logical group size: ceil((g-j)/r).
            p2l = jnp.asarray(spec.phys_to_logical, jnp.int32)
            j = jnp.arange(n_phys, dtype=jnp.int32) - base[p2l]
            r_p = reps[p2l]
            group_sizes = jnp.maximum(
                0, (group_sizes[p2l] - j + r_p - 1) // r_p)
        out_buf = kops.moe_ffn(
            buf, experts["w_gate"], experts["w_up"], experts["w_down"],
            act=act, interpret=kc.interpret,
            group_sizes=group_sizes,
            block_c=kc.block_c, block_f=kc.block_f)
        flat_out = out_buf.reshape(n_phys * cap_pad, d)
        safe = jnp.where(keep_f, pe_f * cap_pad + ps_f, 0)
        picked = flat_out[safe]                          # (T*k, d)

    picked = jnp.where(keep_f[:, None], picked, 0.0)
    y = jnp.zeros_like(xt).at[t_f].add(
        picked * gates.reshape(-1)[:, None])
    if "shared" in p:
        y = y + ffn_apply(p["shared"], xt, act, pc)
    if return_counts:
        counts = routed_counts(idx, moe.n_experts)       # (T, E)
        return (y.reshape(shape), aux,
                counts.reshape(shape[:-1] + (moe.n_experts,)))
    return y.reshape(shape), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map): all_to_all baseline / Aurora rounds
# ---------------------------------------------------------------------------

def moe_apply_ep(p, x, moe, act: str, pc: ParallelContext,
                 return_counts: bool = False):
    """Expert-parallel MoE layer over ``pc.ep_axes``.

    Tokens must arrive sharded so that every EP device holds a token slice
    (the transformer stack constrains x to P(data, model) before calling).
    Expert weights are sharded over the flat EP axis (experts_per_device =
    E / ep_size ≥ 1). Dispatch/return all-to-alls run inside ``shard_map``;
    ``pc.aurora_rounds`` switches the collective to the scheduled ppermute
    rounds, and ``pc.ep_overlap`` pipelines expert FFN chunks with in-flight
    rounds (``repro.distributed.overlap``).

    ``return_counts=True`` appends the same (..., E) routed-choice histogram
    the local paths emit: routing happens inside the collective, so the
    per-device count slices are scattered into the global token range and
    ``psum``-replicated in-collective (``alltoall._replicated_counts``) —
    live traffic monitoring works distributed.
    """
    from repro.distributed.alltoall import ep_dispatch_combine

    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    out = ep_dispatch_combine(
        xt, p["router"], p["experts"], moe, act, pc,
        return_counts=return_counts)
    if return_counts:
        y, aux, counts = out
    else:
        y, aux = out
    if "shared" in p:
        y = y + ffn_apply(p["shared"], xt, act, pc)
    if return_counts:
        return (y.reshape(shape), aux,
                counts.reshape(shape[:-1] + (moe.n_experts,)))
    return y.reshape(shape), aux


def moe_apply(p, x, moe, act: str, pc: ParallelContext = NO_PARALLEL,
              return_counts: bool = False):
    if pc.moe_impl in ("ep", "aurora") and pc.ep_axes:
        return moe_apply_ep(p, x, moe, act, pc, return_counts=return_counts)
    if pc.moe_impl == "kernel":
        return moe_apply_kernel(p, x, moe, act, pc,
                                return_counts=return_counts)
    return moe_apply_dense(p, x, moe, act, pc, return_counts=return_counts)
