"""Mixture-of-Experts layer: router, capacity dispatch, expert FFN, combine.

Three dispatch implementations share the same routing/capacity semantics:

- ``dense``  — local gather/scatter (reference; smoke tests, single device).
- ``ep``     — expert-parallel ``shard_map`` with a monolithic
               ``lax.all_to_all`` (the production baseline the paper starts
               from; see ``repro.distributed.alltoall``).
- ``aurora`` — expert-parallel ``shard_map`` where the all-to-all is replaced
               by the paper's contention-free schedule: a static sequence of
               ``lax.ppermute`` permutation rounds (Thm 4.2 / BvN), computed
               host-side by ``repro.core.schedule`` from historical traffic.

Routing follows the assigned architectures: softmax top-k (phi3.5-moe) and
DeepSeek-V3 sigmoid scoring with normalized top-k gates, an optional shared
expert, and leading dense layers. The Switch-style load-balance auxiliary loss
is returned for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import NO_PARALLEL, ParallelContext, ffn_apply, init_ffn


def init_moe(key, d_model: int, moe, dtype) -> dict:
    """Parameters of one MoE layer (router + stacked experts + shared)."""
    k_r, k_e, k_s = jax.random.split(key, 3)
    ek = jax.random.split(k_e, moe.n_experts)
    experts = jax.vmap(lambda k: init_ffn(k, d_model, moe.d_ff, dtype))(ek)
    p = {
        "router": jax.random.normal(k_r, (d_model, moe.n_experts),
                                    jnp.float32) * d_model ** -0.5,
        "experts": experts,  # each leaf: (E, ...)
    }
    if moe.n_shared_experts:
        p["shared"] = init_ffn(k_s, d_model,
                               moe.shared_d_ff or moe.d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def route(router_w, x, moe):
    """Token→expert assignment.

    x: (T, d). Returns (gates (T,k), idx (T,k) int32, aux_loss scalar).
    """
    logits = (x.astype(jnp.float32) @ router_w)          # (T, E)
    if moe.router == "sigmoid":                          # DeepSeek-V3 style
        scores = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(scores, moe.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
        probs = jax.nn.softmax(logits, axis=-1)          # aux loss statistics
    else:                                                # softmax top-k
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, moe.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    e = moe.n_experts
    f = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(f * p_mean)
    return gates.astype(x.dtype), idx.astype(jnp.int32), aux


def capacity(n_tokens: int, top_k: int, n_experts: int, cf: float,
             multiple: int = 8) -> int:
    """Static per-expert capacity for a token group of ``n_tokens``.

    Clamped above by ``n_tokens``: top-k experts are distinct per token, so
    one source group can never send more than ``n_tokens`` rows to a single
    expert. At decode (1–2 tokens per device) this shrinks the all-to-all
    buffers 4–8× versus the lane-aligned minimum AND makes dispatch
    drop-free (§Perf iteration 4).
    """
    c = int(n_tokens * top_k * cf / n_experts) + 1
    c = max(multiple, -(-c // multiple) * multiple)
    return min(c, max(n_tokens, 1))


def dispatch_indices(idx, n_experts: int, cap: int):
    """Assignment → capacity-bucket coordinates.

    idx: (T, k). Returns (slot (T,k) int32 position inside the expert bucket,
    keep (T,k) bool — False means the token overflowed and is dropped).
    Position assignment is token-order per expert (GShard semantics).
    """
    t, k = idx.shape
    flat = idx.reshape(-1)                               # (T*k,) token-major
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot            # slots before me
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < cap
    return slot.reshape(t, k).astype(jnp.int32), keep.reshape(t, k)


def _experts_ffn(experts, xb, act: str):
    """Apply expert e's FFN to its capacity bucket. xb: (E, C, d)."""
    return jax.vmap(lambda p, x: ffn_apply(p, x, act))(experts, xb)


# ---------------------------------------------------------------------------
# Dense (reference) dispatch — single device / smoke tests
# ---------------------------------------------------------------------------

def moe_apply_dense(p, x, moe, act: str,
                    pc: ParallelContext = NO_PARALLEL,
                    return_counts: bool = False):
    """Reference MoE layer. x: (..., d) → (y, aux).

    ``return_counts=True`` appends a (..., E) float32 per-token histogram of
    routed expert choices (capacity drops included — it measures OFFERED
    dispatch traffic, the quantity the deployment planner consumes)."""
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)                                # (T, d)
    t = xt.shape[0]
    gates, idx, aux = route(p["router"], xt, moe)
    cap = capacity(t, moe.top_k, moe.n_experts, moe.capacity_factor)
    slot, keep = dispatch_indices(idx, moe.n_experts, cap)

    # Scatter tokens into (E, C, d) buckets.
    buf = jnp.zeros((moe.n_experts, cap, d), xt.dtype)
    tok_ids = jnp.broadcast_to(jnp.arange(t)[:, None], idx.shape)
    e_f, s_f, t_f = idx.reshape(-1), slot.reshape(-1), tok_ids.reshape(-1)
    safe_s = jnp.where(keep.reshape(-1), s_f, cap - 1)
    contrib = jnp.where(keep.reshape(-1)[:, None], xt[t_f], 0.0)
    buf = buf.at[e_f, safe_s].add(contrib)  # each kept slot hit exactly once

    out_buf = _experts_ffn(p["experts"], buf, act)       # (E, C, d)

    # Gather back and combine with gates.
    picked = out_buf[e_f, safe_s]                        # (T*k, d)
    picked = jnp.where(keep.reshape(-1)[:, None], picked, 0.0)
    y = jnp.zeros_like(xt).at[t_f].add(
        picked * gates.reshape(-1)[:, None])
    if "shared" in p:
        y = y + ffn_apply(p["shared"], xt, act, pc)
    if return_counts:
        counts = jax.nn.one_hot(idx, moe.n_experts,
                                dtype=jnp.float32).sum(axis=1)   # (T, E)
        return (y.reshape(shape), aux,
                counts.reshape(shape[:-1] + (moe.n_experts,)))
    return y.reshape(shape), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map): all_to_all baseline / Aurora rounds
# ---------------------------------------------------------------------------

def moe_apply_ep(p, x, moe, act: str, pc: ParallelContext):
    """Expert-parallel MoE layer over ``pc.ep_axes``.

    Tokens must arrive sharded so that every EP device holds a token slice
    (the transformer stack constrains x to P(data, model) before calling).
    Expert weights are sharded over the flat EP axis (experts_per_device =
    E / ep_size ≥ 1). Dispatch/return all-to-alls run inside ``shard_map``;
    ``pc.aurora_rounds`` switches the collective to the scheduled ppermute
    rounds.
    """
    from repro.distributed.alltoall import ep_dispatch_combine

    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    y, aux = ep_dispatch_combine(
        xt, p["router"], p["experts"], moe, act, pc)
    if "shared" in p:
        y = y + ffn_apply(p["shared"], xt, act, pc)
    return y.reshape(shape), aux


def moe_apply(p, x, moe, act: str, pc: ParallelContext = NO_PARALLEL,
              return_counts: bool = False):
    if pc.moe_impl in ("ep", "aurora") and pc.ep_axes:
        if return_counts:
            raise NotImplementedError(
                "routing-count collection requires the dense dispatch path "
                "(the serving monitor runs single-host)")
        return moe_apply_ep(p, x, moe, act, pc)
    return moe_apply_dense(p, x, moe, act, pc, return_counts=return_counts)
