"""Shared neural building blocks (pure-function JAX, no framework deps).

Everything here is dtype- and sharding-polymorphic: params are plain nested
dicts of ``jnp.ndarray``; an optional ``ParallelContext`` adds
``with_sharding_constraint`` hints (no-ops on a single device).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Pallas kernel-path settings for the serving hot path.

    Attaching one to ``ParallelContext.kernels`` (see ``Model.with_kernels``)
    routes decode-step attention through ``kernels.ops.decode_attn_auto`` and
    — together with ``moe_impl="kernel"`` — MoE dispatch through the
    sort-based ragged path feeding ``kernels.moe_gmm``.

    ``interpret``: None = auto (compiled Pallas on TPU, pure-jnp reference on
    CPU); True forces Pallas interpret mode (correctness validation on CPU).
    """

    interpret: bool | None = None
    block_c: int = 128    # moe_gmm capacity-row block
    block_f: int = 128    # moe_gmm d_ff block (reduction axis)
    block_s: int = 512    # decode_attn KV-sequence block


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """How the model is laid out on a mesh.

    ``data_axes``: mesh axes the batch is sharded over (e.g. ("pod","data")).
    ``model_axis``: mesh axis for tensor parallelism (heads / d_ff / vocab).
    ``ep_axes``: mesh axes forming the flat expert-parallel axis for MoE
    dispatch (None → dense reference dispatch).
    ``seq_axis``: axis to shard long KV caches' sequence dim over (used when
    batch is too small to shard, e.g. long_500k).
    """

    mesh: Any = None
    data_axes: tuple[str, ...] = ()
    model_axis: str | None = None
    ep_axes: tuple[str, ...] | None = None   # collective axes for MoE a2a
    token_axes: tuple[str, ...] = ()         # all axes the flat token dim
    #                                          shards over (pod stays outside
    #                                          the EP collective: no all-to-all
    #                                          ever crosses the DCN boundary)
    seq_axis: str | None = None
    aurora_rounds: tuple[tuple[int, ...], ...] | None = None  # ppermute schedule
    ep_overlap: bool = False  # round-pipelined dispatch: expert FFN chunks
    #                           overlap in-flight ppermute rounds
    #                           (repro.distributed.overlap)
    moe_impl: str = "dense"  # dense | ep | aurora | kernel
    kernels: KernelConfig | None = None      # non-None → kernelized hot path
    moe_replication: Any = None  # moe.ReplicationSpec | None: hot-expert
    #                              replicas (params widened to sum(counts)
    #                              physical experts; routing stays logical)
    flash_block: int = 1024
    unroll_segments: bool = False  # Python-loop layer blocks instead of
    #                                lax.scan (cost-calibration lowerings:
    #                                XLA counts a while body ONCE regardless
    #                                of trip count)

    def shard(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))


NO_PARALLEL = ParallelContext()


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(w, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); pos: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
                sections: tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. pos3: (3, ..., S) temporal/height/width ids.

    The head_dim/2 frequency slots are split into three sections, each
    rotated by its own position stream (all three equal for pure text).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    # Build per-slot position by section.
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                 # (D/2,) in {0,1,2}
    pos_sel = jnp.moveaxis(pos3, 0, -1)                # (..., S, 3)
    pos_per_slot = jnp.take(pos_sel, sec, axis=-1)     # (..., S, D/2)
    angles = pos_per_slot.astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def plain_attention(q, k, v, mask) -> jnp.ndarray:
    """GQA attention without repeating KV.

    q: (B,Sq,Hkv,G,D); k,v: (B,Sk,Hkv,D); mask: (1|B,1,Sq,Sk) bool or None.
    Keeping the kv-head/group split as separate einsum dims (instead of
    broadcast+reshape repeat_kv) avoids 4× KV temporaries AND a GSPMD
    "involuntary full rematerialization" of seq-sharded caches at decode
    (§Perf iteration 6).
    """
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, :, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def flash_attention(q, k, v, mask_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray] | None,
                    block_k: int = 1024) -> jnp.ndarray:
    """Memory-bounded GQA attention: scan over KV blocks, online softmax.

    q: (B,Sq,Hkv,G,D); k,v: (B,Sk,Hkv,D). Never materializes the (Sq, Sk)
    score matrix — peak temporary is (B, Hkv, G, Sq, block_k).
    ``mask_fn(q_pos, k_pos) -> bool`` builds the mask for one block
    (causal / sliding window / cache-length).
    """
    b, sq, hkv, g, d = q.shape
    sk = k.shape[1]
    nb = -(-sk // block_k)
    pad = nb * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block_k, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_k, hkv, d).transpose(1, 0, 2, 3, 4)
    scale = d ** -0.5
    q_pos = jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        blk_idx, kblk, vblk = inp
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        valid = k_pos < sk
        if mask_fn is not None:
            valid = valid[None, :] & mask_fn(q_pos[:, None], k_pos[None, :])
        else:
            valid = jnp.broadcast_to(valid[None, :], (sq, block_k))
        s = jnp.where(valid[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,Hkv,G,Sq,D)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def attention_core(q, k, v, *, causal_offset: jnp.ndarray | int | None,
                   window: int | None, valid_len: jnp.ndarray | None,
                   flash_block: int = 1024) -> jnp.ndarray:
    """Dispatch between plain and flash attention (GQA-native, no repeat).

    q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D) with H = Hkv·G.
    ``causal_offset``: query i may attend key j iff j <= i + offset
    (offset = Sk - Sq for self-attention with a prefix cache; None = no
    causal mask, e.g. encoder self-attention / cross-attention).
    ``window``: additionally require j > i + offset - window.
    ``valid_len``: keys >= valid_len are masked (cache fill level). Both
    ``causal_offset`` and ``valid_len`` may be scalars (one value for the
    whole batch) or (B,) vectors (per-slot values — continuous batching /
    batched chunked continuation).
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]

    def mask_fn(qi, kj):
        m = jnp.ones(jnp.broadcast_shapes(qi.shape, kj.shape), bool)
        if causal_offset is not None:
            m &= kj <= qi + causal_offset
            if window is not None:
                m &= kj > qi + causal_offset - window
        if valid_len is not None:
            m &= kj < valid_len
        return m

    # Mode split (§Perf it-6): at DECODE (single query over a seq-sharded
    # cache) the grouped form avoids repeat_kv's broadcast+reshape, which
    # GSPMD can only realize by fully rematerializing the cache. At
    # train/prefill the grouped 5-D reshape would instead SPLIT the
    # model-sharded head dim (Hkv < axis size), so the classic repeated-KV
    # form partitions better there.
    if sq == 1:
        qg = q.reshape(b, sq, hkv, h // hkv, d)
        if valid_len is None:
            mask = None
        elif jnp.ndim(valid_len) == 1:
            # Per-slot fill levels: (B, 1, Sq, Sk) mask, one row per slot.
            mask = (jnp.arange(sk)[None, None, None, :]
                    < valid_len[:, None, None, None])
        else:
            mask = mask_fn(jnp.arange(sq)[:, None],
                           jnp.arange(sk)[None, :])[None, None]
        out = plain_attention(qg, k, v, mask)
        return out.reshape(b, sq, h, d)

    if ((causal_offset is not None and jnp.ndim(causal_offset) == 1)
            or (valid_len is not None and jnp.ndim(valid_len) == 1)):
        # Per-row offsets / fill levels at Sq > 1: a batch of chunked
        # prefill continuations, each resuming at its own cache offset.
        # Chunks are short, so the (B, Sq, Sk) mask is materialized and the
        # grouped plain form used directly — no flash.
        qi = jnp.arange(sq)[None, :, None]
        kj = jnp.arange(sk)[None, None, :]
        m = jnp.ones((b, sq, sk), bool)
        if causal_offset is not None:
            off = jnp.reshape(jnp.asarray(causal_offset), (-1, 1, 1))
            m &= kj <= qi + off
            if window is not None:
                m &= kj > qi + off - window
        if valid_len is not None:
            m &= kj < jnp.reshape(jnp.asarray(valid_len), (-1, 1, 1))
        out = plain_attention(q.reshape(b, sq, hkv, h // hkv, d), k, v,
                              m[:, None])
        return out.reshape(b, sq, h, d)

    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    qg = q[:, :, :, None, :]                      # (B,Sq,H,1,D): G=1 form
    if sq * sk <= 4_194_304:  # small enough to materialize scores
        qi = jnp.arange(sq)[:, None]
        kj = jnp.arange(sk)[None, :]
        need_mask = causal_offset is not None or valid_len is not None
        mask = mask_fn(qi, kj)[None, None] if need_mask else None
        out = plain_attention(qg, k, v, mask)
    else:
        out = flash_attention(qg, k, v, mask_fn, block_k=flash_block)
    return out.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def ffn_apply(p, x, act: str, pc: ParallelContext = NO_PARALLEL):
    h_gate = x @ p["w_gate"]
    h_up = x @ p["w_up"]
    # Column-parallel hint: batch over the data axes, d_ff over the model
    # axis. (A PartitionSpec ``None`` means REPLICATED, not unconstrained —
    # omitting the batch axes here forced GSPMD to all-gather the full
    # global batch before every FFN dot; §Perf iteration 3.) Applied only
    # to (B, S, d) activations: 2-D (tokens, d) inputs — the MoE shared
    # expert — carry a flat token sharding that a None spec would destroy.
    if (pc.mesh is not None and pc.model_axis is not None and x.ndim == 3
            and h_gate.shape[-1] % pc.mesh.shape[pc.model_axis] == 0):
        nb = 1
        for a in pc.data_axes:
            nb *= pc.mesh.shape[a]
        batch_ax = pc.data_axes if (nb and x.shape[0] % nb == 0) else None
        spec = (batch_ax, None, pc.model_axis)
        h_gate = pc.shard(h_gate, *spec)
        h_up = pc.shard(h_up, *spec)
    act_fn = jax.nn.gelu if act == "geglu" else jax.nn.silu
    h = act_fn(h_gate) * h_up
    return h @ p["w_down"]


def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }
