"""Attention blocks: GQA (RoPE / M-RoPE / qk-norm / sliding window), MLA,
and encoder-decoder cross-attention — with train / prefill / decode modes.

Caches are fixed-capacity (batched serving): global layers allocate
``cap = seq_len`` slots, sliding-window layers a ``min(cap, window)`` ring
buffer (RoPE is applied at write time with absolute positions, so ring slots
need no re-rotation). MLA caches the **compressed latent** (kv_lora + rope
key) and decodes with the absorbed-matrix form — the memory win that makes
DeepSeek-V3 decode feasible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_mrope, apply_rope, attention_core, rmsnorm)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_attn(key, cfg, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h, hd, d), dtype) * (h * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_attn_cache(cfg, batch: int, cap: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def _cache_write(cache_arr, new, slot, pc):
    """Write one token into the cache at (traced) sequence index ``slot``.

    ``slot`` may be a scalar (whole batch at one position) or a (B,) vector
    (per-slot positions — continuous batching), in which case each batch row
    writes at its own index via a one-hot masked update.

    On a mesh, a dynamic_update_slice at a traced index into the
    seq-SHARDED cache dim triggers GSPMD "involuntary full
    rematerialization" — the whole cache is all-gathered and re-sharded
    every layer every step (~tens of GB/step). A one-hot masked update is
    elementwise, stays local to each shard, and decode streams the full
    cache for attention anyway (§Perf iteration 5).
    """
    cap = cache_arr.shape[1]
    slot = jnp.asarray(slot)
    if slot.ndim == 1:
        mask = (jnp.arange(cap)[None, :] == slot[:, None]).reshape(
            (slot.shape[0], cap) + (1,) * (cache_arr.ndim - 2))
        return jnp.where(mask, new.astype(cache_arr.dtype), cache_arr)
    if pc is None or pc.mesh is None:
        idx = (0, slot) + (0,) * (cache_arr.ndim - 2)
        return jax.lax.dynamic_update_slice(cache_arr, new, idx)
    mask = (jnp.arange(cap) == slot).reshape(
        (1, cap) + (1,) * (cache_arr.ndim - 2))
    return jnp.where(mask, new.astype(cache_arr.dtype), cache_arr)


def _rope_qk(cfg, q, k, pos, pos3):
    if cfg.mrope_sections is not None:
        if pos3 is None:  # pure text: all three position streams equal
            pos3 = jnp.broadcast_to(pos[None], (3,) + pos.shape)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def _head_constraint(t, pc):
    """Pin (B, S, H, hd) activations to batch×head sharding when divisible.

    with_sharding_constraint transposes to the SAME constraint on the
    cotangent, so this also pins the backward dq/dk/dv — without it GSPMD
    resolves the dW einsum by all-gathering full-batch activations in f32
    over the data axis (§Perf iteration 3).

    DENSE archs only: MoE stacks keep activations in the EP (data, model)
    token layout between layers, and pinning q/k/v to batch-over-data
    forces a per-layer reshard (probe: 5.1 → 38.2 GiB/layer on phi3.5
    train — §Perf it-7)."""
    if pc is None or pc.mesh is None or pc.model_axis is None \
            or pc.ep_axes:
        return t
    nb = 1
    for a in pc.data_axes:
        nb *= pc.mesh.shape[a]
    if nb == 0 or t.shape[0] % max(nb, 1):
        return t
    if t.shape[2] % pc.mesh.shape[pc.model_axis]:
        return pc.shard(t, pc.data_axes, None, None, None)
    return pc.shard(t, pc.data_axes, None, pc.model_axis, None)


def attn_block(p, x, *, cfg, pos, window=None, cache=None, length=None,
               mode="train", pos3=None, flash_block=1024, causal=True,
               pc=None):
    """GQA attention. x: (B, S, d); pos: (B, S) absolute positions.

    mode: "train" (no cache) | "prefill" (build cache) | "decode" (S == 1,
    read + update cache at ``length``). Returns (y, new_cache | None).
    ``causal=False`` → bidirectional (encoder layers).
    """
    b, s, _ = x.shape
    offset = 0 if causal else None
    q = _head_constraint(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), pc)
    k = _head_constraint(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), pc)
    v = _head_constraint(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), pc)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q, k = _rope_qk(cfg, q, k, pos, pos3)

    new_cache = None
    if mode == "train":
        out = attention_core(q, k, v, causal_offset=offset, window=window,
                             valid_len=None, flash_block=flash_block)
    elif mode == "prefill" and length is None:
        # Fresh one-shot prefill: attend the s chunk keys only (O(s^2), not
        # O(s*cap)) and write from offset 0 — the pre-chunking fast path.
        cap = cache["k"].shape[1]
        out = attention_core(q, k, v, causal_offset=offset, window=window,
                             valid_len=None, flash_block=flash_block)
        if cap < s:
            # Ring buffer smaller than the prefill: keep the last cap tokens
            # (their slot indices are consecutive mod cap → unique writes).
            kk, vv = k[:, s - cap:], v[:, s - cap:]
            slots = pos[0, s - cap:] % cap
            new_cache = {"k": cache["k"].at[:, slots].set(kk),
                         "v": cache["v"].at[:, slots].set(vv)}
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v, (0, 0, 0, 0))}
    elif mode == "prefill":
        # Chunked CONTINUATION: the chunk's keys land at the current fill
        # level ``length`` and queries attend the cached prefix plus the
        # causal part of the chunk. causal_offset = start makes query i see
        # key j iff j <= start + i; valid_len covers the Sq == 1 single-
        # token-chunk case, where attention_core ignores causal_offset.
        # ``length`` may be a (B,) vector — per-slot offsets, each batch row
        # resuming its own chunked prefill. Wrapped rings can't continue
        # (slot positions become ambiguous);
        # Model.supports_chunked_prefill gates those shapes out upstream.
        cap = cache["k"].shape[1]
        if cap < s:
            raise ValueError("chunked prefill continuation into a cache "
                             f"smaller than the chunk ({cap} < {s})")
        start = length.astype(jnp.int32)
        if start.ndim == 1:
            rows = jnp.arange(b)[:, None]
            idx = start[:, None] + jnp.arange(s)[None]       # (B, s)
            ck = cache["k"].at[rows, idx].set(
                k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[rows, idx].set(
                v.astype(cache["v"].dtype), mode="drop")
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
        out = attention_core(q, ck, cv, causal_offset=start,
                             window=window, valid_len=start + s,
                             flash_block=flash_block)
        new_cache = {"k": ck, "v": cv}
    else:  # decode: s == 1, absolute position == length
        cap = cache["k"].shape[1]
        if window is not None and cap <= window:
            slot = length % cap
        else:
            slot = jnp.minimum(length, cap - 1)
        ck = _cache_write(cache["k"], k, slot, pc)
        cv = _cache_write(cache["v"], v, slot, pc)
        new_cache = {"k": ck, "v": cv}
        valid = jnp.minimum(length + 1, cap)
        kc = pc.kernels if pc is not None else None
        if kc is not None:
            # Kernelized hot path: stream the per-slot cache past the single
            # query through kernels.ops.decode_attn_auto (Pallas flash-decode
            # on TPU / interpret; jnp oracle on CPU — same masking math).
            from repro.kernels.ops import decode_attn_auto
            out = decode_attn_auto(q[:, 0], ck, cv, valid,
                                   block_s=kc.block_s,
                                   interpret=kc.interpret)[:, None]
        else:
            out = attention_core(q, ck, cv, causal_offset=None, window=None,
                                 valid_len=valid, flash_block=flash_block)
    out = _head_constraint(out, pc)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype) -> dict:
    m, d, h = cfg.mla, cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": jax.random.normal(ks[0], (d, m.q_lora_rank), dtype) * d ** -0.5,
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": jax.random.normal(
            ks[1], (m.q_lora_rank, h, qk_head), dtype) * m.q_lora_rank ** -0.5,
        "wkv_a": jax.random.normal(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype) * d ** -0.5,
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wk_b": jax.random.normal(
            ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim),
            dtype) * m.kv_lora_rank ** -0.5,
        "wv_b": jax.random.normal(
            ks[4], (m.kv_lora_rank, h, m.v_head_dim),
            dtype) * m.kv_lora_rank ** -0.5,
        "wo": jax.random.normal(
            ks[5], (h, m.v_head_dim, d), dtype) * (h * m.v_head_dim) ** -0.5,
    }


def init_mla_cache(cfg, batch: int, cap: int, dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cap, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cap, m.qk_rope_head_dim), dtype),
    }


def _mla_qkv(p, x, cfg, pos):
    m = cfg.mla
    cq = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    ckv_full = x @ p["wkv_a"]
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_block(p, x, *, cfg, pos, cache=None, length=None, mode="train",
              flash_block=1024, pc=None, **_):
    """MLA attention. Direct form for train/prefill; absorbed for decode."""
    m = cfg.mla
    b, s, _ = x.shape
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, pos)

    new_cache = None
    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_b"])
        h = k_nope.shape[2]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, s, h, m.qk_rope_head_dim))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        # attention_core assumes equal k/v head dims; pad v with zeros up to
        # the qk head size and slice the output back (exact, no bias).
        qk_dim = q.shape[-1]
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                            (0, qk_dim - m.v_head_dim)))
        out = attention_core(q, k, v_pad, causal_offset=0, window=None,
                             valid_len=None, flash_block=flash_block)
        out = out[..., :m.v_head_dim]
        if mode == "prefill":
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv, (0, 0, 0)),
                "k_rope": jax.lax.dynamic_update_slice(
                    cache["k_rope"], k_rope, (0, 0, 0))}
    else:  # decode — absorbed-matrix form over the latent cache
        cap = cache["ckv"].shape[1]
        slot = jnp.minimum(length, cap - 1)
        cckv = _cache_write(cache["ckv"], ckv, slot, pc)
        ckr = _cache_write(cache["k_rope"], k_rope, slot, pc)
        new_cache = {"ckv": cckv, "k_rope": ckr}
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])   # absorb W^UK
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, cckv)
                  + jnp.einsum("bshk,btk->bhst", q_rope, ckr)) * scale
        vl = jnp.minimum(length + 1, cap)
        if jnp.ndim(vl) == 1:   # per-slot fill levels (continuous batching)
            valid = jnp.arange(cap)[None, :] < vl[:, None]       # (B, cap)
            scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        else:
            valid = jnp.arange(cap) < vl
            scores = jnp.where(valid[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs.astype(cckv.dtype), cckv)
        out = jnp.einsum("bshr,rhk->bshk", ctx_lat, p["wv_b"])    # absorb W^UV

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attn_block(p, x, enc_kv, *, cfg, flash_block=1024):
    """Decoder cross-attention over precomputed encoder K/V (no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = attention_core(q, enc_kv["k"], enc_kv["v"], causal_offset=None,
                         window=None, valid_len=None,
                         flash_block=flash_block)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(p, enc_out):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    return {"k": jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"]),
            "v": jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])}
