"""Architecture-generic stack: decoder / encoder-decoder / hybrid.

Layers are grouped into **segments** — the smallest repeating block of layer
kinds (e.g. gemma3's ``LLLLLG``; zamba2's ``MMMMMMA``; deepseek's 3 dense +
58 MoE). Parameters and caches are stacked per segment and the stack scans
over blocks with ``lax.scan``, keeping HLO size O(segment), not O(n_layers)
— essential for lowering 61–81-layer production configs.

Layer kinds:
  G global attention + FFN     L sliding-window attention + FFN
  D attention + dense FFN (MoE arch's leading dense layers)
  E attention + MoE FFN        M Mamba2 (SSD)
  A zamba2 shared attention block (parameters shared across occurrences)
  C decoder layer with cross-attention (encoder-decoder)
  B bidirectional encoder layer
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import ssm as ssm_mod
from .layers import NO_PARALLEL, ParallelContext, ffn_apply, init_ffn, rmsnorm
from .moe import init_moe, moe_apply


# ---------------------------------------------------------------------------
# Segment structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]
    count: int                       # number of scanned blocks


def segments_of(cfg) -> list[Segment]:
    """Decoder-side segment decomposition of the layer stack."""
    n = cfg.n_layers
    if cfg.is_encoder_decoder:
        return [Segment(("C",), n)]
    if cfg.family == "ssm":
        return [Segment(("M",), n)]
    if cfg.family == "hybrid":
        q = cfg.hybrid_period + 1
        segs = [Segment(("M",) * cfg.hybrid_period + ("A",), n // q)]
        if n % q:
            segs.append(Segment(("M",) * (n % q), 1))
        return segs
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        k = cfg.moe.first_dense_layers
        return [Segment(("D",), k), Segment(("E",), n - k)]
    if cfg.moe is not None:
        return [Segment(("E",), n)]
    if cfg.layer_pattern:
        p = len(cfg.layer_pattern)
        segs = [Segment(tuple(cfg.layer_pattern), n // p)]
        if n % p:
            segs.append(Segment(tuple(cfg.layer_pattern[: n % p]), 1))
        return segs
    return [Segment(("G",), n)]


def padded_vocab(cfg) -> int:
    """Vocab rounded up so the tensor axis and the MXU lane width divide it."""
    return -(-cfg.vocab // 256) * 256


def moe_layer_count(cfg) -> int:
    """Number of MoE layers, in the canonical stats order (segment-major,
    kind-major, block-major — the order ``forward(collect_moe_stats=True)``
    stacks per-layer routing counts in)."""
    return sum(seg.count * seg.kinds.count("E") for seg in segments_of(cfg))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_layer(key, kind: str, cfg, dtype) -> dict:
    from .layers import init_rmsnorm
    d = cfg.d_model
    if kind == "M":
        k1, = jax.random.split(key, 1)
        return {"ln": init_rmsnorm(d, dtype)["scale"],
                "mamba": ssm_mod.init_mamba(k1, cfg, dtype)}
    if kind == "A":
        return {}                                   # shared params used
    ks = jax.random.split(key, 4)
    init_a = attn_mod.init_mla if cfg.mla is not None else attn_mod.init_attn
    p = {"ln1": jnp.zeros((d,), dtype), "attn": init_a(ks[0], cfg, dtype),
         "ln2": jnp.zeros((d,), dtype)}
    if kind == "E":
        p["moe"] = init_moe(ks[1], d, cfg.moe, dtype)
    elif kind == "D":
        p["ffn"] = init_ffn(ks[1], d, cfg.moe.dense_d_ff, dtype)
    else:
        p["ffn"] = init_ffn(ks[1], d, cfg.d_ff, dtype)
    if kind == "C":
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xattn"] = attn_mod.init_attn(ks[2], cfg, dtype)
    return p


def _init_segment(key, seg: Segment, cfg, dtype):
    """Per-position stacked params: tuple of dicts, leaves (count, ...)."""
    out = []
    for i, kind in enumerate(seg.kinds):
        ks = jax.random.split(jax.random.fold_in(key, i), seg.count)
        out.append(jax.vmap(lambda k: _init_layer(k, kind, cfg, dtype))(ks))
    return tuple(out)


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg)
    keys = jax.random.split(key, 8)
    p = {
        "embed": jax.random.normal(keys[0], (vp, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "segments": tuple(
            _init_segment(jax.random.fold_in(keys[1], si), seg, cfg, dtype)
            for si, seg in enumerate(segments_of(cfg))
        ),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            keys[2], (cfg.d_model, vp), dtype) * cfg.d_model ** -0.5
    if cfg.family == "hybrid":                      # zamba2 shared block
        p["shared"] = _init_layer(keys[3], "G", cfg, dtype)
    if cfg.is_encoder_decoder:
        enc_seg = Segment(("B",), cfg.n_encoder_layers)
        p["encoder"] = {
            "segments": (_init_segment(keys[4], enc_seg, cfg, dtype),),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    if cfg.input_mode != "text":
        p["frontend_proj"] = jax.random.normal(
            keys[5], (cfg.frontend_dim, cfg.d_model),
            dtype) * cfg.frontend_dim ** -0.5
    return p


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _init_layer_cache(kind: str, cfg, batch: int, cap: int, src_len: int,
                      dtype):
    if kind == "M":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if kind in ("D", "E", "G", "A"):
        if cfg.mla is not None:
            return attn_mod.init_mla_cache(cfg, batch, cap, dtype)
        return attn_mod.init_attn_cache(cfg, batch, cap, dtype)
    if kind == "L":
        w = min(cap, cfg.sliding_window)
        return attn_mod.init_attn_cache(cfg, batch, w, dtype)
    if kind == "C":
        c = attn_mod.init_attn_cache(cfg, batch, cap, dtype)
        c["xk"] = jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.head_dim),
                            dtype)
        c["xv"] = jnp.zeros_like(c["xk"])
        return c
    raise ValueError(kind)


def init_cache(cfg, batch: int, cap: int, src_len: int = 0,
               dtype=None, per_slot_len: bool = False) -> dict:
    """``per_slot_len=True`` makes ``cache["len"]`` a (batch,) vector — each
    batch row (decode slot) tracks its own sequence length, the cache layout
    continuous batching decodes against."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    segs = []
    for seg in segments_of(cfg):
        entries = []
        for kind in seg.kinds:
            one = _init_layer_cache(kind, cfg, batch, cap, src_len, dtype)
            entries.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.count,) + x.shape), one))
        segs.append(tuple(entries))
    ln = (jnp.zeros((batch,), jnp.int32) if per_slot_len
          else jnp.zeros((), jnp.int32))
    return {"len": ln, "segments": tuple(segs)}


def merge_cache_slot(cache, sub, slot):
    """Write a batch-1 cache ``sub`` into row ``slot`` of a multi-slot cache.

    Segment cache leaves are stacked (count, batch, ...), so the batch/slot
    dim is axis 1. ``cache["len"]`` must be per-slot (a vector); the slot's
    length is set to ``sub["len"]``. Used by per-slot prefill: a freshly
    prefilled request lands in one decode slot of the shared cache.
    """
    segs = jax.tree.map(
        lambda full, new: jax.lax.dynamic_update_slice_in_dim(
            full, new.astype(full.dtype), slot, axis=1),
        cache["segments"], sub["segments"])
    return {"len": cache["len"].at[slot].set(sub["len"].astype(jnp.int32)),
            "segments": segs}


def slice_cache_slot(cache, slot):
    """Batch-1 copy of row ``slot`` of a multi-slot cache — the inverse view
    of ``merge_cache_slot``. Segment leaves are stacked (count, batch, ...),
    so the slot is sliced on axis 1; the slot's recorded fill level becomes
    the scalar ``len``, so the slice feeds straight into the scalar prefill
    continuation path. ``slot`` may be traced."""
    segs = jax.tree.map(
        lambda full: jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=1),
        cache["segments"])
    return {"len": cache["len"][slot].astype(jnp.int32), "segments": segs}


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(kind, p, x, entry, *, cfg, pc, mode, pos, pos3, length,
                 shared, enc_out=None, collect_stats=False, row_mask=None):
    """One layer. Returns (x, new_cache_entry, aux, moe_counts).

    ``moe_counts`` is None unless ``collect_stats`` and the layer is MoE, in
    which case it is a (B, S, E) float32 per-position count of routed
    (token, k) choices — the live traffic signal harvested by the serving
    monitor (positions kept separate so callers can mask left-padding).

    ``row_mask`` (decode only): (B,) bool gating cache updates per batch
    row — masked-out rows keep their previous KV / latent / SSM state. One
    generic gate here covers every cache layout (GQA, MLA, Mamba, cross-KV).

    Note: no blanket activation constraint here — an explicit per-layer
    P(data, …) pin was tried (§Perf it-3) and REFUTED: neutral for dense
    archs (the FFN/qkv hints do the real work) and actively harmful for
    MoE archs, whose activations want the EP (data, model) token layout
    between layers; pinning them data-only forced per-layer resharding.
    """
    aux = jnp.zeros((), jnp.float32)

    def gate(nc):
        # Freeze masked-out rows' cache state (batch is axis 0 of every
        # cache entry leaf). Elementwise select — stays shard-local.
        if mode != "decode" or row_mask is None or nc is None:
            return nc
        return jax.tree.map(
            lambda new, old: jnp.where(
                row_mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
            nc, entry)

    if kind == "M":
        h = rmsnorm(p["ln"], x, cfg.norm_eps)
        if mode == "decode":
            y, nc = ssm_mod.mamba_decode(p["mamba"], h, cfg, entry)
        else:
            # Prefill reads conv/SSD state from the cache entry and writes
            # the final state back, so a chunked continuation (non-zero
            # initial state) is the same code path as a fresh prefill.
            y, nc = ssm_mod.mamba_block(
                p["mamba"], h, cfg, entry if mode == "prefill" else None)
        return x + y, gate(nc), aux, None

    pp = shared if kind == "A" else p
    h = rmsnorm(pp["ln1"], x, cfg.norm_eps)
    window = cfg.sliding_window if kind == "L" else None
    causal = kind != "B"
    block = (partial(attn_mod.mla_block, pc=pc) if cfg.mla is not None
             else partial(attn_mod.attn_block, causal=causal, pc=pc))
    attn_cache = None
    if entry is not None:
        attn_cache = ({k: v for k, v in entry.items()
                       if k not in ("xk", "xv")} if kind == "C" else entry)
    y, nc = block(pp["attn"], h, cfg=cfg, pos=pos, window=window,
                  cache=attn_cache, length=length, mode=mode, pos3=pos3,
                  flash_block=pc.flash_block)
    x = x + y

    if kind == "C":
        hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        if mode == "decode":
            kv = {"k": entry["xk"], "v": entry["xv"]}
        else:  # train / prefill: fresh cross K/V from the encoder output
            kv = attn_mod.encode_cross_kv(p["xattn"], enc_out)
        yx = attn_mod.cross_attn_block(p["xattn"], hx, kv, cfg=cfg,
                                       flash_block=pc.flash_block)
        x = x + yx
        if nc is not None:
            nc = dict(nc, xk=kv["k"], xv=kv["v"])

    h2 = rmsnorm(pp["ln2"], x, cfg.norm_eps)
    counts = None
    if kind == "E":
        if collect_stats:
            y2, aux, counts = moe_apply(p["moe"], h2, cfg.moe, cfg.act, pc,
                                        return_counts=True)   # (B, S, E)
        else:
            y2, aux = moe_apply(p["moe"], h2, cfg.moe, cfg.act, pc)
    else:
        y2 = ffn_apply(pp["ffn"], h2, cfg.act, pc)
    return x + y2, gate(nc), aux, counts


def _run_segment(seg, seg_params, seg_cache, x, *, cfg, pc, mode, pos, pos3,
                 length, shared, enc_out=None, remat=False,
                 collect_stats=False, row_mask=None):
    """Scan one segment over its ``count`` blocks.

    Returns (x, new_cache, stats, aux). ``stats`` is a tuple with one
    (count, B, S, E) array per MoE kind position when ``collect_stats``,
    else an empty tuple."""
    with_cache = mode != "train"

    def block(carry, xs):
        x, aux = carry
        params = xs[0] if with_cache else xs
        cache = xs[1] if with_cache else (None,) * len(seg.kinds)
        new_entries = []
        stats = []
        for i, kind in enumerate(seg.kinds):
            x, nc, a, cnt = _apply_layer(
                kind, params[i], x, cache[i], cfg=cfg, pc=pc, mode=mode,
                pos=pos, pos3=pos3, length=length, shared=shared,
                enc_out=enc_out, collect_stats=collect_stats,
                row_mask=row_mask)
            aux = aux + a
            new_entries.append(nc)
            if cnt is not None:
                stats.append(cnt)
        return (x, aux), (tuple(new_entries) if with_cache else None,
                          tuple(stats))

    if remat:
        block = jax.checkpoint(block)
    xs = (seg_params, seg_cache) if with_cache else seg_params
    if pc.unroll_segments:
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for b in range(seg.count):
            xs_b = jax.tree.map(lambda t: t[b], xs)
            carry, y = block(carry, xs_b)
            ys.append(y)
        (x, aux) = carry
        new_cache, stats = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
        return x, new_cache if with_cache else None, stats, aux
    (x, aux), (new_cache, stats) = jax.lax.scan(
        block, (x, jnp.zeros((), jnp.float32)), xs, length=seg.count)
    return x, new_cache, stats, aux


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def encode(params, cfg, frames, pc: ParallelContext = NO_PARALLEL):
    """Encoder stack (audio): frames (B, S_src, frontend_dim) → (B, S, d)."""
    x = frames @ params["frontend_proj"]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    enc_seg = Segment(("B",), cfg.n_encoder_layers)
    x, _, _, _ = _run_segment(
        enc_seg, params["encoder"]["segments"][0], None, x, cfg=cfg, pc=pc,
        mode="train", pos=pos, pos3=None, length=None, shared=None)
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg, *, tokens=None, embeds=None, mode="train",
            cache=None, pc: ParallelContext = NO_PARALLEL, pos3=None,
            enc_out=None, remat=False, collect_moe_stats=False,
            continuation=False, row_mask=None):
    """Run the decoder stack.

    mode "train"/"prefill": tokens (B, S) or embeds (B, S, F). With
    ``continuation=True`` (a STATIC flag) a prefill resumes at the cache's
    fill level ``cache["len"]``: positions and cache writes start at the
    offset and queries attend the cached prefix, so a prompt absorbed in
    chunks is mathematically identical to one-shot prefill. ``len`` may be a
    scalar or a per-slot (B,) vector — each row then resumes at its own
    offset (ring-buffer sliding-window caches support continuation only
    while the prompt fits inside the ring — see
    ``Model.supports_chunked_prefill``). Fresh prefills keep the cheap
    chunk-local attention (O(S^2), not O(S*cap)).
    mode "decode": tokens (B, 1), cache required (reads cache["len"]).
    ``row_mask`` (decode only): (B,) bool; rows where it is False keep their
    cache state and fill level unchanged — the continuous engine freezes
    slots that hold a partially absorbed chunked prefill (their logits are
    still computed and discarded, as for any vacant slot).
    enc_out: encoder output for encoder-decoder archs (train / prefill).
    Returns (logits (B, S, padded_vocab), new_cache | None, aux_loss,
    moe_stats) where moe_stats is a (n_moe_layers, B, S, E) float32 array of
    per-position routed-choice counts (segment-major, kind-major,
    block-major layer order — ``moe_layer_count``) when
    ``collect_moe_stats``, else None. Callers mask pad positions before
    aggregating traffic from prefill stats.
    """
    if cfg.is_encoder_decoder or cfg.input_mode == "text" or embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    else:
        x = embeds @ params["frontend_proj"]
    b, s = x.shape[:2]
    if mode == "decode":
        length = cache["len"]
        if length.ndim == 1:   # per-slot lengths (continuous batching)
            pos = jnp.broadcast_to(length[:, None], (b, s))
        else:
            pos = jnp.broadcast_to(length[None, None], (b, s))
    elif mode == "prefill" and continuation:
        if cache is None:
            raise ValueError("prefill continuation requires a cache")
        length = cache["len"]
        if length.ndim == 1:   # per-slot offsets: each row resumes its own
            pos = length[:, None] + jnp.broadcast_to(jnp.arange(s)[None],
                                                     (b, s))
        else:
            pos = length[None, None] + jnp.broadcast_to(jnp.arange(s)[None],
                                                        (b, s))
    else:
        length = None
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    shared = params.get("shared")
    aux_total = jnp.zeros((), jnp.float32)
    new_segs = []
    stats_parts = []
    for si, seg in enumerate(segments_of(cfg)):
        seg_cache = cache["segments"][si] if cache is not None else None
        x, nc, stats, aux = _run_segment(
            seg, params["segments"][si], seg_cache, x, cfg=cfg, pc=pc,
            mode=mode, pos=pos, pos3=pos3, length=length, shared=shared,
            enc_out=enc_out, remat=remat, collect_stats=collect_moe_stats,
            row_mask=row_mask)
        aux_total = aux_total + aux
        new_segs.append(nc)
        stats_parts.extend(stats)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    new_cache = None
    if mode != "train" and cache is not None:
        inc = jnp.asarray(s if mode == "prefill" else 1, jnp.int32)
        if mode == "decode" and row_mask is not None:
            inc = inc * row_mask.astype(jnp.int32)   # frozen rows: no bump
        new_cache = {"len": cache["len"] + inc, "segments": tuple(new_segs)}
    moe_stats = None
    if collect_moe_stats:
        moe_stats = (jnp.concatenate(stats_parts, axis=0) if stats_parts
                     else jnp.zeros((0, b, s, 0), jnp.float32))
    return logits, new_cache, aux_total, moe_stats
