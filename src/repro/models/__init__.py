"""Model zoo: layers, attention, SSM, MoE, and the architecture-generic
transformer stack behind the ``Model`` facade."""

from .layers import KernelConfig, NO_PARALLEL, ParallelContext
from .model import Model, cross_entropy
from .transformer import (Segment, forward, init_cache, init_params,
                          merge_cache_slot, padded_vocab, segments_of)

__all__ = ["KernelConfig", "NO_PARALLEL", "ParallelContext", "Model",
           "cross_entropy", "Segment", "forward", "init_cache",
           "init_params", "merge_cache_slot", "padded_vocab", "segments_of"]
