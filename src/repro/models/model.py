"""Top-level model facade: one API over all 10 assigned architectures.

``Model(cfg)`` exposes:
  init(key)                          → params
  train_logits(params, batch)        → (logits, aux)
  prefill(params, inputs, cache)     → (logits, cache)
  decode_step(params, token, cache)  → (logits, cache)
  init_cache(batch, cap)             → cache pytree

Input conventions per family (see DESIGN.md §5):
  text archs       tokens (B, S) int32
  vlm              prefill takes ``embeds`` (B, S, frontend_dim) patch
                   embeddings from the vision-stub; train/decode take tokens
  audio (enc-dec)  ``frames`` (B, S_src, frontend_dim); the decoder runs on
                   target tokens; cross-K/V is cached at prefill
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import KernelConfig, NO_PARALLEL, ParallelContext
from . import transformer as tf


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: object
    pc: ParallelContext = NO_PARALLEL

    # -- params / cache ----------------------------------------------------
    def init(self, key):
        return tf.init_params(key, self.cfg)

    def with_kernels(self, kernels: "KernelConfig | bool" = True) -> "Model":
        """Model routed through the Pallas serving hot path.

        Attaches a ``KernelConfig`` to the parallel context (decode-step
        attention → ``kernels.ops.decode_attn_auto``) and, for MoE configs
        not already expert-parallel, switches dispatch to the sort-based
        ragged kernel path (``moe_impl="kernel"``). Pass a ``KernelConfig``
        to pin block shapes or force interpret mode; ``False`` is a no-op so
        engines can thread their ``kernels=`` flag straight through.
        """
        if kernels is False:
            return self
        kc = kernels if isinstance(kernels, KernelConfig) else KernelConfig()
        impl = self.pc.moe_impl
        if self.cfg.moe is not None and impl not in ("ep", "aurora"):
            impl = "kernel"
        pc = dataclasses.replace(self.pc, kernels=kc, moe_impl=impl)
        return dataclasses.replace(self, pc=pc)

    def init_cache(self, batch: int, cap: int, src_len: int = 0,
                   per_slot_len: bool = False):
        return tf.init_cache(self.cfg, batch, cap, src_len=src_len,
                             per_slot_len=per_slot_len)

    @property
    def padded_vocab(self) -> int:
        return tf.padded_vocab(self.cfg)

    # -- training ----------------------------------------------------------
    def train_logits(self, params, batch, remat: bool = True):
        """batch: {"tokens": (B,S)} (+ "frames" for enc-dec, "embeds" for
        vlm-style pretraining). Returns (logits, aux_loss)."""
        cfg = self.cfg
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = tf.encode(params, cfg, batch["frames"], self.pc)
        logits, _, aux, _ = tf.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), mode="train", pc=self.pc,
            enc_out=enc_out, remat=remat)
        return logits, aux

    # -- serving -----------------------------------------------------------
    def prefill(self, params, inputs, cache, collect_moe_stats: bool = False,
                continuation: bool = False):
        """inputs: {"tokens"} | {"embeds"} | {"frames", "tokens"}.

        ``continuation=True`` (static) resumes a chunked prefill at the
        cache's fill level (scalar, or per-slot (B,) vector — each row at
        its own offset): positions and cache writes start at the offset, so
        absorbing a prompt chunk-by-chunk over the same cache equals
        one-shot prefill (``supports_chunked_prefill`` gates eligible
        arch/shape combos). Returns (logits, cache) — plus
        (n_moe_layers, B, S, E) per-position routing counts when
        ``collect_moe_stats`` (mask left-pad positions before aggregating).
        """
        cfg = self.cfg
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = tf.encode(params, cfg, inputs["frames"], self.pc)
        logits, cache, _, stats = tf.forward(
            params, cfg, tokens=inputs.get("tokens"),
            embeds=inputs.get("embeds"), mode="prefill", cache=cache,
            pc=self.pc, enc_out=enc_out, collect_moe_stats=collect_moe_stats,
            continuation=continuation)
        if collect_moe_stats:
            return logits, cache, stats
        return logits, cache

    def decode_step(self, params, token, cache, row_mask=None):
        """token: (B, 1) int32. Returns (logits (B,1,V), cache).

        ``row_mask`` (B,) bool gates cache updates per row: masked-out rows
        keep their cache state and fill level (the continuous engine freezes
        vacant slots and the slot holding a partially chunk-prefilled
        prompt; the masked rows' logits are computed and discarded)."""
        logits, cache, _, _ = tf.forward(
            params, self.cfg, tokens=token, mode="decode", cache=cache,
            pc=self.pc, row_mask=row_mask)
        return logits, cache

    def decode_step_stats(self, params, token, cache, row_mask=None):
        """``decode_step`` that also returns (n_moe_layers, B, E) float32
        per-slot routed-choice counts (the live traffic signal for
        ``repro.serving.monitor.TrafficMonitor``)."""
        logits, cache, _, stats = tf.forward(
            params, self.cfg, tokens=token, mode="decode", cache=cache,
            pc=self.pc, collect_moe_stats=True, row_mask=row_mask)
        return logits, cache, stats[:, :, 0, :]      # S == 1 at decode

    def prefill_slot(self, params, inputs, cache, slot, *, cap: int,
                     src_len: int = 0, collect_moe_stats: bool = False):
        """Prefill ONE request into row ``slot`` of a multi-slot cache.

        The request is run through ``prefill`` against a fresh zero batch-1
        cache (so no state from a previous occupant of the slot can leak),
        then written into the shared cache at the slot offset. ``cache`` must
        be per-slot (``init_cache(..., per_slot_len=True)``); ``slot`` may be
        traced, so one jit covers every slot. Returns (logits, cache)
        (+ per-position (n_moe_layers, 1, S, E) routing counts when
        ``collect_moe_stats`` — mask left-pad positions before aggregating).
        """
        sub = tf.init_cache(self.cfg, 1, cap, src_len=src_len)
        if collect_moe_stats:
            logits, sub, stats = self.prefill(params, inputs, sub,
                                              collect_moe_stats=True)
            return logits, tf.merge_cache_slot(cache, sub, slot), stats
        logits, sub = self.prefill(params, inputs, sub)
        return logits, tf.merge_cache_slot(cache, sub, slot)

    def merge_slot(self, cache, sub, slot):
        """Write a completed batch-1 prefill cache into row ``slot`` of the
        shared per-slot cache (the final step of a chunked prefill)."""
        return tf.merge_cache_slot(cache, sub, slot)

    def prefill_chunk_slot(self, params, inputs, cache, slot, *, first: bool,
                           cap: int, src_len: int = 0,
                           collect_moe_stats: bool = False):
        """One chunk of a chunked prefill for row ``slot`` of the shared
        per-slot cache — slice, continue, merge in ONE program, so the
        partially absorbed prompt's state lives in its slot row between
        chunks (no detached batch-1 cache shuttled on the host).

        ``first=True`` (static) starts from a fresh ZERO batch-1 cache so no
        state from the slot's previous occupant can leak (SSM state is
        cumulative — a stale conv/SSD state would silently corrupt the new
        prompt); later chunks resume from the slot's own state at its
        recorded fill level. Between chunks the engine freezes the slot's
        row against decode writes (``decode_step(row_mask=...)``). Returns
        (logits, cache) (+ per-position routing counts)."""
        if first:
            sub = tf.init_cache(self.cfg, 1, cap, src_len=src_len)
        else:
            sub = tf.slice_cache_slot(cache, slot)
        if collect_moe_stats:
            logits, sub, stats = self.prefill(
                params, inputs, sub, collect_moe_stats=True,
                continuation=not first)
            return logits, tf.merge_cache_slot(cache, sub, slot), stats
        logits, sub = self.prefill(params, inputs, sub,
                                   continuation=not first)
        return logits, tf.merge_cache_slot(cache, sub, slot)

    @property
    def n_moe_layers(self) -> int:
        """MoE layer count, in the canonical routing-stats order."""
        return tf.moe_layer_count(self.cfg)

    def chunkable_len(self, cache_cap: int) -> int | None:
        """Longest (padded) prompt absorbable in chunks — ``None`` when
        unbounded, ``0`` when the arch cannot chunk at all.

        Chunked continuation needs cache writes at a traced offset, which
        rules out MLA (prefill writes the latent at offset 0 only) and
        encoder-decoder (the encoder would re-run per chunk) entirely.
        Sliding-window ring buffers continue exactly while the prompt stays
        inside the ring — only a prompt that WRAPS it loses slot identity
        mid-prefill — so their bound is the ring size. SSM state and global
        GQA caches continue without bound."""
        cfg = self.cfg
        if cfg.mla is not None or cfg.is_encoder_decoder:
            return 0
        kinds = {k for seg in tf.segments_of(cfg) for k in seg.kinds}
        if "L" in kinds:
            return min(cache_cap, cfg.sliding_window)
        return None

    def supports_chunked_prefill(self, total_len: int, cache_cap: int) -> bool:
        """Whether a ``total_len``-token (padded) prompt may be absorbed in
        chunks — see ``chunkable_len`` for the per-arch bound."""
        lim = self.chunkable_len(cache_cap)
        return lim is None or total_len <= lim


def cross_entropy(logits, labels, vocab: int):
    """Mean CE over tokens; logits (B,S,Vpad) may carry padded vocab slots.

    Written to stay VOCAB-SHARDED under GSPMD (§Perf iteration 2): the
    padded-slot mask is an elementwise iota compare (no cross-shard
    scatter), and the label logit is a fused select+reduce instead of
    ``take_along_axis`` — the naive forms forced XLA to all-gather the full
    (B, S, 152k) f32 logits to every device (74 GiB/step on qwen3 train).
    Only (B, S)-sized partial sums cross the mesh.
    """
    vpad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    slot = jnp.arange(vpad)
    if vpad > vocab:
        logits = jnp.where(slot >= vocab, -1e30, logits)
    # logsumexp: local max/sum over the vocab shard + tiny all-reduces.
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    # Label logit: compare-select-reduce fuses into the logits producer.
    lab = jnp.where(slot == labels[..., None], logits, 0.0).sum(-1)
    return (lse - lab).mean()
