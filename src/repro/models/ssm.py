"""Mamba2 (State-Space Duality) blocks [arXiv:2405.21060].

Chunked SSD forward for train/prefill — the block-decomposition of the
semiseparable attention form: intra-chunk "attention" with the 1-SS decay
mask plus an inter-chunk state recurrence carried by ``lax.scan`` — and a
constant-time single-token recurrence for decode (this is what makes SSM
archs eligible for the ``long_500k`` shape: no KV cache, O(1) state).

TPU adaptation: the chunk length is the tile unit — intra-chunk einsums are
(Q×Q)·(Q×P) matmuls that map onto the MXU; the sequential part is only the
S/Q chunk-granular scan. Heads/d_inner shard over the tensor axis; batch over
data; the scan itself is unsharded in sequence (chunk recurrence is serial).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rmsnorm


def d_inner_of(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def n_heads_of(cfg) -> int:
    return d_inner_of(cfg) // cfg.ssm.head_dim


def conv_dim_of(cfg) -> int:
    return d_inner_of(cfg) + 2 * cfg.ssm.n_groups * cfg.ssm.d_state


def init_mamba(key, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = d_inner_of(cfg)
    h = n_heads_of(cfg)
    cdim = conv_dim_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + h
    return {
        "in_proj": jax.random.normal(k1, (d, in_dim), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(k2, (s.conv_kernel, cdim), dtype) * 0.3,
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": jax.random.normal(k3, (di, d), dtype) * di ** -0.5,
    }


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim_of(cfg)), dtype),
        "state": jnp.zeros((batch, n_heads_of(cfg), s.head_dim, s.d_state),
                           jnp.float32),
    }


# ---------------------------------------------------------------------------
# Depthwise causal conv
# ---------------------------------------------------------------------------

def _conv_scan(w, b, x, init_state):
    """Causal depthwise conv1d. x: (B, S, C); init_state: (B, K-1, C)."""
    k = w.shape[0]
    xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(out + b), new_state


# ---------------------------------------------------------------------------
# Chunked SSD
# ---------------------------------------------------------------------------

def _expand_groups(m, h: int):
    """(B, S, G, N) → (B, S, H, N) by repeating each group H/G times."""
    g = m.shape[2]
    if g == h:
        return m
    return jnp.repeat(m, h // g, axis=2)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, init_state=None):
    """SSD scan. x: (B,S,H,P); dt: (B,S,H); a: (H,) negative;
    b_mat/c_mat: (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q

    bh = _expand_groups(b_mat, h)
    ch = _expand_groups(c_mat, h)

    def to_chunks(t):
        return t.reshape((bsz, nc, q) + t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x.astype(jnp.float32) * dt[..., None]),
          to_chunks((dt * a).astype(jnp.float32)),        # dA, negative
          to_chunks(bh.astype(jnp.float32)),
          to_chunks(ch.astype(jnp.float32)))

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xdt, da, bc, cc = inp                     # (B,Q,H,P) (B,Q,H) (B,Q,H,N)
        cum = jnp.cumsum(da, axis=1)              # (B,Q,H)
        # Intra-chunk: 1-SS masked attention  L[q1,q2] = exp(cum_q1 - cum_q2).
        rel = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
        # Mask BEFORE exp: upper-tri rel is positive and overflows, and
        # where(mask, inf, 0) poisons the gradient with inf*0 = NaN.
        l_mask = jnp.exp(jnp.where(tri, rel, -jnp.inf))
        scores = jnp.einsum("bqhn,bkhn->bqkh", cc, bc) * l_mask
        y = jnp.einsum("bqkh,bkhp->bqhp", scores, xdt)
        # Contribution of the carried state.
        y = y + jnp.einsum("bqhn,bhpn,bqh->bqhp", cc, state, jnp.exp(cum))
        # New carried state.
        decay_end = jnp.exp(cum[:, -1:, :] - cum)          # (B,Q,H)
        new_state = jnp.einsum("bkhn,bkh,bkhp->bhpn", bc, decay_end, xdt)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + new_state
        return state, y

    final_state, ys = jax.lax.scan(step, init_state, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, nc * q, h, p)[:, :s]
    return y, final_state


def ssd_decode_step(x, dt, a, b_mat, c_mat, state):
    """One-token recurrence. x: (B,H,P); dt: (B,H); b/c: (B,G,N);
    state: (B,H,P,N). Returns (y (B,H,P), new_state)."""
    h = x.shape[1]
    bh = _expand_groups(b_mat[:, None], h)[:, 0]           # (B,H,N)
    ch = _expand_groups(c_mat[:, None], h)[:, 0]
    da = jnp.exp((dt * a).astype(jnp.float32))             # (B,H)
    xdt = (x * dt[..., None]).astype(jnp.float32)
    state = state * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xdt, bh.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", ch.astype(jnp.float32), state)
    return y, state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    di = d_inner_of(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * gn], axis=-1)
    return z, xbc, dt


def mamba_block(p, x, cfg, cache=None):
    """Mamba2 block, sequence mode (train / prefill).

    x: (B, S, d). Returns (y, new_cache or None)."""
    s = cfg.ssm
    bsz, seq, _ = x.shape
    di = d_inner_of(cfg)
    h = n_heads_of(cfg)
    gn = s.n_groups * s.d_state

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_init = (cache["conv"] if cache is not None else
                 jnp.zeros((bsz, s.conv_kernel - 1, xbc.shape[-1]), x.dtype))
    xbc, conv_state = _conv_scan(p["conv_w"], p["conv_b"], xbc, conv_init)
    xin, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
    xin = xin.reshape(bsz, seq, h, s.head_dim)
    bmat = bmat.reshape(bsz, seq, s.n_groups, s.d_state)
    cmat = cmat.reshape(bsz, seq, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    init_state = cache["state"] if cache is not None else None
    y, state = ssd_chunked(xin, dt, a, bmat, cmat, s.chunk, init_state)
    y = y + p["D"][None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(bsz, seq, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = ({"conv": conv_state, "state": state}
                 if cache is not None else None)
    return out, new_cache


def mamba_decode(p, x, cfg, cache):
    """Mamba2 block, single-token decode. x: (B, 1, d)."""
    s = cfg.ssm
    bsz = x.shape[0]
    di = d_inner_of(cfg)
    h = n_heads_of(cfg)
    gn = s.n_groups * s.d_state

    zxbcdt = x[:, 0] @ p["in_proj"]                        # (B, ·)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate(
        [cache["conv"].astype(x.dtype), xbc[:, None]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xin, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
    xin = xin.reshape(bsz, h, s.head_dim)
    bmat = bmat.reshape(bsz, s.n_groups, s.d_state)
    cmat = cmat.reshape(bsz, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    y, state = ssd_decode_step(xin.astype(jnp.float32), dt, a, bmat, cmat,
                               cache["state"])
    y = y + p["D"][None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(bsz, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": new_conv, "state": state}
