"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="Phi-3.5-MoE [hf:microsoft/Phi-3.5-MoE-instruct model card]",
    n_layers=32,
    d_model=4096,
    vocab=32_064,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    act="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400, router="softmax",
                  capacity_factor=1.25),
)
