"""Model configuration system.

One ``ModelConfig`` describes any architecture in the zoo (dense / MoE / SSM /
hybrid / VLM-backbone / audio enc-dec). Every assigned architecture gets a
module in this package exporting ``CONFIG``; the registry in ``__init__``
resolves ``--arch <id>``.

``reduced()`` returns the smoke-test variant mandated by the brief: <=2
layers, d_model <= 512, <= 4 experts, tiny vocab — same family and code paths.
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    n_shared_experts: int = 0
    shared_d_ff: int = 0           # hidden size of the shared expert(s)
    router: Literal["softmax", "sigmoid"] = "softmax"
    first_dense_layers: int = 0    # leading layers that use a dense FFN
    dense_d_ff: int = 0            # hidden size of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_rope_head_dim: int
    qk_nope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) dims."""
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128               # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    source: str                    # paper / model-card citation

    n_layers: int
    d_model: int
    vocab: int

    # attention (unused for pure SSM)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None      # window size for local layers
    layer_pattern: str | None = None       # e.g. "LLLLLG" repeated; None=all global
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl M-RoPE
    mla: MLAConfig | None = None

    # dense FFN
    d_ff: int = 0
    act: Literal["swiglu", "geglu"] = "swiglu"

    # MoE / SSM / hybrid
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid_period: int = 0          # hybrid: one shared attn block every N ssm blocks

    # encoder-decoder (audio)
    n_encoder_layers: int = 0

    # modality frontend stub
    input_mode: Literal["text", "patches", "frames"] = "text"
    frontend_dim: int = 0           # embedding dim delivered by the stub

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md shape-skip matrix)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense archs qualify only with a sliding-window layer pattern
        return self.sliding_window is not None and self.layer_pattern is not None

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def layer_kinds(self) -> list[str]:
        """Per-layer kind: 'G' global attn, 'L' local attn, 'M' mamba,
        'A' shared attn (hybrid), 'D' dense-ffn MoE exception handled
        separately by MoEConfig.first_dense_layers."""
        if self.family in ("ssm",):
            return ["M"] * self.n_layers
        if self.family == "hybrid":
            kinds = []
            for i in range(self.n_layers):
                kinds.append("A" if (i + 1) % (self.hybrid_period + 1) == 0
                             else "M")
            return kinds
        if self.layer_pattern:
            pat = self.layer_pattern
            return [pat[i % len(pat)] for i in range(self.n_layers)]
        return ["G"] * self.n_layers

    def param_count(self) -> int:
        """Analytic parameter count (total)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        return _param_count(self, active_only=True)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/code paths, tiny dims."""
        d_model = min(self.d_model, 256)
        head_dim = min(self.head_dim, 64) if self.head_dim else 0
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff=min(self.moe.d_ff, 384),
                shared_d_ff=min(self.moe.shared_d_ff, 384) if self.moe.shared_d_ff else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                dense_d_ff=min(self.moe.dense_d_ff, 512) if self.moe.dense_d_ff else 0,
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=64,
                            qk_rope_head_dim=16, qk_nope_head_dim=32,
                            v_head_dim=32)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=min(self.ssm.d_state, 16),
                                      head_dim=32, chunk=32)
        n_layers = min(self.n_layers, 2)
        if self.family == "hybrid":
            n_layers = 3  # 2 mamba + 1 shared attn exercises both paths
        pattern = self.layer_pattern
        if pattern:  # keep one local + one global layer
            pattern = "LG"
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-reduced",
            n_layers=n_layers,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            layer_pattern=pattern,
            moe=moe, mla=mla, ssm=ssm,
            hybrid_period=2 if self.family == "hybrid" else 0,
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            mrope_sections=(8, 12, 12) if self.mrope_sections else None,
            dtype="float32",
        )


def _ffn_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # gate, up, down


def _attn_params(cfg: ModelConfig) -> int:
    if cfg.mla is not None:
        m = cfg.mla
        d = cfg.d_model
        qk_head = m.qk_rope_head_dim + m.qk_nope_head_dim
        p = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk_head
        p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
        p += cfg.n_heads * m.v_head_dim * d
        return p
    hd = cfg.head_dim
    return (cfg.d_model * cfg.n_heads * hd          # q
            + 2 * cfg.d_model * cfg.n_kv_heads * hd  # k, v
            + cfg.n_heads * hd * cfg.d_model)        # o


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    p = cfg.d_model * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
    p += conv_dim * s.conv_kernel                     # depthwise conv
    p += n_heads * 2                                  # A_log, D
    p += d_inner                                      # gated norm
    p += d_inner * cfg.d_model                        # out_proj
    return p


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    total = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        total += 2 * cfg.d_model  # norms
        if kind == "M":
            total += _ssm_params(cfg)
            continue
        if kind == "A" and cfg.family == "hybrid":
            continue  # counted once below (shared params)
        total += _attn_params(cfg)
        if cfg.moe is not None:
            if i < cfg.moe.first_dense_layers:
                total += _ffn_params(cfg.d_model, cfg.moe.dense_d_ff)
            else:
                total += cfg.d_model * cfg.moe.n_experts  # router
                n_used = (cfg.moe.top_k if active_only else cfg.moe.n_experts)
                total += n_used * _ffn_params(cfg.d_model, cfg.moe.d_ff)
                if cfg.moe.n_shared_experts:
                    total += _ffn_params(cfg.d_model,
                                         cfg.moe.shared_d_ff or cfg.moe.d_ff)
        else:
            total += _ffn_params(cfg.d_model, cfg.d_ff)
    if cfg.family == "hybrid":  # one shared attention(+ffn) block
        total += _attn_params(cfg) + _ffn_params(cfg.d_model, cfg.d_ff)
    if cfg.is_encoder_decoder:
        # encoder layers: self-attn + ffn; decoder already counted has
        # cross-attn in addition
        total += cfg.n_encoder_layers * (
            _attn_params(cfg) + _ffn_params(cfg.d_model, cfg.d_ff)
            + 2 * cfg.d_model)
        total += len(kinds) * _attn_params(cfg)  # decoder cross-attn
    return total
