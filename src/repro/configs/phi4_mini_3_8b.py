"""phi4-mini-3.8b — RoPE SwiGLU GQA [arXiv:2412.08905]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi4-mini-3.8b",
    family="dense",
    source="Phi-4 [arXiv:2412.08905]",
    n_layers=32,
    d_model=3072,
    vocab=200_064,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
