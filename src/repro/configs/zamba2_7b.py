"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 blocks with a single SHARED transformer block (attention + FFN,
one parameter set) invoked periodically (every 6th position in our build).
Each invocation keeps its own KV cache.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    source="Zamba2 [arXiv:2411.15242]",
    n_layers=81,
    d_model=3584,
    vocab=32_000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,                 # 3584 / 32
    d_ff=14_336,
    act="swiglu",
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk=256, n_groups=1),
    hybrid_period=6,              # one shared attn block per 6 mamba blocks
)
