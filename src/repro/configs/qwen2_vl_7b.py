"""qwen2-vl-7b — M-RoPE, dynamic resolution [arXiv:2409.12191].

VLM: the vision encoder (ViT + merger) is a frontend STUB per the brief —
``input_specs`` delivers patch embeddings of shape (batch, seq, d_model);
this config is the language/decoder backbone that consumes them.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-7b",
    family="vlm",
    source="Qwen2-VL [arXiv:2409.12191]",
    n_layers=28,
    d_model=3584,
    vocab=152_064,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    act="swiglu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # temporal / height / width of head_dim/2
    input_mode="patches",
    frontend_dim=3584,
)
