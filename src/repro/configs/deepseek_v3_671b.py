"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8 [arXiv:2412.19437].

The primary Aurora target: 256-way expert parallelism with scheduled
all-to-all dispatch. First 3 layers dense (d_ff 18432); sigmoid router.
(The optional MTP head is exposed via training config, not counted here.)
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    source="DeepSeek-V3 [arXiv:2412.19437]",
    n_layers=61,
    d_model=7168,
    vocab=129_280,
    n_heads=128,
    n_kv_heads=128,               # MLA: kv heads == heads over the latent
    head_dim=128,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_rope_head_dim=64, qk_nope_head_dim=128,
                  v_head_dim=128),
    act="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048,
                  n_shared_experts=1, shared_d_ff=2048,
                  router="sigmoid", first_dense_layers=3,
                  dense_d_ff=18_432, capacity_factor=1.25),
)
