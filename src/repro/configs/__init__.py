"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""

from __future__ import annotations

from .base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from .shapes import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                     InputShape)

from . import (deepseek_v3_671b, gemma3_27b, gemma_7b, mamba2_1_3b,
               phi3_5_moe_42b, phi4_mini_3_8b, qwen2_vl_7b, qwen3_32b,
               seamless_m4t_large_v2, zamba2_7b)

_MODULES = (mamba2_1_3b, gemma_7b, qwen2_vl_7b, qwen3_32b, deepseek_v3_671b,
            gemma3_27b, seamless_m4t_large_v2, phi4_mini_3_8b, zamba2_7b,
            phi3_5_moe_42b)

REGISTRY: dict[str, ModelConfig] = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}
ARCH_IDS: tuple[str, ...] = tuple(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "InputShape",
           "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "REGISTRY", "ARCH_IDS", "get_config"]
