"""qwen3-32b — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-32b",
    family="dense",
    source="Qwen3 [hf:Qwen/Qwen3-8B model card]",
    n_layers=64,
    d_model=5120,
    vocab=151_936,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    d_ff=25_600,
    act="swiglu",
    rope_theta=1_000_000.0,
)
