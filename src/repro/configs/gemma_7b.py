"""gemma-7b — GeGLU, head_dim=256 [arXiv:2403.08295]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-7b",
    family="dense",
    source="Gemma [arXiv:2403.08295]",
    n_layers=28,
    d_model=3072,
    vocab=256_000,
    n_heads=16,
    n_kv_heads=16,                # MQA only on the 2b variant
    head_dim=256,
    d_ff=24_576,
    act="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
