"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596].

Audio: the mel-spectrogram + conformer feature frontend is a STUB per the
brief — ``input_specs`` delivers frame embeddings (batch, frames, d_model);
this config is the transformer encoder-decoder backbone.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    source="SeamlessM4T [arXiv:2308.11596]",
    n_layers=24,                  # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    vocab=256_206,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    act="swiglu",
    rope_theta=10_000.0,
    input_mode="frames",
    frontend_dim=1024,
)
