"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060]."""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    source="SSD / Mamba-2 [arXiv:2405.21060]",
    n_layers=48,
    d_model=2048,
    vocab=50_280,
    d_ff=0,                       # attention-free, FFN-free backbone
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk=256, n_groups=1),
    tie_embeddings=True,          # GPT-NeoX tokenizer family ties in 1.3b
    norm_eps=1e-5,
)
