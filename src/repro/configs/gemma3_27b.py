"""gemma3-27b — 5:1 local:global attention, 128k ctx [hf:google/gemma-3 family].

Five sliding-window (1024) layers per global layer; the sliding-window
pattern makes this the one dense arch eligible for long_500k decode
(see DESIGN.md shape-skip matrix).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-27b",
    family="dense",
    source="Gemma 3 [hf:google/gemma-3-1b-pt model card]",
    n_layers=62,
    d_model=5376,
    vocab=262_144,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    qk_norm=True,
    d_ff=21_504,
    act="geglu",
    rope_theta=1_000_000.0,
    sliding_window=1024,
    layer_pattern="LLLLLG",
    tie_embeddings=True,
)
