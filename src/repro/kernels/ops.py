"""Jit'd wrappers over the Pallas kernels with automatic fallback.

``interpret`` selects the execution mode everywhere:
- On TPU: compiled Pallas (the production path).
- On CPU (this container): ``interpret=True`` executes the kernel body in
  Python for correctness validation; ``interpret=None`` (auto) keeps the
  pure-jnp reference so serving and tests stay fast.

The ``*_auto`` entry points additionally derive legal block shapes from the
runtime array shapes (capacity buckets and cache lengths are workload-sized,
not kernel-sized), so the model layer never has to know the grid rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .decode_attn import decode_attn
from .moe_gmm import moe_gmm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas(interpret: bool | None = None) -> bool:
    """Whether the kernel path executes a Pallas body (compiled or
    interpret) as opposed to the pure-jnp reference."""
    return on_tpu() or bool(interpret)


def _divisor_block(n: int, block: int) -> int:
    """Largest block size <= ``block`` that divides ``n`` exactly."""
    b = max(min(block, n), 1)
    while n % b:
        b -= 1
    return b


def moe_ffn(x, w_gate, w_up, w_down, act: str = "swiglu",
            impl: str = "auto", interpret: bool | None = None,
            group_sizes=None, block_c: int = 128, block_f: int = 128):
    """Grouped expert FFN: Pallas on TPU, reference elsewhere.

    ``group_sizes`` (E,) enables the ragged path: expert blocks past the
    fill level are skipped on the kernel and zero-masked on the reference —
    identical semantics (zero-padded buckets, FFN(0) == 0).
    """
    if impl == "ref" or (impl == "auto" and not use_pallas(interpret)):
        return ref.moe_ffn_ref(x, w_gate, w_up, w_down, act,
                               group_sizes=group_sizes)
    return moe_gmm(x, w_gate, w_up, w_down, act=act,
                   group_sizes=group_sizes,
                   block_c=_divisor_block(x.shape[1], block_c),
                   block_f=_divisor_block(w_gate.shape[-1], block_f),
                   interpret=bool(interpret) if interpret is not None
                   else not on_tpu())


def decode_attn_auto(q, k, v, valid_len, block_s: int = 512,
                     interpret: bool | None = None):
    """Decode-step attention over a per-slot cache, impl auto-selected.

    q: (B, H, D); k/v: (B, S, Hkv, D); valid_len scalar or (B,) fill levels
    (broadcast to every batch row). Picks the largest KV block that divides
    the cache capacity, so workload-sized caches never trip the grid rules.
    """
    b = q.shape[0]
    valid_len = jnp.broadcast_to(
        jnp.asarray(valid_len, jnp.int32).reshape(-1), (b,))
    if not use_pallas(interpret):
        return ref.decode_attn_ref(q, k, v, valid_len)
    return decode_attn(q, k, v, valid_len,
                       block_s=_divisor_block(k.shape[1], block_s),
                       interpret=bool(interpret) if interpret is not None
                       else not on_tpu())
