"""Jit'd wrappers over the Pallas kernels with automatic fallback.

``use_pallas(interpret=...)`` selects the execution mode:
- On TPU: compiled Pallas (the production path).
- On CPU (this container): ``interpret=True`` executes the kernel body in
  Python for correctness validation; the model default remains the pure-jnp
  reference so tests stay fast.
"""

from __future__ import annotations

import jax

from . import ref
from .decode_attn import decode_attn
from .moe_gmm import moe_gmm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def moe_ffn(x, w_gate, w_up, w_down, act: str = "swiglu",
            impl: str = "auto", interpret: bool | None = None):
    """Grouped expert FFN: Pallas on TPU, reference elsewhere."""
    if impl == "ref" or (impl == "auto" and not on_tpu() and not interpret):
        return ref.moe_ffn_ref(x, w_gate, w_up, w_down, act)
    return moe_gmm(x, w_gate, w_up, w_down, act=act,
                   interpret=bool(interpret) if interpret is not None
                   else not on_tpu())


def flash_decode(q, k, v, valid_len, impl: str = "auto",
                 interpret: bool | None = None):
    """Single-query attention: Pallas on TPU, reference elsewhere."""
    if impl == "ref" or (impl == "auto" and not on_tpu() and not interpret):
        return ref.decode_attn_ref(q, k, v, valid_len)
    return decode_attn(q, k, v, valid_len,
                       interpret=bool(interpret) if interpret is not None
                       else not on_tpu())
