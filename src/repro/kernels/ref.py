"""Pure-jnp oracles for the Pallas kernels (tested via assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn_ref(x, w_gate, w_up, w_down, act: str = "swiglu",
                group_sizes=None):
    """Grouped expert FFN over capacity buckets.

    x: (E, C, d); w_gate/w_up: (E, d, f); w_down: (E, f, d) → (E, C, d).
    ``group_sizes``: optional (E,) real-row counts — rows at or beyond a
    group's fill level are zeroed, matching the ragged kernel's block-skip
    semantics exactly (pad rows are zero inputs, and FFN(0) == 0).
    """
    act_fn = jax.nn.gelu if act == "geglu" else jax.nn.silu
    h = act_fn(jnp.einsum("ecd,edf->ecf", x, w_gate,
                          preferred_element_type=jnp.float32))
    h = h * jnp.einsum("ecd,edf->ecf", x, w_up,
                       preferred_element_type=jnp.float32)
    y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), w_down,
                   preferred_element_type=jnp.float32)
    if group_sizes is not None:
        live = jnp.arange(x.shape[1])[None, :] < group_sizes[:, None]
        y = jnp.where(live[..., None], y, 0.0)
    return y.astype(x.dtype)


def decode_attn_ref(q, k, v, valid_len):
    """Single-query GQA flash-decode oracle.

    q: (B, H, D); k/v: (B, S, Hkv, D); valid_len: (B,) int32 → (B, H, D).
    """
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * d ** -0.5
    mask = jnp.arange(s)[None] < valid_len[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)
