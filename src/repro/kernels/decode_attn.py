"""Pallas TPU kernel: single-query flash-decode attention.

The decode shapes (``decode_32k``, ``long_500k``) are dominated by streaming
the KV cache past one query token — a pure memory-bandwidth problem. The
kernel tiles the cache into (block_s, Hkv, D) VMEM blocks and maintains an
online-softmax running (max, sum, accumulator) across sequence blocks, so
the (S)-long score row is never materialized in HBM and each cache byte is
read exactly once.

TPU mapping: grid (B, S/block_s) with the sequence axis innermost
(arbitrary = sequential accumulation). GQA is handled in-block: q is viewed
as (Hkv, G, D) and scores are computed per kv-head group. ``valid_len``
masks cache slots beyond the fill level (per batch row).

Validated against ``ref.decode_attn_ref`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_NEG_INF = -1e30


def _kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, n_s: int, scale: float):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (H, D)
    k = k_ref[0]                                    # (bs, Hkv, D)
    v = v_ref[0]
    h, d = q.shape
    bs, hkv, _ = k.shape
    g = h // hkv

    qg = q.reshape(hkv, g, d)
    scores = jax.lax.dot_general(
        qg.astype(jnp.float32), k.astype(jnp.float32).transpose(1, 2, 0),
        (((2,), (1,)), ((0,), (0,))),
    ) * scale                                        # (hkv, g, bs)
    scores = scores.reshape(h, bs)

    valid = (s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (h, bs), 1)) < vl_ref[0]
    scores = jnp.where(valid, scores, _NEG_INF)

    m_prev = m_ref[...]                              # (H, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)                      # (H, bs)
    corr = jnp.exp(m_prev - m_new)                   # (H, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    pg = p.reshape(hkv, g, bs)
    ctx = jax.lax.dot_general(
        pg, v.astype(jnp.float32).transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
    )                                                # (hkv, g, d)
    acc_ref[...] = acc_ref[...] * corr[:, :, None].reshape(h, 1) + \
        ctx.reshape(h, d)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attn(q, k, v, valid_len, *, block_s: int = 512,
                interpret: bool = False):
    """Flash-decode. q: (B, H, D); k/v: (B, S, Hkv, D); valid_len: (B,)."""
    b, h, d = q.shape
    s = k.shape[1]
    bs = min(block_s, s)
    if s % bs:
        raise ValueError(f"S={s} not divisible by block_s={bs}")
    n_s = s // bs
    grid = (b, n_s)
    return pl.pallas_call(
        functools.partial(_kernel, block_s=bs, n_s=n_s, scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, s_: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, h, d), lambda b_, s_: (b_, 0, 0)),
            pl.BlockSpec((1, bs, k.shape[2], d), lambda b_, s_: (b_, s_, 0, 0)),
            pl.BlockSpec((1, bs, k.shape[2], d), lambda b_, s_: (b_, s_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, s_: (b_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),     # running max
            pltpu.VMEM((h, 1), jnp.float32),     # running sum
            pltpu.VMEM((h, d), jnp.float32),     # context accumulator
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(valid_len, q, k, v)
