"""Pallas TPU kernel: grouped expert FFN (the MoE compute hot-spot).

Computes, per expert e over its capacity bucket:

    y[e] = (act(x[e] @ w_gate[e]) * (x[e] @ w_up[e])) @ w_down[e]

in one fused kernel — the (E, C, d) dispatch buffer produced by the
all-to-all is consumed directly, so the gate/up/down matmuls and the
activation never round-trip through HBM between them.

TPU mapping: grid (E, C/bc, F/bf) with the f-axis innermost as a reduction —
each (e, c) output block accumulates partial ``h_blk @ w_down_blk`` products
across f-steps in a float32 VMEM scratch accumulator, flushing to the output
on the last step. Block shapes keep the working set in VMEM
(x (bc,d) + w (d,bf)·2 + w_down (bf,d) + acc (bc,d)f32 ≈ 11 MB at
bc=bf=128, d=7168) and all matmul dims are multiples of 128 for the MXU.

Validated against ``ref.moe_ffn_ref`` in interpret mode (this container is
CPU-only; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, act: str,
            n_f: int):
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, d)
    hg = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    hu = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    act_fn = jax.nn.gelu if act == "geglu" else jax.nn.silu
    h = (act_fn(hg) * hu).astype(x.dtype)          # (bc, bf)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f_idx == n_f - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "interpret"))
def moe_gmm(x, w_gate, w_up, w_down, *, act: str = "swiglu",
            block_c: int = 128, block_f: int = 128,
            interpret: bool = False):
    """Fused grouped expert FFN.

    x: (E, C, d); w_gate/w_up: (E, d, f); w_down: (E, f, d) → (E, C, d).
    C and f must be divisible by the block sizes (the dispatch path pads
    capacity to multiples of 8·block granularity already).
    """
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bc = min(block_c, c)
    bf = min(block_f, f)
    if c % bc or f % bf:
        raise ValueError(f"C={c} / F={f} not divisible by blocks {bc}/{bf}")
    n_f = f // bf
    grid = (e, c // bc, n_f)
    return pl.pallas_call(
        functools.partial(_kernel, act=act, n_f=n_f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, c_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
