"""Pallas TPU kernel: grouped expert FFN (the MoE compute hot-spot).

Computes, per expert e over its capacity bucket:

    y[e] = (act(x[e] @ w_gate[e]) * (x[e] @ w_up[e])) @ w_down[e]

in one fused kernel — the (E, C, d) dispatch buffer produced by the
sort-based ragged dispatch (or the all-to-all) is consumed directly, so the
gate/up/down matmuls and the activation never round-trip through HBM between
them.

TPU mapping: grid (E, C/bc, F/bf) with the f-axis innermost as a reduction —
each (e, c) output block accumulates partial ``h_blk @ w_down_blk`` products
across f-steps in a float32 VMEM scratch accumulator, flushing to the output
on the last step. Block shapes keep the working set in VMEM
(x (bc,d) + w (d,bf)·2 + w_down (bf,d) + acc (bc,d)f32 ≈ 11 MB at
bc=bf=128, d=7168) and all matmul dims are multiples of 128 for the MXU.

**Ragged groups** (``group_sizes``): the serving dispatch path routes only a
handful of real tokens per step, so most capacity rows are zero padding. A
per-expert row count rides in SMEM (like ``decode_attn``'s ``valid_len``)
and every (e, c)-block whose row range starts at or beyond its group's fill
level skips all three matmuls — the MegaBlocks-style dropless-group idea at
block granularity. Skipped blocks flush the zero accumulator, which equals
the dense result exactly: padding rows are zero and FFN(0) == 0.

Validated against ``ref.moe_ffn_ref`` in interpret mode (this container is
CPU-only; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def align_capacity(cap: int, block_c: int) -> int:
    """Smallest padded capacity the kernel grid can tile with ``block_c``.

    ``capacity()`` rounds to a multiple of 8, which need not divide into
    ``block_c`` blocks (e.g. cap=136 with block_c=128). A bucket that fits in
    one block is its own (shrunk) block; anything larger is padded up to a
    whole number of blocks. The extra rows are zero padding that the ragged
    ``group_sizes`` path skips entirely.
    """
    if cap <= block_c:
        return cap
    return -(-cap // block_c) * block_c


def _kernel(gs_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
            act: str, n_f: int, block_c: int):
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block (e, c) holds bucket rows [c*bc, (c+1)*bc); with fewer than
    # c*bc + 1 routed rows the whole block is zero padding — skip the MXU
    # work. (Partially-filled blocks still run; their pad rows are zero
    # inputs, and FFN(0) == 0 keeps the output exact.)
    live = gs_ref[0] > pl.program_id(1) * block_c

    @pl.when(live)
    def _compute():
        x = x_ref[0]                               # (bc, d)
        hg = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        hu = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
        act_fn = jax.nn.gelu if act == "geglu" else jax.nn.silu
        h = (act_fn(hg) * hu).astype(x.dtype)      # (bc, bf)
        acc_ref[...] += jnp.dot(h, wd_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(f_idx == n_f - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "interpret"))
def moe_gmm(x, w_gate, w_up, w_down, *, group_sizes=None, act: str = "swiglu",
            block_c: int = 128, block_f: int = 128,
            interpret: bool = False):
    """Fused grouped expert FFN.

    x: (E, C, d); w_gate/w_up: (E, d, f); w_down: (E, f, d) → (E, C, d).
    C and f must be divisible by the block sizes (``align_capacity`` gives a
    compliant C; ``ops.moe_ffn`` derives a legal f block).

    ``group_sizes``: optional (E,) int32 count of real rows per bucket —
    blocks past a group's fill level are skipped (flushed as zeros). None
    runs every block (the dense all-to-all layout).
    """
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bc = min(block_c, c)
    bf = min(block_f, f)
    if c % bc or f % bf:
        raise ValueError(f"C={c} / F={f} not divisible by blocks {bc}/{bf}")
    if group_sizes is None:
        group_sizes = jnp.full((e,), c, jnp.int32)
    group_sizes = group_sizes.astype(jnp.int32)
    n_f = f // bf
    grid = (e, c // bc, n_f)
    return pl.pallas_call(
        functools.partial(_kernel, act=act, n_f=n_f, block_c=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda e_, c_, f_: (e_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, c_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(group_sizes, x, w_gate, w_up, w_down)
