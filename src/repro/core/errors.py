"""Typed exceptions for planning and fault handling.

Two families:

``PlanError``
    A plan, pairing, schedule, or adoption request is malformed or cannot
    be applied to the engine's live state (bad permutation, wrong tenant
    count, EP-indivisible replication, ...). Subclasses ``ValueError`` so
    pre-existing ``except ValueError`` call sites — and tests asserting
    ``pytest.raises(ValueError)`` — keep working.

``FaultError``
    A fault-handling operation cannot proceed: an injected fault targets a
    device/expert that does not exist, failover would lose the last copy of
    an expert's weights, or a degraded re-plan is impossible on the
    surviving devices. Subclasses ``RuntimeError`` — these are runtime
    conditions, not argument validation.
"""

from __future__ import annotations

__all__ = ["PlanError", "FaultError"]


class PlanError(ValueError):
    """A plan/pairing/schedule is invalid or cannot be adopted as-is."""


class FaultError(RuntimeError):
    """A fault-injection or failover operation cannot proceed."""
