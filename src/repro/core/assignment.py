"""GPU assignment for heterogeneous clusters (§5, Thm 5.1).

Sort experts by token load (tokens processed = received traffic) in
descending order; assign to devices from highest to lowest performance.
The baseline is random GPU assignment (RGA, §8.1).
"""

from __future__ import annotations

import numpy as np

from .cluster import Cluster
from .traffic import strip_diagonal


def expert_loads(d: np.ndarray) -> np.ndarray:
    """Tokens each expert processes = column sums of the dispatch matrix
    (tokens routed *to* that expert, excluding free self-traffic)."""
    return strip_diagonal(d).sum(axis=0)


def aurora_assignment(d: np.ndarray, cluster: Cluster) -> np.ndarray:
    """Thm 5.1: experts sorted by load desc → devices sorted by perf desc.

    Returns ``expert_to_device`` with entry e = device index hosting expert e.
    """
    loads = expert_loads(d)
    n = len(loads)
    if cluster.n != n:
        raise ValueError(f"cluster has {cluster.n} devices for {n} experts")
    experts_by_load = np.argsort(-loads, kind="stable")
    devices_by_perf = cluster.sorted_indices_by_performance()
    e2d = np.empty(n, dtype=np.int64)
    for rank, e in enumerate(experts_by_load):
        e2d[e] = devices_by_perf[rank]
    return e2d


def random_assignment(n: int, seed: int = 0) -> np.ndarray:
    """RGA baseline."""
    rng = np.random.default_rng(seed)
    return rng.permutation(n)


def apply_assignment(d: np.ndarray, expert_to_device: np.ndarray) -> np.ndarray:
    """Permute an expert-indexed traffic matrix into device space.

    Traffic from (the device hosting) expert i to (the device hosting)
    expert j becomes device-level traffic e2d[i] -> e2d[j].
    """
    d = np.asarray(d, dtype=np.float64)
    e2d = np.asarray(expert_to_device)
    out = np.zeros_like(d)
    out[np.ix_(e2d, e2d)] = d
    return out
