"""Bipartite matching primitives used throughout Aurora.

- Hopcroft–Karp maximum matching (O(E*sqrt(V))), used both by the BvN
  decomposition in ``schedule.py`` (perfect matchings on positive-entry
  graphs) and by the bottleneck matching solver.
- Bottleneck perfect matching (§6.2 Case II): binary search on the sorted
  edge weights for the smallest threshold admitting a perfect matching,
  overall O(n^2 * sqrt(n) * log n) exactly as the paper describes.
"""

from __future__ import annotations

import numpy as np

_INF = float("inf")


def hopcroft_karp(adj: list[list[int]], n_left: int, n_right: int) -> tuple[int, list[int]]:
    """Maximum bipartite matching.

    ``adj[u]`` lists right-side neighbours of left node ``u``.
    Returns (matching size, match_left) where ``match_left[u]`` is the right
    node matched to ``u`` or -1.
    """
    match_l = [-1] * n_left
    match_r = [-1] * n_right
    size = 0
    while True:
        # BFS: layer the graph from free left vertices.
        dist = [_INF] * n_left
        queue = [u for u in range(n_left) if match_l[u] == -1]
        for u in queue:
            dist[u] = 0
        found_free = False
        qi = 0
        while qi < len(queue):
            u = queue[qi]
            qi += 1
            for v in adj[u]:
                w = match_r[v]
                if w == -1:
                    found_free = True
                elif dist[w] == _INF:
                    dist[w] = dist[u] + 1
                    queue.append(w)
        if not found_free:
            break

        # DFS augmentation along layered paths (iterative to dodge recursion
        # limits on large graphs).
        iters = [0] * n_left

        def try_augment(root: int) -> bool:
            stack = [root]
            path: list[tuple[int, int]] = []  # (left, right) tentative edges
            while stack:
                u = stack[-1]
                advanced = False
                while iters[u] < len(adj[u]):
                    v = adj[u][iters[u]]
                    iters[u] += 1
                    w = match_r[v]
                    if w == -1:
                        # Augment along the path.
                        path.append((u, v))
                        for pu, pv in path:
                            match_l[pu] = pv
                            match_r[pv] = pu
                        return True
                    if dist[w] == dist[u] + 1:
                        path.append((u, v))
                        stack.append(w)
                        advanced = True
                        break
                if not advanced:
                    dist[u] = _INF
                    stack.pop()
                    if path:
                        path.pop()
            return False

        progressed = 0
        for u in range(n_left):
            if match_l[u] == -1 and try_augment(u):
                progressed += 1
        if progressed == 0:
            break
        size += progressed
    return size, match_l


def has_perfect_matching(allowed: np.ndarray) -> bool:
    n = allowed.shape[0]
    adj = [np.flatnonzero(allowed[u]).tolist() for u in range(n)]
    size, _ = hopcroft_karp(adj, n, n)
    return size == n


def perfect_matching(allowed: np.ndarray) -> list[int] | None:
    """Perfect matching on an n x n boolean adjacency, or None."""
    n = allowed.shape[0]
    adj = [np.flatnonzero(allowed[u]).tolist() for u in range(n)]
    size, match_l = hopcroft_karp(adj, n, n)
    return match_l if size == n else None


def bottleneck_perfect_matching(weights: np.ndarray) -> tuple[list[int], float]:
    """Perfect matching minimizing the maximum edge weight (§6.2 Case II).

    ``weights`` is a full n x n matrix (complete bipartite graph). Returns
    (match, w*) with ``match[i]`` = right node paired with left node ``i``.
    Binary search over the sorted distinct weights; feasibility by
    Hopcroft–Karp on the thresholded subgraph.
    """
    w = np.asarray(weights, dtype=np.float64)
    n = w.shape[0]
    if w.shape != (n, n):
        raise ValueError(f"weights must be square, got {w.shape}")
    uniq = np.unique(w)
    lo, hi = 0, len(uniq) - 1
    # The complete graph always has a perfect matching at the max weight.
    best = uniq[hi]
    while lo <= hi:
        mid = (lo + hi) // 2
        if has_perfect_matching(w <= uniq[mid]):
            best = uniq[mid]
            hi = mid - 1
        else:
            lo = mid + 1
    match = perfect_matching(w <= best)
    assert match is not None
    return match, float(best)
