"""All-to-all communication scheduling (§4.2, Thm 4.2 / Thm 5.2).

Aurora's schedule is the constructive object behind Thm 4.2: augment the
traffic(-time) matrix to equal row/col sums ``b_max`` (the artificial matrix X
whose existence Farkas' lemma guarantees; we construct it directly with a
transportation-style greedy fill), then peel permutation matrices off the
augmented matrix — a Birkhoff–von-Neumann decomposition. Every slot is a
permutation, so no receiver ever hears from two senders at once (the paper's
contention-free invariant) and the total schedule length is exactly ``b_max``.

Baselines: SJF (each sender transmits its flows shortest-first) and RCS
(random order), evaluated under a max-min-fair fluid model of the big-switch
network where receiver bandwidth is shared between concurrent incoming flows
(this reproduces Fig 4's 3-units-vs-2-units example).

Heterogeneous clusters (Thm 5.2): entries are normalized to *time* by the
effective pair bandwidth ``min(B_i, B_j)`` (Appx. B) and the same machinery
applies; ``b_max`` becomes the maximum per-GPU send/receive *time*.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .errors import PlanError
from .traffic import strip_diagonal, validate_traffic

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Slot:
    """One time slot of the schedule: a (partial) permutation.

    ``dst[i]`` is the destination device for sender ``i`` (-1 = idle, i.e.
    this sender only carried artificial traffic in this slot).
    ``duration`` is in time units (traffic units / bandwidth).
    """

    dst: tuple[int, ...]
    duration: float


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """A full contention-free schedule for one all-to-all phase."""

    slots: tuple[Slot, ...]
    b_max: float

    @property
    def total_time(self) -> float:
        return sum(s.duration for s in self.slots)

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def sender_orders(self) -> list[list[tuple[int, float]]]:
        """Per-sender (destination, duration) sequences — the paper's
        "token transmission order" view of the schedule."""
        n = len(self.slots[0].dst) if self.slots else 0
        orders: list[list[tuple[int, float]]] = [[] for _ in range(n)]
        for s in self.slots:
            for i, j in enumerate(s.dst):
                if j >= 0:
                    orders[i].append((j, s.duration))
        return orders

    def permutations(self) -> list[tuple[tuple[int, ...], float]]:
        """(dst-array, duration) pairs — consumed by the TPU ppermute lowering
        in ``repro.distributed.alltoall``."""
        return [(s.dst, s.duration) for s in self.slots]

    def traffic(self, n: int | None = None) -> np.ndarray:
        """Realized (time-unit) traffic matrix: per-pair sum of slot durations.

        The inverse view of ``aurora_schedule``: summing what each slot moves
        recovers (up to artificial-padding idle time) the matrix the schedule
        was decomposed from. Used to re-derive device-level BvN rounds from a
        planner ``Plan`` whose schedules live at expert granularity."""
        if n is None:
            n = len(self.slots[0].dst) if self.slots else 0
        d = np.zeros((n, n), dtype=np.float64)
        for slot in self.slots:
            for i, j in enumerate(slot.dst):
                if j >= 0:
                    d[i, j] += slot.duration
        return d


def check_partial_permutation(dst, n: int, what: str) -> tuple[int, ...]:
    """One dst vector must be a *partial permutation* of ``n`` devices.

    The shared invariant of every ppermute lowering input — schedule slots
    AND literal exchange rounds: ``dst[i]`` is sender i's receiver (-1 =
    idle), no receiver hears two senders, nobody sends to itself
    (self-traffic never crosses the network, §4.2 footnote 1), nothing
    points off the mesh. Violations silently drop or overwrite token
    buckets in flight, so they raise ``PlanError`` here instead. Returns the
    normalized tuple."""
    dst = tuple(int(j) for j in dst)
    if len(dst) != n:
        raise PlanError(f"{what}: dst has {len(dst)} entries for {n} "
                        "devices")
    seen_recv: set[int] = set()
    for i, j in enumerate(dst):
        if j < 0:
            continue  # idle sender (artificial traffic only)
        if j >= n:
            raise PlanError(f"{what}: sender {i} targets device {j} "
                            f"(out of range for {n} devices)")
        if j == i:
            raise PlanError(
                f"{what}: self-send {i}->{i} — self-traffic never crosses "
                "the network (§4.2 footnote 1) and must be marked idle (-1)")
        if j in seen_recv:
            raise PlanError(
                f"{what}: receiver {j} is targeted by two senders — not a "
                "(partial) permutation; lowering it to ppermute would "
                "silently misroute one bucket")
        seen_recv.add(j)
    return dst


def validate_permutation_slots(slots, n: int) -> None:
    """Explicit error for non-permutation slots instead of silent misrouting.

    ``aurora_schedule`` only emits valid slots; hand-built or corrupted
    schedules fail loudly (``PlanError``) here before the ppermute lowering
    trusts them.
    """
    if n <= 0:
        raise PlanError(f"schedule needs a positive device count, got {n}")
    for s_i, slot in enumerate(slots):
        check_partial_permutation(slot.dst, n, f"slot {s_i}")


def time_matrix(d: np.ndarray, bandwidths: np.ndarray | None = None) -> np.ndarray:
    """Traffic → time units. Pair (i, j) moves at ``min(B_i, B_j)`` (Appx. B)."""
    d = strip_diagonal(d)
    n = d.shape[0]
    if bandwidths is None:
        return d
    b = np.asarray(bandwidths, dtype=np.float64)
    if b.shape != (n,):
        raise ValueError("bandwidths must have one entry per device")
    pair_bw = np.minimum(b[:, None], b[None, :])
    return d / pair_bw


def b_max_of(d: np.ndarray, bandwidths: np.ndarray | None = None) -> float:
    t = time_matrix(d, bandwidths)
    return float(max(t.sum(axis=1).max(initial=0.0), t.sum(axis=0).max(initial=0.0)))


def augment_to_bmax(t: np.ndarray) -> tuple[np.ndarray, float]:
    """Construct D' = D + X with every row/col sum equal to b_max (Appx. A step 1).

    Farkas' lemma proves a non-negative X exists; we build one constructively
    with a northwest-corner-style fill over the row/col deficits (total row
    deficit equals total column deficit, so the fill always completes).
    Artificial traffic may sit on the diagonal — in the final schedule those
    entries are simply idle slots for that sender.
    """
    t = validate_traffic(t)
    n = t.shape[0]
    rows = t.sum(axis=1)
    cols = t.sum(axis=0)
    b_max = float(max(rows.max(initial=0.0), cols.max(initial=0.0)))
    r_def = b_max - rows
    c_def = b_max - cols
    x = np.zeros_like(t)
    i = j = 0
    while i < n and j < n:
        if r_def[i] <= _EPS:
            i += 1
            continue
        if c_def[j] <= _EPS:
            j += 1
            continue
        add = min(r_def[i], c_def[j])
        x[i, j] += add
        r_def[i] -= add
        c_def[j] -= add
    d_prime = t + x
    return d_prime, b_max


def aurora_schedule(
    d: np.ndarray, bandwidths: np.ndarray | None = None
) -> CommSchedule:
    """Thm 4.2 / 5.2 constructive schedule via BvN decomposition.

    Returns a schedule of at most n^2 - 2n + 2 permutation slots whose total
    duration is exactly ``b_max`` and in which no two senders ever target the
    same receiver simultaneously.
    """
    from .matching import perfect_matching

    t = time_matrix(d, bandwidths)
    n = t.shape[0]
    # Clean negligible entries BEFORE augmenting: a crumb of ~1e-9·b_max has
    # no matching partner once the big entries are peeled off (it breaks
    # Hall's condition on the positive mask) yet changes the schedule length
    # by nothing. Cleaning first keeps the augmented matrix exactly
    # doubly-balanced, which is what the BvN peeling relies on.
    pre = float(max(t.sum(axis=1).max(initial=0.0),
                    t.sum(axis=0).max(initial=0.0)))
    if pre <= _EPS:
        return CommSchedule(slots=(), b_max=0.0)
    t = np.where(t > 1e-9 * pre, t, 0.0)
    real = t > 0.0  # which (i, j) carry real traffic
    d_prime, b_max = augment_to_bmax(t)
    if b_max <= _EPS:
        return CommSchedule(slots=(), b_max=0.0)

    slots: list[Slot] = []
    remaining = d_prime.copy()
    tol = 1e-12 * b_max  # subtraction round-off, far below any real entry
    # Each iteration zeroes at least one positive entry; entries never
    # increase, so this terminates in <= n^2 iterations.
    for _ in range(n * n + 1):
        remaining[remaining <= tol] = 0.0
        if remaining.sum() <= tol * n * n:
            break
        positive = remaining > 0.0
        match = perfect_matching(positive)
        if match is None:
            # Numerically degenerate remainder (should not happen after the
            # input cleaning): schedule leftover entries one pair per slot.
            # Costs at most the leftover mass, which is O(n²·tol).
            for i, j in zip(*np.nonzero(positive)):
                dst = [-1] * n
                dst[i] = int(j)
                if real[i, j] and i != j:
                    slots.append(Slot(dst=tuple(dst),
                                      duration=float(remaining[i, j])))
                remaining[i, j] = 0.0
            break
        delta = float(min(remaining[i, match[i]] for i in range(n)))
        dst = []
        for i in range(n):
            j = match[i]
            remaining[i, j] -= delta
            # Idle if this edge was purely artificial or a diagonal self-edge.
            dst.append(j if (real[i, j] and i != j) else -1)
        slots.append(Slot(dst=tuple(dst), duration=delta))
    else:
        raise RuntimeError("BvN decomposition did not terminate")

    # Drop slots where every sender is idle (pure artificial traffic).
    slots = [s for s in slots if any(j >= 0 for j in s.dst)]
    # Merge adjacent slots with identical destination patterns (beyond-paper
    # cleanup: fewer rounds for the ppermute lowering, same total time).
    merged: list[Slot] = []
    for s in slots:
        if merged and merged[-1].dst == s.dst:
            merged[-1] = Slot(dst=s.dst, duration=merged[-1].duration + s.duration)
        else:
            merged.append(s)
    return CommSchedule(slots=tuple(merged), b_max=b_max)


def algorithm1_order(
    d: np.ndarray, bandwidths: np.ndarray | None = None, seed: int = 0
) -> list[list[tuple[int, float]]]:
    """Alg. 1 (paper's greedy sketch): per-sender destination orders.

    Identify the bottleneck GPU, give it a random continuous order, then
    arrange remaining senders (descending traffic) around the existing
    commitments. We realize "avoid conflicts" by simulating slot occupancy.
    This is the paper's heuristic; ``aurora_schedule`` is the constructive
    optimum that the proof of Thm 4.2 actually builds, and is what the
    planner uses. Exposed for completeness and comparison.
    """
    sched = aurora_schedule(d, bandwidths)
    return sched.sender_orders()


# ---------------------------------------------------------------------------
# Baseline orders + fluid network evaluation
# ---------------------------------------------------------------------------

Order = list[list[tuple[int, float]]]  # per-sender [(dst, size-in-traffic-units)]


def _flows_from_matrix(d: np.ndarray) -> Order:
    d = strip_diagonal(d)
    n = d.shape[0]
    return [
        [(j, float(d[i, j])) for j in range(n) if d[i, j] > _EPS] for i in range(n)
    ]


def sjf_order(d: np.ndarray) -> Order:
    """Shortest-job-first: each sender transmits its smallest flows first."""
    flows = _flows_from_matrix(d)
    return [sorted(f, key=lambda x: x[1]) for f in flows]


def rcs_order(d: np.ndarray, seed: int = 0) -> Order:
    """Random communication scheduling."""
    rng = np.random.default_rng(seed)
    flows = _flows_from_matrix(d)
    out = []
    for f in flows:
        f = list(f)
        rng.shuffle(f)
        out.append(f)
    return out


def fluid_comm_time(
    order: Order, bandwidths: np.ndarray | float = 1.0, n: int | None = None
) -> float:
    """Max-min-fair fluid simulation of the big-switch network.

    Each sender transmits its flows strictly in the given order, one at a
    time, at up to its link bandwidth. A receiver's bandwidth is shared
    max-min-fairly among concurrent incoming flows. This reproduces the
    contention behaviour of Fig 4: two senders targeting one receiver halve
    each other's rates.
    """
    if n is None:
        n = len(order)
    if np.isscalar(bandwidths):
        bw = np.full(n, float(bandwidths))
    else:
        bw = np.asarray(bandwidths, dtype=np.float64)
    queues = [list(f) for f in order]
    head = [0] * n
    rem = [queues[i][0][1] if queues[i] else 0.0 for i in range(n)]
    t = 0.0
    for _ in range(10_000_000):  # safety bound
        active = [i for i in range(n) if head[i] < len(queues[i])]
        if not active:
            return t
        # Max-min fair rate allocation by progressive filling. Constraints:
        # sender i carries one active flow capped at bw[i]; receiver j's
        # incoming flows share bw[j].
        recv_of = {i: queues[i][head[i]][0] for i in active}
        rates = {i: 0.0 for i in active}
        unfrozen = set(active)
        while unfrozen:
            # Smallest headroom-per-unfrozen-flow across all constraints.
            inc = min(
                min(bw[i] - rates[i] for i in unfrozen),  # sender constraints
                min(  # receiver constraints
                    (bw[j] - sum(rates[i] for i in active if recv_of[i] == j))
                    / sum(1 for i in unfrozen if recv_of[i] == j)
                    for j in {recv_of[i] for i in unfrozen}
                ),
            )
            inc = max(inc, 0.0)
            for i in unfrozen:
                rates[i] += inc
            # Freeze flows touching any now-tight constraint.
            newly = {i for i in unfrozen if rates[i] >= bw[i] - 1e-12}
            for j in {recv_of[i] for i in unfrozen}:
                if sum(rates[i] for i in active if recv_of[i] == j) >= bw[j] - 1e-12:
                    newly.update(i for i in unfrozen if recv_of[i] == j)
            if not newly:  # numerical guard; should not happen
                break
            unfrozen -= newly
        # Advance to the next flow completion.
        dt = min(
            rem[i] / rates[i] for i in active if rates[i] > _EPS
        ) if any(rates[i] > _EPS for i in active) else None
        if dt is None:
            raise RuntimeError("fluid simulation deadlock (all rates zero)")
        t += dt
        for i in active:
            rem[i] -= rates[i] * dt
            if rem[i] <= 1e-9:
                head[i] += 1
                rem[i] = queues[i][head[i]][1] if head[i] < len(queues[i]) else 0.0
    raise RuntimeError("fluid simulation did not terminate")


def comm_time(
    d: np.ndarray,
    policy: str = "aurora",
    bandwidths: np.ndarray | None = None,
    seed: int = 0,
) -> float:
    """Communication time of one all-to-all under a scheduling policy."""
    d = strip_diagonal(d)
    n = d.shape[0]
    bw = np.ones(n) if bandwidths is None else np.asarray(bandwidths, float)
    if policy == "aurora":
        # Thm 4.2/5.2: the schedule achieves exactly b_max, so the TIME
        # needs no schedule construction (the constructive BvN decomposition
        # is only needed for the transmission order itself). The equality is
        # asserted property-tested in tests/test_properties.py.
        return b_max_of(d, bw)
    if policy == "sjf":
        return fluid_comm_time(sjf_order(d), bw, n)
    if policy == "rcs":
        return fluid_comm_time(rcs_order(d, seed), bw, n)
    raise ValueError(f"unknown policy {policy!r}")
