"""Traffic matrices for MoE all-to-all phases.

The paper's inputs (§3, Table 1) are per-layer traffic matrices ``D_N`` (first
all-to-all: token dispatch) and ``D_C`` (second: expert-output return), with
``D_C = D_N^T`` because the two phases are exact reverses (§2.2) and FFN
preserves token count.

This module builds traffic matrices from routing decisions and provides the
synthetic "production-like" trace generator used by the evaluation (the Google
LIMoE traces the paper uses are not redistributable; see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def validate_traffic(d: np.ndarray) -> np.ndarray:
    d = np.asarray(d, dtype=np.float64)
    if d.ndim != 2 or d.shape[0] != d.shape[1]:
        raise ValueError(f"traffic matrix must be square, got {d.shape}")
    if (d < 0).any():
        raise ValueError("traffic matrix must be non-negative")
    return d


def strip_diagonal(d: np.ndarray) -> np.ndarray:
    """Footnote 1 (§4.2): self-traffic never crosses the network."""
    d = validate_traffic(d).copy()
    np.fill_diagonal(d, 0.0)
    return d


def traffic_from_routing(
    token_source: np.ndarray, expert_choice: np.ndarray, n_devices: int,
    expert_to_device: np.ndarray | None = None, token_bytes: float = 1.0,
) -> np.ndarray:
    """Build ``D_N`` from per-token routing decisions.

    token_source: (T,) device hosting each token; expert_choice: (T, k) chosen
    expert ids; expert_to_device: (E,) placement map (identity by default,
    i.e. expert e on device e % n_devices).
    """
    token_source = np.asarray(token_source)
    expert_choice = np.asarray(expert_choice)
    if expert_choice.ndim == 1:
        expert_choice = expert_choice[:, None]
    n_experts = int(expert_choice.max()) + 1 if expert_choice.size else 0
    if expert_to_device is None:
        expert_to_device = np.arange(n_experts) % n_devices
    dest = np.asarray(expert_to_device)[expert_choice]  # (T, k)
    d = np.zeros((n_devices, n_devices), dtype=np.float64)
    np.add.at(d, (np.repeat(token_source, expert_choice.shape[1]), dest.ravel()),
              token_bytes)
    return strip_diagonal(d)


def validate_replication(replicas, n: int) -> tuple[tuple[int, ...], ...]:
    """Normalize/validate a per-expert replica placement.

    ``replicas[e]`` lists the devices hosting a copy of expert e, HOME device
    first (the planner world puts expert e's home on device e, the identity
    placement every trace uses). Every entry must be a non-empty sequence of
    distinct device ids in ``range(n)`` starting with ``e``.
    """
    if len(replicas) != n:
        raise ValueError(f"replication needs one host tuple per expert "
                         f"({n}), got {len(replicas)}")
    out = []
    for e, hosts in enumerate(replicas):
        hosts = tuple(int(h) for h in hosts)
        if not hosts or hosts[0] != e:
            raise ValueError(f"replicas[{e}] must start with the home device "
                             f"{e}, got {hosts}")
        if len(set(hosts)) != len(hosts):
            raise ValueError(f"replicas[{e}] has duplicate hosts: {hosts}")
        if any(h < 0 or h >= n for h in hosts):
            raise ValueError(f"replicas[{e}] out of range(n={n}): {hosts}")
        out.append(hosts)
    return tuple(out)


def replicated_traffic(d: np.ndarray, replicas) -> np.ndarray:
    """Replica-aware device traffic for one all-to-all phase.

    Tokens bound for expert e split EVENLY across its replica hosts — the
    deterministic shard-of-token rule (routed rank r of expert e goes to
    replica ``r % r_e``), which distributes any source's flow uniformly.
    A replica hosted on the token's own source device absorbs its 1/r_e
    share locally (footnote 1: self-traffic never crosses the network), so
    replication cuts both the hot column AND total network bytes.
    """
    d = validate_traffic(d)
    n = d.shape[0]
    replicas = validate_replication(replicas, n)
    out = np.zeros_like(d)
    for e, hosts in enumerate(replicas):
        share = d[:, e] / len(hosts)
        for h in hosts:
            out[:, h] += share
    return strip_diagonal(out)


def replicated_ffn_loads(d: np.ndarray, replicas) -> np.ndarray:
    """Per-device expert-FFN token load under a replica placement.

    Unlike the network matrix, FFN load counts the locally-absorbed shares
    too — a replica still computes the tokens it keeps off the wire.
    """
    d = validate_traffic(d)
    n = d.shape[0]
    replicas = validate_replication(replicas, n)
    loads = np.zeros(n)
    for e, hosts in enumerate(replicas):
        share = d[:, e].sum() / len(hosts)
        for h in hosts:
            loads[h] += share
    return loads


def identity_replication(n: int) -> tuple[tuple[int], ...]:
    """The no-replication placement: every expert only on its home device."""
    return tuple((e,) for e in range(n))


def validate_degraded_hosts(hosts, n_experts: int,
                            m: int) -> tuple[tuple[int, ...], ...]:
    """Normalize/validate a survivor-frame host map.

    Unlike ``validate_replication`` — which lives in the one-device-per-
    expert frame and pins each expert's home to its own index — a degraded
    map places ``n_experts`` logical experts on ``m <= n_experts`` surviving
    devices: ``hosts[e]`` is a non-empty tuple of distinct survivor indices
    in ``range(m)``, home (the copy routing falls back to) first, with no
    home constraint since the expert↔device bijection is gone.
    """
    if len(hosts) != n_experts:
        raise ValueError(f"degraded hosts need one tuple per expert "
                         f"({n_experts}), got {len(hosts)}")
    out = []
    for e, hs in enumerate(hosts):
        hs = tuple(int(h) for h in hs)
        if not hs:
            raise ValueError(f"hosts[{e}] is empty — expert {e} has no "
                             "surviving copy")
        if len(set(hs)) != len(hs):
            raise ValueError(f"hosts[{e}] has duplicate devices: {hs}")
        if any(h < 0 or h >= m for h in hs):
            raise ValueError(f"hosts[{e}] out of range({m} survivors): {hs}")
        out.append(hs)
    return tuple(out)


def degraded_traffic(d: np.ndarray, hosts, sources,
                     m: int) -> np.ndarray:
    """Device traffic of a survivor-only deployment, ``(m, m)``.

    ``d`` is the expert-frame matrix (source device i → expert e tokens,
    one row per ORIGINAL device); ``sources[i]`` is the survivor that
    inherited original device i's tokens (i's own survivor index when it
    survived); ``hosts[e]`` lists the survivors computing expert e, tokens
    splitting evenly across copies (same shard-of-token rule as
    ``replicated_traffic``). Self-shares stay off the wire (§4.2 fn 1).
    """
    d = validate_traffic(d)
    n = d.shape[0]
    hosts = validate_degraded_hosts(hosts, n, m)
    src = [int(s) for s in sources]
    if len(src) != n or any(s < 0 or s >= m for s in src):
        raise ValueError(f"sources must map {n} original devices into "
                         f"range({m} survivors), got {sources}")
    row_agg = np.zeros((m, n))
    for i, s in enumerate(src):
        row_agg[s] += d[i]
    out = np.zeros((m, m))
    for e, hs in enumerate(hosts):
        share = row_agg[:, e] / len(hs)
        for h in hs:
            out[:, h] += share
    return strip_diagonal(out)


def degraded_ffn_loads(d: np.ndarray, hosts, m: int) -> np.ndarray:
    """Per-survivor FFN token load; locally-absorbed shares still count."""
    d = validate_traffic(d)
    n = d.shape[0]
    hosts = validate_degraded_hosts(hosts, n, m)
    loads = np.zeros(m)
    for e, hs in enumerate(hosts):
        share = d[:, e].sum() / len(hs)
        for h in hs:
            loads[h] += share
    return loads


def row_col_sums(d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    d = validate_traffic(d)
    return d.sum(axis=1), d.sum(axis=0)


def b_max_homogeneous(d: np.ndarray, bandwidth: float = 1.0) -> float:
    """Thm 4.2: minimum all-to-all time = max(row sum, col sum) / B."""
    rows, cols = row_col_sums(strip_diagonal(d))
    return float(max(rows.max(initial=0.0), cols.max(initial=0.0))) / bandwidth


def b_max_heterogeneous(d: np.ndarray, bandwidths: np.ndarray) -> float:
    """Thm 5.2: minimum time = max_i(row_i/B_i, col_i/B_i)."""
    rows, cols = row_col_sums(strip_diagonal(d))
    b = np.asarray(bandwidths, dtype=np.float64)
    if b.shape != rows.shape:
        raise ValueError("bandwidths must have one entry per device")
    return float(max((rows / b).max(initial=0.0), (cols / b).max(initial=0.0)))


@dataclasses.dataclass(frozen=True)
class MoETrace:
    """A per-layer trace of one MoE model, LIMoE-style (§8.1).

    ``layers[l]`` is the first-all-to-all traffic matrix ``D_N`` of layer l.
    The second all-to-all is its transpose. ``gate``, ``ffn_per_token`` and
    ``agg`` are computation times on the *reference* device (compute=1.0);
    heterogeneous devices scale them by 1/compute.
    """

    name: str
    layers: tuple[np.ndarray, ...]
    gate: float
    ffn_per_token: float
    agg: float
    ffn_fixed: float = 0.0  # weight-load / launch cost, independent of tokens
    # (at inference batch sizes the expert FFN is often memory-bound on its
    # weights, so a model with 4x fewer tokens does NOT run 4x faster)

    @property
    def n(self) -> int:
        return self.layers[0].shape[0]

    def layer(self, l: int) -> np.ndarray:
        return self.layers[l]

    def ffn_time(self, tokens) -> float:
        return self.ffn_fixed + self.ffn_per_token * tokens


def synthetic_trace(
    name: str,
    n_experts: int = 8,
    n_layers: int = 4,
    tokens_per_device: float = 1024.0,
    skew: float = 1.2,
    gate: float = 0.08,
    ffn_per_token: float = 0.004,
    agg: float = 0.05,
    ffn_fixed: float = 0.0,
    seed: int = 0,
) -> MoETrace:
    """Skewed expert-popularity traces mimicking production MoE routing.

    Expert popularity per layer follows a Dirichlet draw sharpened by a
    Zipf-like rank profile (production MoE routing is heavy-tailed: a few hot
    experts draw most tokens [Fedus+22, Huang+23]). Each device contributes
    ``tokens_per_device`` tokens, split across destination experts by the
    popularity vector with per-source multiplicative noise.
    """
    rng = np.random.default_rng(seed)
    layers = []
    # The second all-to-all returns expert outputs to the token's home
    # device before the next layer starts (§2.1 "ensuring the original
    # sequences are organized"), so every layer's senders hold the same
    # ~uniform resident token count; only the receive side is skewed by
    # expert popularity.
    tok = np.full(n_experts, float(tokens_per_device))
    for _ in range(n_layers):
        # Zipf-like rank profile with a concentrated Dirichlet perturbation:
        # production routers are load-balance regularized, so popularity is
        # heavy-tailed but not degenerate (max/mean ~ 1.3-2x for skew ~0.2-1).
        rank = np.arange(1, n_experts + 1, dtype=np.float64) ** (-skew)
        base = rank / rank.sum()
        pop = rng.dirichlet(base * 150.0 * n_experts)
        rng.shuffle(pop)  # hot expert is not always expert 0
        d = np.zeros((n_experts, n_experts))
        for src in range(n_experts):
            noise = rng.lognormal(mean=0.0, sigma=0.12, size=n_experts)
            w = pop * noise
            w = w / w.sum()
            d[src] = tok[src] * w
        layers.append(strip_diagonal(d))
    return MoETrace(name=name, layers=tuple(layers), gate=gate,
                    ffn_per_token=ffn_per_token, agg=agg, ffn_fixed=ffn_fixed)


def trace_from_counts(
    name: str,
    counts: np.ndarray,
    tokens_per_device: float = 1024.0,
    gate: float = 0.08,
    ffn_per_token: float = 0.004,
    agg: float = 0.05,
    ffn_fixed: float = 0.0,
) -> MoETrace:
    """Build a ``MoETrace`` from live per-layer expert routing counts.

    ``counts``: (n_layers, E) routed-choice counts (or rates) per expert, as
    harvested by ``repro.serving.monitor.TrafficMonitor`` from engine steps.
    Each expert sits on its own device (identity placement, n = E — the same
    convention the planner's traces use). Token sources are modeled as
    uniform across devices — the §2.1 return all-to-all restores ~uniform
    resident token counts every layer, so only the receive side carries the
    popularity skew: ``d[src, dst] = pop[dst] * tokens_per_device``.

    Layers whose counts are all zero (not yet observed) fall back to uniform
    popularity. Absolute scale is set by ``tokens_per_device`` so live traces
    are comparable with ``synthetic_trace`` outputs.
    """
    counts = np.asarray(counts, dtype=np.float64)
    if counts.ndim != 2:
        raise ValueError(f"counts must be (n_layers, E), got {counts.shape}")
    if (counts < 0).any():
        raise ValueError("routing counts must be non-negative")
    n_layers, n = counts.shape
    layers = []
    for l in range(n_layers):
        total = counts[l].sum()
        pop = counts[l] / total if total > 0 else np.full(n, 1.0 / n)
        d = np.tile(pop * tokens_per_device, (n, 1))
        layers.append(strip_diagonal(d))
    return MoETrace(name=name, layers=tuple(layers), gate=gate,
                    ffn_per_token=ffn_per_token, agg=agg,
                    ffn_fixed=ffn_fixed)


def paper_eval_traces(seed: int = 0) -> tuple[MoETrace, MoETrace]:
    """The two-model setup of §8.1: LIMoE B/16 and B/32, 8 experts, 4 layers.

    B/16 sees ~4x the tokens of B/32 (patch size halves → 4x sequence length),
    making B/16 the communication-heavy model and B/32 the compute-light one —
    the complementarity Aurora's colocation exploits.
    """
    b16 = synthetic_trace("B/16", tokens_per_device=1024.0, skew=0.30,
                          ffn_per_token=0.0075, ffn_fixed=3.0,
                          gate=0.30, agg=0.18, seed=seed)
    b32 = synthetic_trace("B/32", tokens_per_device=512.0, skew=0.25,
                          ffn_per_token=0.0075, ffn_fixed=3.0,
                          gate=0.15, agg=0.09, seed=seed + 1)
    return b16, b32


def add_noise(trace: MoETrace, noise_frac: float, seed: int = 0) -> MoETrace:
    """Fig 14 methodology: perturb traffic by mixing in unseen request traffic.

    ``noise_frac`` of each matrix is replaced by traffic drawn from a fresh
    synthetic layer (the paper mixes in other layers' matrices; we mix a fresh
    draw, same effect: the plan was optimized for the unperturbed matrix).
    """
    rng = np.random.default_rng(seed)
    noisy = []
    for d in trace.layers:
        total = d.sum()
        fresh = rng.random(d.shape)
        np.fill_diagonal(fresh, 0.0)
        fresh = fresh / fresh.sum() * total
        noisy.append((1.0 - noise_frac) * d + noise_frac * fresh)
    return dataclasses.replace(trace, layers=tuple(noisy))
