"""Inference-time simulator for the four Aurora scenarios (Eqn 1–4, Table 2).

Timing semantics follow the paper:

- Exclusive (Eqn 3, generalized to heterogeneous devices):
  ``t = max_i G_i + N + max_i F_i + C + max_i A_i`` where N and C are the two
  all-to-all times under the chosen scheduling policy.
- Colocated (Table 2 recurrence): model b's gate overlaps model a's dispatch,
  each model's FFN overlaps the other model's communication, etc. Component
  end-times are the maxima across devices, exactly as Table 2 collapses the
  per-GPU index. Aggregated communication completions follow §6.2:
  ``End(N^b) = |overline{N^a+N^b}|`` and
  ``End(C^b) = |overline{N^a+N^b}| + |overline{C^a+C^b}|`` (N and C phases are
  disjoint in time, separated by the FFNs), each additionally floored by the
  compute dependencies (a phase cannot end before its producer finished plus
  its own duration).

Computation-time model: ``trace.gate`` / ``trace.agg`` are per-device times on
a reference (compute=1.0) device; FFN time is ``ffn_per_token × tokens
received``; a device with relative compute c runs all of these 1/c as fast.
GPU utilization is compute-busy time divided by inference time, averaged over
devices (§8.1 metrics).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .assignment import apply_assignment
from .cluster import Cluster
from .colocation import aggregate_traffic, aggregate_traffic_multi, lina_packing
from .schedule import comm_time
from .traffic import (MoETrace, degraded_ffn_loads, degraded_traffic,
                      replicated_ffn_loads, replicated_traffic, strip_diagonal)


@dataclasses.dataclass(frozen=True)
class SimResult:
    inference_time: float
    utilization: float
    detail: dict


def _device_arrays(cluster: Cluster) -> tuple[np.ndarray, np.ndarray]:
    return (np.asarray(cluster.bandwidths, float),
            np.asarray(cluster.computes, float))


def exclusive_inference_time(
    trace: MoETrace,
    layer: int,
    cluster: Cluster,
    expert_to_device: np.ndarray | None = None,
    policy: str = "aurora",
    seed: int = 0,
) -> SimResult:
    """One MoE layer, one model per cluster (scenarios 1 and 2)."""
    d_exp = trace.layer(layer)
    n = d_exp.shape[0]
    if cluster.n != n:
        raise ValueError("one device per expert required in exclusive mode")
    e2d = (np.arange(n) if expert_to_device is None
           else np.asarray(expert_to_device))
    d_dev = apply_assignment(d_exp, e2d)
    bw, comp = _device_arrays(cluster)

    recv_tokens = strip_diagonal(d_dev).sum(axis=0)  # per-device FFN load
    gate = trace.gate / comp
    ffn = trace.ffn_time(recv_tokens) / comp
    agg = trace.agg / comp
    n_time = comm_time(d_dev, policy, bw, seed=seed)
    c_time = comm_time(d_dev.T, policy, bw, seed=seed + 1)

    t = float(gate.max() + n_time + ffn.max() + c_time + agg.max())
    busy = gate + ffn + agg
    util = float(np.mean(busy / t)) if t > 0 else 1.0
    return SimResult(t, util, dict(
        gate=float(gate.max()), N=n_time, ffn=float(ffn.max()),
        C=c_time, agg=float(agg.max()),
    ))


def replicated_inference_time(
    trace: MoETrace,
    layer: int,
    cluster: Cluster,
    replicas,
    policy: str = "aurora",
    seed: int = 0,
) -> SimResult:
    """Exclusive scenario with hot experts replicated across devices.

    ``replicas[e]`` lists the devices hosting a copy of expert e (home
    first); tokens split evenly across copies (the shard-of-token rule), so
    a device hosting r copies of a hot expert receives 1/r of its column —
    both the all-to-all bottleneck column and the FFN straggler shrink.
    Shares absorbed by a replica on the token's own source device never
    cross the network but still count as FFN load.
    """
    d_exp = trace.layer(layer)
    n = d_exp.shape[0]
    if cluster.n != n:
        raise ValueError("one home device per expert required")
    d_dev = replicated_traffic(d_exp, replicas)
    ffn_tokens = replicated_ffn_loads(d_exp, replicas)
    bw, comp = _device_arrays(cluster)

    gate = trace.gate / comp
    ffn = trace.ffn_time(ffn_tokens) / comp
    agg = trace.agg / comp
    n_time = comm_time(d_dev, policy, bw, seed=seed)
    c_time = comm_time(d_dev.T, policy, bw, seed=seed + 1)

    t = float(gate.max() + n_time + ffn.max() + c_time + agg.max())
    busy = gate + ffn + agg
    util = float(np.mean(busy / t)) if t > 0 else 1.0
    return SimResult(t, util, dict(
        gate=float(gate.max()), N=n_time, ffn=float(ffn.max()),
        C=c_time, agg=float(agg.max()),
        n_replicas=int(sum(len(h) for h in replicas)),
    ))


def degraded_inference_time(
    trace: MoETrace,
    layer: int,
    survivors: Cluster,
    hosts,
    sources,
    policy: str = "aurora",
    seed: int = 0,
) -> SimResult:
    """Exclusive scenario on a survivor-only cluster after device loss.

    Unlike ``exclusive_inference_time``/``replicated_inference_time``, the
    device count ``m = survivors.n`` may be SMALLER than the expert count:
    ``hosts[e]`` lists the survivor indices computing expert e (several
    experts share a device, replicas still shard tokens evenly) and
    ``sources[i]`` maps each ORIGINAL device's token stream onto the
    survivor that inherited it. The timing law is still Eqn 3 — the failure
    changes the deployment, not the phase structure.
    """
    d_exp = trace.layer(layer)
    m = survivors.n
    d_dev = degraded_traffic(d_exp, hosts, sources, m)
    ffn_tokens = degraded_ffn_loads(d_exp, hosts, m)
    bw, comp = _device_arrays(survivors)

    gate = trace.gate / comp
    ffn = trace.ffn_time(ffn_tokens) / comp
    agg = trace.agg / comp
    n_time = comm_time(d_dev, policy, bw, seed=seed)
    c_time = comm_time(d_dev.T, policy, bw, seed=seed + 1)

    t = float(gate.max() + n_time + ffn.max() + c_time + agg.max())
    busy = gate + ffn + agg
    util = float(np.mean(busy / t)) if t > 0 else 1.0
    return SimResult(t, util, dict(
        gate=float(gate.max()), N=n_time, ffn=float(ffn.max()),
        C=c_time, agg=float(agg.max()), n_survivors=m,
    ))


def colocated_inference_time(
    trace_a: MoETrace,
    trace_b: MoETrace,
    layer: int,
    cluster: Cluster,
    pair: list[int],
    slot_to_device: np.ndarray | None = None,
    policy: str = "aurora",
    seed: int = 0,
) -> SimResult:
    """Two models colocated, one expert of each per device (scenarios 3, 4).

    Slot k hosts a-expert k and b-expert ``pair[k]``; ``slot_to_device`` maps
    slots onto physical devices (identity on homogeneous clusters).
    """
    da = trace_a.layer(layer)
    db = trace_b.layer(layer)
    n = da.shape[0]
    if db.shape[0] != n:
        raise ValueError("colocated models must have equal expert counts (§6 fn 3)")
    if cluster.n != n:
        raise ValueError("one device per expert pair required")
    s2d = (np.arange(n) if slot_to_device is None
           else np.asarray(slot_to_device))
    p = np.asarray(pair)

    # Device-space matrices.
    da_dev = apply_assignment(da, s2d)                      # a-expert k -> slot k
    db_dev = apply_assignment(db[np.ix_(p, p)], s2d)        # b-expert pair[k] -> slot k
    d_agg = apply_assignment(aggregate_traffic(da, db, pair), s2d)
    bw, comp = _device_arrays(cluster)

    # Communication times under the policy.
    na = comm_time(da_dev, policy, bw, seed=seed)
    nb = comm_time(db_dev, policy, bw, seed=seed + 1)
    n_agg = comm_time(d_agg, policy, bw, seed=seed + 2)     # |overline{Na+Nb}|
    ca = comm_time(da_dev.T, policy, bw, seed=seed + 3)
    cb = comm_time(db_dev.T, policy, bw, seed=seed + 4)
    c_agg = comm_time(d_agg.T, policy, bw, seed=seed + 5)   # |overline{Ca+Cb}|

    # Per-device compute times.
    recv_a = strip_diagonal(da_dev).sum(axis=0)
    recv_b = strip_diagonal(db_dev).sum(axis=0)
    ga = trace_a.gate / comp
    gb = trace_b.gate / comp
    fa = trace_a.ffn_time(recv_a) / comp
    fb = trace_b.ffn_time(recv_b) / comp
    aa = trace_a.agg / comp
    ab = trace_b.agg / comp

    # Table 2 recurrence (maxima across devices).
    e_gb = float(gb.max())
    e_na = na                                    # End(N^a) = |N̄^a|
    e_fa = max(e_gb, e_na) + float(fa.max())
    e_nb = max(n_agg, e_gb + nb)                 # End(N^b) = |overline{Na+Nb}|
    e_fb = max(e_fa, e_nb) + float(fb.max())
    e_ca = max(e_nb, e_fa) + ca                  # network frees at E_Nb; §6.2:
    #   |overline{Na+Nb+Ca}| = |overline{Na+Nb}| + |C̄a|, floored by E_Fa.
    e_aa = max(e_fb, e_ca) + float(aa.max())
    # End(C^b) = |overline{Na+Nb}| + |overline{Ca+Cb}| (the two return
    # all-to-alls overlap), floored by its compute producer and by E_Ca.
    e_cb = max(e_nb + c_agg, e_fb + cb, e_ca)
    e_ab = max(e_aa, e_cb) + float(ab.max())
    t = e_ab + float(ga.max())  # Eqn 4: + |G^a| of the next round

    busy = ga + gb + fa + fb + aa + ab
    util = float(np.mean(busy / t)) if t > 0 else 1.0
    return SimResult(t, util, dict(
        Na=na, Nb=nb, Nagg=n_agg, Ca=ca, Cb=cb,
        E_Fa=e_fa, E_Fb=e_fb, E_Ab=e_ab,
    ))


def multi_colocated_inference_time(
    traces: list[MoETrace],
    layer: int,
    cluster: Cluster,
    groups: list[tuple[int, ...]],
    slot_to_device: np.ndarray | None = None,
    policy: str = "aurora",
    seed: int = 0,
) -> SimResult:
    """N tenants colocated, one expert of each per device.

    The Table-2 recurrence generalizes phase-by-phase. Tenants are indexed
    m = 0..T-1 in interleave order; slot g hosts expert ``groups[g][m]`` of
    tenant m. On the shared network, dispatches serialize and the §6.2
    merged-traffic law gives ``End(N^m) = |overline{N^0+..+N^m}|`` (prefix
    aggregates), floored by the producing gate plus the tenant's own
    dispatch; the return all-to-alls likewise complete at
    ``End(N^{T-1}) + |overline{C^0+..+C^m}|``, floored by their producing
    FFN and the previous combine. On the shared compute, gates of tenants
    1..T-1 run during tenant 0's dispatch, then FFNs and aggregations chain
    in tenant order — the T-fold version of "one model computes while the
    others communicate". For T == 2 this reduces term-for-term to
    ``colocated_inference_time`` (exactly equal under deterministic
    policies; the seeded ``rcs`` policy draws its random orders from a
    different seed layout).
    """
    tmats = [tr.layer(layer) for tr in traces]
    nt = len(traces)
    if nt < 1:
        raise ValueError("need at least one tenant")
    n = tmats[0].shape[0]
    for d in tmats:
        if d.shape[0] != n:
            raise ValueError(
                "colocated tenants must have equal expert counts (§6 fn 3)")
    if cluster.n != n:
        raise ValueError("one device per expert group required")
    if len(groups) != n or any(len(g) != nt for g in groups):
        raise ValueError(f"groups must be {n} tuples of {nt} experts")
    s2d = (np.arange(n) if slot_to_device is None
           else np.asarray(slot_to_device))
    bw, comp = _device_arrays(cluster)

    # Per-tenant device-space matrices and their prefix aggregates.
    devs, prefixes = [], []
    run = np.zeros((n, n))
    for m in range(nt):
        p = np.asarray([g[m] for g in groups])
        d_dev = apply_assignment(tmats[m][np.ix_(p, p)], s2d)
        devs.append(d_dev)
        run = run + d_dev
        prefixes.append(run.copy())

    n_own = [comm_time(devs[m], policy, bw, seed=seed + 2 * m)
             for m in range(nt)]
    c_own = [comm_time(devs[m].T, policy, bw, seed=seed + 2 * m + 1)
             for m in range(nt)]
    # prefixes[0] IS devs[0]: reuse its times so stochastic policies (rcs)
    # don't draw two different samples of the same all-to-all.
    n_pref = [n_own[0]] + [
        comm_time(prefixes[m], policy, bw, seed=seed + 2 * nt + m)
        for m in range(1, nt)]
    c_pref = [c_own[0]] + [
        comm_time(prefixes[m].T, policy, bw, seed=seed + 3 * nt + m)
        for m in range(1, nt)]

    # Per-device compute times (reference-device times scaled by 1/compute).
    gate = [tr.gate / comp for tr in traces]
    ffn = [traces[m].ffn_time(strip_diagonal(devs[m]).sum(axis=0)) / comp
           for m in range(nt)]
    agg_t = [tr.agg / comp for tr in traces]
    g_max = [float(g.max()) for g in gate]
    f_max = [float(f.max()) for f in ffn]
    a_max = [float(a.max()) for a in agg_t]

    # Gates of tenants 1.. chain on the shared compute during N^0.
    e_g = [0.0] * nt
    for m in range(1, nt):
        e_g[m] = e_g[m - 1] + g_max[m]
    # Dispatches: prefix-aggregated completion, floored by the gate producer.
    e_n = [max(n_pref[m], e_g[m] + n_own[m]) for m in range(nt)]
    # FFNs chain after the last gate, each gated on its own dispatch.
    e_f = [0.0] * nt
    prev = e_g[nt - 1]
    for m in range(nt):
        e_f[m] = max(prev, e_n[m]) + f_max[m]
        prev = e_f[m]
    # Combines: network frees at End(N^{T-1}); prefix-aggregated, floored by
    # the producing FFN and ordered after the previous combine.
    e_c = [0.0] * nt
    prev = 0.0
    for m in range(nt):
        e_c[m] = max(e_n[nt - 1] + c_pref[m], e_f[m] + c_own[m], prev)
        prev = e_c[m]
    # Aggregations chain after the last FFN, each gated on its own combine.
    e_a = [0.0] * nt
    prev = e_f[nt - 1]
    for m in range(nt):
        e_a[m] = max(prev, e_c[m]) + a_max[m]
        prev = e_a[m]
    t = e_a[nt - 1] + g_max[0]        # Eqn 4: + |G^0| of the next round

    busy = np.zeros(n)
    for m in range(nt):
        busy = busy + gate[m] + ffn[m] + agg_t[m]
    util = float(np.mean(busy / t)) if t > 0 else 1.0
    agg_all = aggregate_traffic_multi(tmats, groups)
    return SimResult(t, util, dict(
        n_tenants=nt, N=n_own, C=c_own, N_prefix=n_pref, C_prefix=c_pref,
        E_N=e_n, E_F=e_f, E_C=e_c, E_A=e_a,
        agg_bmax=comm_time(apply_assignment(agg_all, s2d), policy, bw,
                           seed=seed + 4 * nt),
    ))


def lina_inference_time(
    trace: MoETrace,
    layer: int,
    cluster: Cluster,
    device_subset: np.ndarray | None = None,
    policy: str = "aurora",
    seed: int = 0,
) -> SimResult:
    """Lina baseline: two experts of the SAME model per device.

    The model's n experts pack onto n/2 devices (popular-with-unpopular);
    colocated same-model experts stay bound to the synchronous all-to-all, so
    the phase structure is the exclusive one with merged traffic and doubled
    per-device FFN load (Fig 3a).
    """
    d_exp = trace.layer(layer)
    merged, pairs = lina_packing(d_exp)
    m = merged.shape[0]
    if device_subset is None:
        device_subset = np.arange(m)
    devs = [cluster.devices[i] for i in np.asarray(device_subset)]
    bw = np.asarray([d.bandwidth for d in devs], float)
    comp = np.asarray([d.compute for d in devs], float)

    recv_tokens = strip_diagonal(merged).sum(axis=0)
    gate = trace.gate / comp
    # Two experts per device: two weight-loads (fixed cost counted twice).
    ffn = (trace.ffn_fixed + trace.ffn_time(recv_tokens)) / comp
    agg = trace.agg / comp
    n_time = comm_time(merged, policy, bw, seed=seed)
    c_time = comm_time(merged.T, policy, bw, seed=seed + 1)

    t = float(gate.max() + n_time + ffn.max() + c_time + agg.max())
    busy = gate + ffn + agg
    util = float(np.mean(busy / t)) if t > 0 else 1.0
    return SimResult(t, util, dict(pairs=pairs, N=n_time, C=c_time))


def mean_over_layers(fn, n_layers: int, **kw) -> SimResult:
    """Average a per-layer simulator over all layers of a trace."""
    results = [fn(layer=l, **kw) for l in range(n_layers)]
    return SimResult(
        inference_time=float(np.mean([r.inference_time for r in results])),
        utilization=float(np.mean([r.utilization for r in results])),
        detail={"per_layer": [r.inference_time for r in results]},
    )
