"""AuroraPlanner: the four-scenario dispatcher (Fig 2).

Given historical model statistics (traces) and a cluster description, produce
a deployment + scheduling plan:

  scenario 1  Exclusive  + Homogeneous   → transmission schedule (Thm 4.2)
  scenario 2  Exclusive  + Heterogeneous → GPU assignment (Thm 5.1) + schedule
  scenario 3  Colocating + Homogeneous   → expert pairing (Thm 6.2 / bottleneck
                                           matching) + schedule
  scenario 4  Colocating + Heterogeneous → decoupled 3D matching (§7.2):
                                           pairing then pair→GPU matching

The plan carries everything the runtime needs: per-layer CommSchedules (BvN
permutation rounds for the ppermute lowering), the expert→device map, and the
predicted inference time from the Table-2 simulator.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .assignment import aurora_assignment, expert_loads
from .cluster import Cluster
from .colocation import (aurora_grouping, aurora_pairing, aggregate_traffic,
                         aggregate_traffic_multi, case2_pairing, group_pairs)
from .errors import FaultError
from .matching import bottleneck_perfect_matching
from .schedule import CommSchedule, aurora_schedule
from .simulator import (SimResult, colocated_inference_time,
                        degraded_inference_time, exclusive_inference_time,
                        multi_colocated_inference_time,
                        replicated_inference_time)
from .traffic import (MoETrace, degraded_traffic, identity_replication,
                      replicated_ffn_loads, replicated_traffic,
                      validate_degraded_hosts, validate_replication)
from .assignment import apply_assignment


@dataclasses.dataclass(frozen=True)
class Plan:
    scenario: str
    expert_to_device: np.ndarray              # model a (or the only model)
    pair: list[int] | None                    # b-expert colocated per slot
    schedules: tuple[CommSchedule, ...]       # per layer, dispatch phase
    predicted: SimResult
    # N-tenant plans (scenario "multi+..."): groups[g][t] = tenant-t expert
    # on slot g, tenant 0 the identity anchor. For two tenants this carries
    # the same information as ``pair`` (groups[g] == (g, pair[g])).
    groups: tuple[tuple[int, ...], ...] | None = None
    # Replicated plans (scenario "...+replicated"): replication[e] lists the
    # devices hosting a copy of expert e, HOME device first. Tokens split
    # evenly across copies (the shard-of-token rule), so this is pure
    # deployment data — the routed function never changes. None = no
    # replication (every expert only on its home device).
    replication: tuple[tuple[int, ...], ...] | None = None
    # Degraded plans (scenario "degraded+..."): survivors[j] is the ORIGINAL
    # cluster index of survivor j — every other per-device field of this
    # plan (expert_to_device, replication hosts, schedules) is expressed in
    # the 0..len(survivors)-1 survivor frame, and replication hosts need not
    # start with the expert's own index (the expert↔device bijection died
    # with the failed devices). None = healthy plan in the original frame.
    survivors: tuple[int, ...] | None = None

    @property
    def replication_counts(self) -> tuple[int, ...] | None:
        """Per-expert replication factor (len of each host tuple)."""
        if self.replication is None:
            return None
        return tuple(len(h) for h in self.replication)

    @property
    def n_layers(self) -> int:
        return len(self.schedules)

    @property
    def n_tenants(self) -> int:
        if self.groups is not None:
            return len(self.groups[0])
        return 2 if self.pair is not None else 1


@dataclasses.dataclass(frozen=True)
class PlanDiff:
    """What changed between two plans, and how much it is predicted to buy.

    ``rel_improvement`` > 0 means the new plan is predicted faster. For an
    apples-to-apples online decision, re-evaluate the OLD plan's placement on
    the live trace first (``AuroraPlanner.evaluate_colocated``) — the stale
    plan's stored prediction was computed against the historical trace it
    was planned from, not against current traffic.
    """

    pair_changed: bool
    assignment_changed: bool
    old_time: float
    new_time: float

    @property
    def placement_changed(self) -> bool:
        return self.pair_changed or self.assignment_changed

    @property
    def rel_improvement(self) -> float:
        if self.old_time <= 0.0:
            return 0.0
        return (self.old_time - self.new_time) / self.old_time


def diff_plans(old: Plan, new: Plan,
               old_time: float | None = None) -> PlanDiff:
    """Compare two plans' placements and predicted inference times.

    ``old_time`` overrides the stale plan's stored prediction — pass the old
    placement re-simulated on the live trace when diffing for re-planning.
    """
    pair_changed = (old.pair is None) != (new.pair is None) or (
        old.pair is not None and list(old.pair) != list(new.pair))
    assignment_changed = not np.array_equal(
        np.asarray(old.expert_to_device), np.asarray(new.expert_to_device))
    return PlanDiff(
        pair_changed=pair_changed,
        assignment_changed=assignment_changed,
        old_time=float(old.predicted.inference_time
                       if old_time is None else old_time),
        new_time=float(new.predicted.inference_time),
    )


def _mean_sim(sims: list[SimResult]) -> SimResult:
    """Whole-model prediction: per-layer simulations averaged."""
    return SimResult(
        float(np.mean([s.inference_time for s in sims])),
        float(np.mean([s.utilization for s in sims])),
        {"per_layer": [s.inference_time for s in sims]},
    )


class AuroraPlanner:
    """Plans deployment + communication scheduling per the paper's four cases."""

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        cluster.validate()

    # -- scenarios 1 & 2 ----------------------------------------------------
    def plan_exclusive(self, trace: MoETrace) -> Plan:
        cl = self.cluster
        n = trace.n
        if cl.homogeneous:
            scenario = "exclusive+homogeneous"
            e2d = np.arange(n)  # observation 1: assignment is irrelevant
        else:
            scenario = "exclusive+heterogeneous"
            # Thm 5.1 on aggregate load across layers (the deployment is one
            # decision for the whole model; per-layer loads are averaged).
            mean_d = np.mean([trace.layer(l) for l in range(len(trace.layers))],
                             axis=0)
            e2d = aurora_assignment(mean_d, cl)
        bw = np.asarray(cl.bandwidths, float)
        schedules = tuple(
            aurora_schedule(apply_assignment(trace.layer(l), e2d), bw)
            for l in range(len(trace.layers))
        )
        pred = _mean_sim([
            exclusive_inference_time(trace, l, cl, e2d, policy="aurora")
            for l in range(len(trace.layers))
        ])
        return Plan(scenario, e2d, None, schedules, pred)

    # -- scenarios 3 & 4 ----------------------------------------------------
    def plan_colocated(self, trace_a: MoETrace, trace_b: MoETrace) -> Plan:
        cl = self.cluster
        n = trace_a.n
        mean_a = np.mean([trace_a.layer(l) for l in range(len(trace_a.layers))],
                         axis=0)
        mean_b = np.mean([trace_b.layer(l) for l in range(len(trace_b.layers))],
                         axis=0)
        if cl.homogeneous:
            scenario = "colocating+homogeneous"
            pair = aurora_pairing(mean_a, mean_b)
            s2d = np.arange(n)
        else:
            scenario = "colocating+heterogeneous"
            # §7.2 decoupling. Step 1: expert↔expert bottleneck matching.
            pair, _ = case2_pairing(mean_a, mean_b)
            # Step 2: pair↔device bottleneck matching; the edge weight is the
            # pair's inference-time contribution on that device: compute
            # (gate+agg+ffn of both experts) scaled by 1/compute plus its
            # send/recv bottleneck scaled by 1/bandwidth.
            d_agg = aggregate_traffic(mean_a, mean_b, pair)
            send = d_agg.sum(axis=1)
            recv = d_agg.sum(axis=0)
            loads_a = expert_loads(mean_a)
            loads_b = expert_loads(mean_b)[np.asarray(pair)]
            comp_fixed = (trace_a.gate + trace_a.agg + trace_b.gate + trace_b.agg)
            comp_tok = (trace_a.ffn_per_token * loads_a
                        + trace_b.ffn_per_token * loads_b)
            w = np.empty((n, n))
            for k in range(n):
                for dev in range(n):
                    dt = cl.devices[dev]
                    w[k, dev] = ((comp_fixed + comp_tok[k]) / dt.compute
                                 + max(send[k], recv[k]) / dt.bandwidth)
            match, _ = bottleneck_perfect_matching(w)
            s2d = np.asarray(match)
        bw = np.asarray(cl.bandwidths, float)
        schedules = tuple(
            aurora_schedule(
                apply_assignment(
                    aggregate_traffic(trace_a.layer(l), trace_b.layer(l), pair),
                    s2d),
                bw)
            for l in range(len(trace_a.layers))
        )
        pred = self.evaluate_colocated(trace_a, trace_b, pair,
                                       None if cl.homogeneous else s2d)
        return Plan(scenario, np.arange(n) if cl.homogeneous else s2d,
                    pair, schedules, pred)

    # -- expert replication (exclusive + hot-expert copies) ------------------
    def plan_replicated(self, trace: MoETrace, tolerance: float = 0.1,
                        max_total_replicas: int | None = None,
                        total_multiple: int | None = None) -> Plan:
        """Exclusive deployment with the hottest experts replicated.

        Greedy: while the hottest device's FFN load exceeds the mean by more
        than ``tolerance`` (relative), copy the expert with the largest
        per-replica token share onto the least-loaded device not already
        hosting it — each copy halves (r→r+1) that expert's per-device
        share under the shard-of-token rule. Stops when balanced, when no
        copy improves the bottleneck, or after ``max_total_replicas`` extra
        copies (default: one per device). ``total_multiple`` then pads the
        total physical expert count up to a multiple (EP sharding needs the
        physical axis divisible by the device count) with the best legal
        copies even when already balanced.

        Replication is placement-only: replicas are pure weight copies and
        routing stays in the logical expert frame, so the plan changes WHERE
        routed tokens are computed, never which tokens are routed where.
        """
        cl = self.cluster
        n = trace.n
        if cl.n != n:
            raise ValueError("one home device per expert required")
        if not cl.homogeneous:
            raise ValueError("plan_replicated supports homogeneous clusters")
        mean_d = np.mean([trace.layer(l) for l in range(len(trace.layers))],
                         axis=0)
        col = mean_d.sum(axis=0)
        replicas = [[e] for e in range(n)]
        budget = n if max_total_replicas is None else int(max_total_replicas)

        def best_copy(loads):
            """(expert, host) whose copy most lowers the peak load, or None."""
            share = np.array([col[e] / len(replicas[e]) for e in range(n)])
            best = None
            for e in np.argsort(-share):
                hosts = [d for d in np.argsort(loads)
                         if d not in replicas[e]]
                if not hosts:
                    continue
                host = int(hosts[0])
                new_share = col[e] / (len(replicas[e]) + 1)
                peak = max(float(loads[host] + new_share),
                           *(float(loads[d] - share[e] + new_share)
                             for d in replicas[e]),
                           *(float(loads[d]) for d in range(n)
                             if d != host and d not in replicas[e]))
                if best is None or peak < best[0]:
                    best = (peak, int(e), host)
            return best

        extra = 0
        while extra < budget:
            loads = replicated_ffn_loads(mean_d, replicas)
            if loads.max() <= (1.0 + tolerance) * loads.mean():
                break
            cand = best_copy(loads)
            if cand is None or cand[0] >= loads.max() - 1e-12:
                break                       # no copy improves the bottleneck
            _, e, host = cand
            replicas[e].append(host)
            extra += 1
        if total_multiple is not None and total_multiple > 0:
            while sum(len(r) for r in replicas) % total_multiple:
                cand = best_copy(replicated_ffn_loads(mean_d, replicas))
                if cand is None:
                    raise ValueError(
                        f"cannot pad replication to a multiple of "
                        f"{total_multiple}: every expert is everywhere")
                _, e, host = cand
                replicas[e].append(host)

        rep = validate_replication([tuple(r) for r in replicas], n)
        bw = np.asarray(cl.bandwidths, float)
        schedules = tuple(
            aurora_schedule(replicated_traffic(trace.layer(l), rep), bw)
            for l in range(len(trace.layers)))
        pred = self.evaluate_replicated(trace, rep)
        return Plan("exclusive+homogeneous+replicated", np.arange(n), None,
                    schedules, pred, replication=rep)

    # -- degraded re-planning (fail-stop device loss) ------------------------
    def plan_degraded(self, trace: MoETrace, failed_devices,
                      replication=None, ep_compatible: bool = False,
                      total_multiple: int | None = None) -> Plan:
        """Survivor-only plan after fail-stop device loss.

        ``failed_devices`` are original cluster indices now gone. Failover
        is two-tier: experts with a surviving replica (``replication`` is
        the healthy plan's host map, identity when None) keep their
        surviving copies — lossless, only the shard-of-token split widens
        back to fewer copies — while experts whose every host died are
        re-homed greedily onto the least-loaded survivor (load measured in
        FFN time, so slow devices attract less on heterogeneous clusters).
        Schedules and the predicted time come from the survivor-frame
        traffic (``degraded_traffic`` / ``degraded_inference_time``).

        ``ep_compatible=True`` restricts the plan to the fastest survivor
        subset whose size divides the expert count (EP sharding needs
        experts-per-device integral) and pads total replica count to a
        multiple of it, so distributed engines can adopt the plan on a
        shrunken mesh. ``total_multiple`` overrides the padding multiple.

        Raises ``FaultError`` when no device survives, when a failed index
        is out of range, or when padding is impossible.
        """
        cl = self.cluster
        n = trace.n
        if cl.n != n:
            raise FaultError(
                f"plan_degraded plans from the healthy one-device-per-expert "
                f"frame: cluster has {cl.n} devices for {n} experts")
        failed = sorted({int(d) for d in failed_devices})
        for d in failed:
            if not 0 <= d < n:
                raise FaultError(f"failed device {d} out of range({n})")
        alive = [d for d in range(n) if d not in failed]
        if not alive:
            raise FaultError("no surviving devices to re-plan onto")
        if ep_compatible:
            k = max(s for s in range(1, len(alive) + 1) if n % s == 0)
            order = [d for d in cl.sorted_indices_by_performance()
                     if d in alive]
            chosen = sorted(order[:k])
        else:
            chosen = alive
        k = len(chosen)
        surv = cl.subcluster(chosen)
        pos = {d: j for j, d in enumerate(chosen)}

        rep = (identity_replication(n) if replication is None
               else validate_replication(replication, n))
        mean_d = np.mean([trace.layer(l) for l in range(len(trace.layers))],
                         axis=0)
        col = mean_d.sum(axis=0)
        comp = np.asarray(surv.computes, float)

        hosts: list[list[int]] = [
            [pos[d] for d in rep[e] if d in pos] for e in range(n)]
        loads = np.zeros(k)
        for e in range(n):
            if hosts[e]:
                for h in hosts[e]:
                    loads[h] += col[e] / len(hosts[e])
        # Re-home orphaned experts, hottest first, onto the least-loaded
        # survivor (in time units — heterogeneous survivors differ).
        orphans = [e for e in range(n) if not hosts[e]]
        for e in sorted(orphans, key=lambda e: -col[e]):
            h = int(np.argmin(loads / comp))
            hosts[e] = [h]
            loads[h] += col[e]

        multiple = total_multiple if total_multiple is not None else (
            k if ep_compatible else None)
        if multiple:
            while sum(len(h) for h in hosts) % multiple:
                cand = None
                for e in np.argsort(-col / [len(h) for h in hosts]):
                    free = [j for j in np.argsort(loads / comp)
                            if j not in hosts[e]]
                    if free:
                        cand = (int(e), int(free[0]))
                        break
                if cand is None:
                    raise FaultError(
                        f"cannot pad degraded replication to a multiple of "
                        f"{multiple}: every expert is on every survivor")
                e, h = cand
                share_old = col[e] / len(hosts[e])
                for j in hosts[e]:
                    loads[j] -= share_old
                hosts[e].append(h)
                share_new = col[e] / len(hosts[e])
                for j in hosts[e]:
                    loads[j] += share_new

        host_map = validate_degraded_hosts([tuple(h) for h in hosts], n, k)
        # Failed devices' token streams land round-robin on survivors.
        sources = [pos[i] if i in pos else pos[chosen[i % k]]
                   for i in range(n)]
        bw = np.asarray(surv.bandwidths, float)
        schedules = tuple(
            aurora_schedule(
                degraded_traffic(trace.layer(l), host_map, sources, k), bw)
            for l in range(len(trace.layers)))
        pred = _mean_sim([
            degraded_inference_time(trace, l, surv, host_map, sources,
                                    policy="aurora")
            for l in range(len(trace.layers))
        ])
        scenario = ("degraded+homogeneous" if surv.homogeneous
                    else "degraded+heterogeneous")
        e2d = np.asarray([h[0] for h in host_map])
        return Plan(scenario, e2d, None, schedules, pred,
                    replication=host_map, survivors=tuple(chosen))

    def evaluate_replicated(self, trace: MoETrace, replicas) -> SimResult:
        """Predicted inference time of an EXISTING replica placement on
        (possibly new) traces — the scoring leg of online re-replication."""
        rep = validate_replication(replicas, trace.n)
        return _mean_sim([
            replicated_inference_time(trace, l, self.cluster, rep,
                                      policy="aurora")
            for l in range(len(trace.layers))
        ])

    # -- plan evaluation (re-planning support) ------------------------------
    def evaluate_exclusive(self, trace: MoETrace,
                           expert_to_device) -> SimResult:
        """Predicted inference time of an EXISTING expert→device assignment
        on (possibly new) traces — ``plan_exclusive``'s simulator leg without
        re-planning; the scoring leg of online re-assignment (scenario 2)."""
        e2d = np.asarray(expert_to_device)
        return _mean_sim([
            exclusive_inference_time(trace, l, self.cluster, e2d,
                                     policy="aurora")
            for l in range(len(trace.layers))
        ])

    def evaluate_colocated(self, trace_a: MoETrace, trace_b: MoETrace,
                           pair: list[int],
                           slot_to_device: np.ndarray | None = None
                           ) -> SimResult:
        """Predicted inference time of an EXISTING pairing on (possibly new)
        traces — the simulator leg of ``plan_colocated`` without re-planning.

        This is how online re-planning scores a stale plan against live
        traffic: evaluate the current pairing and a fresh plan on the SAME
        live trace, and switch only when the fresh plan wins by a margin.
        """
        cl = self.cluster
        n = trace_a.n
        s2d = (np.arange(n) if slot_to_device is None
               else np.asarray(slot_to_device))
        return _mean_sim([
            colocated_inference_time(trace_a, trace_b, l, cl, list(pair),
                                     s2d, policy="aurora")
            for l in range(len(trace_a.layers))
        ])

    # -- multi-tenant colocation (N >= 2) ------------------------------------
    def plan_multi(self, traces: list[MoETrace]) -> Plan:
        """N-tenant colocation plan: greedy k-way grouping (§7.2 decoupling
        applied tenant-by-tenant), then — heterogeneous only — group↔device
        bottleneck matching with the same inference-time edge weight as
        scenario 4. For two tenants this reproduces ``plan_colocated``.
        """
        cl = self.cluster
        nt = len(traces)
        if nt < 2:
            raise ValueError("plan_multi needs at least two tenants "
                             "(use plan_exclusive for one)")
        n = traces[0].n
        if any(tr.n != n for tr in traces):
            raise ValueError("all tenants must have equal expert counts")
        means = [np.mean([tr.layer(l) for l in range(len(tr.layers))], axis=0)
                 for tr in traces]
        if cl.homogeneous:
            scenario = "multi+homogeneous"
            groups = aurora_grouping(means)
            s2d = np.arange(n)
        else:
            scenario = "multi+heterogeneous"
            groups = aurora_grouping(means, use_case1=False)
            # Group↔device matching: the group's inference-time contribution
            # on a device is its combined compute (all tenants' gate + agg +
            # token-scaled FFN) over the device's compute, plus its send/recv
            # bottleneck over the device's bandwidth — scenario 4's weight
            # with the pair replaced by the k-group.
            d_agg = aggregate_traffic_multi(means, groups)
            send = d_agg.sum(axis=1)
            recv = d_agg.sum(axis=0)
            perms = group_pairs(groups)
            comp_fixed = sum(tr.gate + tr.agg for tr in traces)
            comp_tok = sum(
                traces[t].ffn_per_token
                * expert_loads(means[t])[np.asarray(perms[t])]
                for t in range(nt))
            w = np.empty((n, n))
            for k in range(n):
                for dev in range(n):
                    dt = cl.devices[dev]
                    w[k, dev] = ((comp_fixed + comp_tok[k]) / dt.compute
                                 + max(send[k], recv[k]) / dt.bandwidth)
            match, _ = bottleneck_perfect_matching(w)
            s2d = np.asarray(match)
        bw = np.asarray(cl.bandwidths, float)
        schedules = tuple(
            aurora_schedule(
                apply_assignment(
                    aggregate_traffic_multi(
                        [tr.layer(l) for tr in traces], groups),
                    s2d),
                bw)
            for l in range(len(traces[0].layers))
        )
        pred = self.evaluate_multi(traces, groups,
                                   None if cl.homogeneous else s2d)
        pair = [g[1] for g in groups] if nt == 2 else None
        return Plan(scenario, np.arange(n) if cl.homogeneous else s2d,
                    pair, schedules, pred, groups=tuple(groups))

    def evaluate_multi(self, traces: list[MoETrace],
                       groups: list[tuple[int, ...]],
                       slot_to_device: np.ndarray | None = None) -> SimResult:
        """Predicted inference time of an EXISTING grouping on (possibly new)
        traces — ``evaluate_colocated`` generalized to N tenants; the scoring
        leg of online re-grouping."""
        cl = self.cluster
        n = traces[0].n
        s2d = (np.arange(n) if slot_to_device is None
               else np.asarray(slot_to_device))
        return _mean_sim([
            multi_colocated_inference_time(traces, l, cl,
                                           [tuple(g) for g in groups],
                                           s2d, policy="aurora")
            for l in range(len(traces[0].layers))
        ])
