"""Brute-force optima for validating Aurora (Fig 13 / small-n tests).

Exhaustive search over expert pairings (and device assignments in the
heterogeneous case). Feasible for n <= 6 (6!^2 ~ 5.2e5 colocated evaluations);
the paper itself obtains the optimum "through brute-force search".
"""

from __future__ import annotations

import itertools

import numpy as np

from .cluster import Cluster
from .simulator import colocated_inference_time, exclusive_inference_time
from .traffic import MoETrace


def bruteforce_exclusive(
    trace: MoETrace, layer: int, cluster: Cluster
) -> tuple[float, np.ndarray]:
    """Optimal expert→device assignment by exhaustive permutation search."""
    n = trace.n
    best_t = float("inf")
    best: np.ndarray | None = None
    for perm in itertools.permutations(range(n)):
        e2d = np.asarray(perm)
        r = exclusive_inference_time(trace, layer, cluster, e2d, policy="aurora")
        if r.inference_time < best_t:
            best_t = r.inference_time
            best = e2d
    assert best is not None
    return best_t, best


def bruteforce_colocated(
    trace_a: MoETrace,
    trace_b: MoETrace,
    layer: int,
    cluster: Cluster,
    homogeneous_assignment: bool | None = None,
) -> tuple[float, list[int], np.ndarray]:
    """Optimal (pairing, assignment) by exhaustive search.

    On homogeneous clusters the device assignment is irrelevant (paper
    observation 1), so only pairings are enumerated.
    """
    n = trace_a.n
    if homogeneous_assignment is None:
        homogeneous_assignment = cluster.homogeneous
    best_t = float("inf")
    best_pair: list[int] | None = None
    best_s2d = np.arange(n)
    if homogeneous_assignment:
        assignments = [np.arange(n)]
    else:
        # Devices of the same type are interchangeable (identical bandwidth
        # and compute), so only type-distinct assignments need enumerating:
        # 6 devices in 2 tiers → 20 patterns instead of 720.
        types = [(d.bandwidth, d.compute) for d in cluster.devices]
        seen: set = set()
        assignments = []
        for p in itertools.permutations(range(n)):
            key = tuple(types[d] for d in p)
            if key in seen:
                continue
            seen.add(key)
            assignments.append(np.asarray(p))
    for pair in itertools.permutations(range(n)):
        pair = list(pair)
        for s2d in assignments:
            r = colocated_inference_time(
                trace_a, trace_b, layer, cluster, pair, s2d, policy="aurora")
            if r.inference_time < best_t:
                best_t = r.inference_time
                best_pair = pair
                best_s2d = s2d
    assert best_pair is not None
    return best_t, best_pair, best_s2d
