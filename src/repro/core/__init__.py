"""Aurora core: MoE inference deployment + communication scheduling.

The paper's contribution as a composable library:

- ``traffic``     — traffic matrices, b_max bounds, trace generation
- ``schedule``    — Thm 4.2/5.2 BvN contention-free schedules + baselines
- ``matching``    — Hopcroft–Karp, bottleneck perfect matching
- ``assignment``  — Thm 5.1 heterogeneous GPU assignment
- ``colocation``  — Thm 6.2 cross-model expert colocation
- ``simulator``   — Table 2 / Eqn 1–4 inference-time model
- ``planner``     — the 4-scenario AuroraPlanner
- ``bruteforce``  — exhaustive optima for validation
"""

from .cluster import (Cluster, DeviceType, heterogeneous_cluster,
                      homogeneous_cluster, PAPER_HET_TIERS)
from .errors import FaultError, PlanError
from .traffic import (MoETrace, add_noise, b_max_heterogeneous,
                      b_max_homogeneous, degraded_ffn_loads, degraded_traffic,
                      identity_replication, paper_eval_traces,
                      replicated_ffn_loads, replicated_traffic,
                      synthetic_trace, trace_from_counts,
                      traffic_from_routing, validate_degraded_hosts,
                      validate_replication)
from .schedule import (CommSchedule, Slot, aurora_schedule, comm_time,
                       fluid_comm_time, rcs_order, sjf_order)
from .matching import bottleneck_perfect_matching, hopcroft_karp
from .assignment import (apply_assignment, aurora_assignment, expert_loads,
                         random_assignment)
from .colocation import (aggregate_traffic, aggregate_traffic_multi,
                         aurora_grouping, aurora_pairing, case1_pairing,
                         case2_pairing, group_pairs, lina_packing,
                         random_grouping, random_pairing)
from .simulator import (SimResult, colocated_inference_time,
                        degraded_inference_time, exclusive_inference_time,
                        lina_inference_time, multi_colocated_inference_time,
                        replicated_inference_time)
from .planner import AuroraPlanner, Plan, PlanDiff, diff_plans
from .bruteforce import bruteforce_colocated, bruteforce_exclusive

__all__ = [
    "Cluster", "DeviceType", "heterogeneous_cluster", "homogeneous_cluster",
    "PAPER_HET_TIERS", "MoETrace", "add_noise", "b_max_heterogeneous",
    "b_max_homogeneous", "paper_eval_traces", "synthetic_trace",
    "trace_from_counts", "traffic_from_routing", "CommSchedule", "Slot",
    "aurora_schedule",
    "comm_time", "fluid_comm_time", "rcs_order", "sjf_order",
    "bottleneck_perfect_matching", "hopcroft_karp", "apply_assignment",
    "aurora_assignment", "expert_loads", "random_assignment",
    "aggregate_traffic", "aggregate_traffic_multi", "aurora_grouping",
    "aurora_pairing", "case1_pairing", "case2_pairing", "group_pairs",
    "lina_packing", "random_grouping", "random_pairing", "SimResult",
    "colocated_inference_time", "exclusive_inference_time",
    "lina_inference_time", "multi_colocated_inference_time",
    "replicated_inference_time", "identity_replication",
    "replicated_ffn_loads", "replicated_traffic", "validate_replication",
    "degraded_inference_time", "degraded_ffn_loads", "degraded_traffic",
    "validate_degraded_hosts", "FaultError", "PlanError",
    "AuroraPlanner", "Plan", "PlanDiff", "diff_plans",
    "bruteforce_colocated", "bruteforce_exclusive",
]
