"""Expert colocation across two MoE models (§6).

Aurora colocates one expert of model *a* with one expert of model *b* on each
device so that compute of one interleaves with communication of the other
(Fig 3b). The colocation choice determines the aggregated traffic matrix and
hence, via Thm 4.2, the aggregated communication time; Thm 6.1 shows that
minimizing that time minimizes inference time on homogeneous clusters.

- Case I (per-device send == recv): Thm 6.2 sort-ascending/descending pairing.
- Case II (general): bottleneck matching with weight
  ``max(a_i + b_j, a_{n+i} + b_{n+j})``.
- Baselines: Lina-style same-model packing (popular-with-unpopular within one
  model) and REC (random cross-model pairing).
"""

from __future__ import annotations

import numpy as np

from .matching import bottleneck_perfect_matching
from .traffic import strip_diagonal


def send_recv_vectors(d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    d = strip_diagonal(d)
    return d.sum(axis=1), d.sum(axis=0)


def case1_pairing(a_tot: np.ndarray, b_tot: np.ndarray) -> list[int]:
    """Thm 6.2: sort ``a`` ascending, ``b`` descending, pair sequentially.

    Applicable when send == recv per device, so each expert is described by a
    single scalar. Returns ``pair[i]`` = index of model-b expert colocated
    with model-a expert i.
    """
    a_tot = np.asarray(a_tot, dtype=np.float64)
    b_tot = np.asarray(b_tot, dtype=np.float64)
    n = len(a_tot)
    a_order = np.argsort(a_tot, kind="stable")          # ascending
    b_order = np.argsort(-b_tot, kind="stable")         # descending
    pair = [-1] * n
    for ai, bi in zip(a_order, b_order):
        pair[ai] = int(bi)
    return pair


def case2_pairing(da: np.ndarray, db: np.ndarray) -> tuple[list[int], float]:
    """§6.2 Case II: bottleneck matching on the full bipartite graph.

    Edge (i, j) weight = max(send_a[i] + send_b[j], recv_a[i] + recv_b[j]),
    the per-device bottleneck (max of aggregate send and aggregate receive)
    if a-expert i and b-expert j share a device. Returns (pair, w*) where w*
    is the minimized maximum row/col sum of the aggregated matrix — i.e. the
    aggregated ``b_max`` (bandwidth 1).
    """
    sa, ra = send_recv_vectors(da)
    sb, rb = send_recv_vectors(db)
    w = np.maximum(sa[:, None] + sb[None, :], ra[:, None] + rb[None, :])
    return bottleneck_perfect_matching(w)


def aurora_pairing(da: np.ndarray, db: np.ndarray) -> list[int]:
    """Dispatch: Case I fast path when send==recv everywhere, else Case II."""
    sa, ra = send_recv_vectors(da)
    sb, rb = send_recv_vectors(db)
    if np.allclose(sa, ra) and np.allclose(sb, rb):
        return case1_pairing(sa, sb)
    pair, _ = case2_pairing(da, db)
    return pair


def random_pairing(n: int, seed: int = 0) -> list[int]:
    """REC baseline: random cross-model expert pairing."""
    rng = np.random.default_rng(seed)
    return list(rng.permutation(n))


def aggregate_traffic(
    da: np.ndarray, db: np.ndarray, pair: list[int]
) -> np.ndarray:
    """Aggregated device-level traffic matrix D_new for a colocation choice.

    Device i hosts a-expert i and b-expert pair[i]; model b's traffic is
    re-indexed into device space and summed with model a's.
    """
    da = strip_diagonal(da)
    db = strip_diagonal(db)
    p = np.asarray(pair)
    # b-expert pair[i] lives on device i  =>  device-level b-traffic
    # D_b_dev[i, j] = db[pair[i], pair[j]].
    db_dev = db[np.ix_(p, p)]
    return da + db_dev


# -- multi-tenant (N > 2) grouping -----------------------------------------
#
# Nothing in Thm 6.1/6.2 is specific to two models: colocating one expert of
# each of N tenants per device aggregates their traffic, and minimizing the
# aggregated b_max still minimizes inference time on homogeneous clusters.
# The N-way assignment problem (an N-dimensional matching, NP-hard for N>=3)
# is decoupled exactly like §7.2 decouples case 4: fold tenants in one at a
# time, bottleneck-matching the next tenant's experts against the groups
# built so far. Each fold is the paper's case-I/case-II pairing with the
# current aggregate playing the role of "model a".

def aurora_grouping(traffics: list[np.ndarray],
                    use_case1: bool = True) -> list[tuple[int, ...]]:
    """Greedy k-way expert grouping over N tenants' traffic matrices.

    Returns ``groups`` with ``groups[g][t]`` = the tenant-t expert hosted on
    device slot g; tenant 0 anchors the slots (``groups[g][0] == g``). Each
    fold uses the Thm 6.2 sort-pairing fast path when send == recv for both
    the aggregate and the incoming tenant (``use_case1``), else bottleneck
    matching with the case-II weight. For two tenants this reproduces
    ``aurora_pairing`` exactly.
    """
    if not traffics:
        raise ValueError("aurora_grouping needs at least one tenant")
    mats = [strip_diagonal(d) for d in traffics]
    n = mats[0].shape[0]
    for d in mats:
        if d.shape != (n, n):
            raise ValueError("all tenants must have equal expert counts "
                             f"(got {[m.shape[0] for m in mats]})")
    groups = [[g] for g in range(n)]
    agg = mats[0].copy()
    for dt in mats[1:]:
        s_agg, r_agg = agg.sum(axis=1), agg.sum(axis=0)
        s_t, r_t = dt.sum(axis=1), dt.sum(axis=0)
        if (use_case1 and np.allclose(s_agg, r_agg)
                and np.allclose(s_t, r_t)):
            pair = case1_pairing(s_agg, s_t)
        else:
            w = np.maximum(s_agg[:, None] + s_t[None, :],
                           r_agg[:, None] + r_t[None, :])
            pair, _ = bottleneck_perfect_matching(w)
        p = np.asarray(pair)
        agg = agg + dt[np.ix_(p, p)]
        for g in range(n):
            groups[g].append(int(pair[g]))
    return [tuple(g) for g in groups]


def random_grouping(n: int, n_tenants: int,
                    seed: int = 0) -> list[tuple[int, ...]]:
    """REC baseline generalized: tenant 0 anchors slots, every other tenant's
    experts land on uniformly random slots."""
    rng = np.random.default_rng(seed)
    perms = [np.arange(n)] + [rng.permutation(n)
                              for _ in range(n_tenants - 1)]
    return [tuple(int(perms[t][g]) for t in range(n_tenants))
            for g in range(n)]


def group_pairs(groups: list[tuple[int, ...]]) -> list[list[int]]:
    """Per-tenant slot->expert permutations of a grouping: ``out[t][g]`` is
    the tenant-t expert on slot g (``out[0]`` is the identity anchor)."""
    if not groups:
        return []
    return [[g[t] for g in groups] for t in range(len(groups[0]))]


def aggregate_traffic_multi(traffics: list[np.ndarray],
                            groups: list[tuple[int, ...]]) -> np.ndarray:
    """Device-level traffic aggregated over N colocated tenants.

    Slot g hosts expert ``groups[g][t]`` of each tenant t; every tenant's
    matrix is re-indexed into slot space and summed. For two tenants with
    ``groups[g] == (g, pair[g])`` this equals ``aggregate_traffic``.
    """
    mats = [strip_diagonal(d) for d in traffics]
    n = mats[0].shape[0]
    agg = np.zeros((n, n))
    for t, dt in enumerate(mats):
        p = np.asarray([g[t] for g in groups])
        agg += dt[np.ix_(p, p)]
    return agg


def lina_packing(d: np.ndarray) -> tuple[np.ndarray, list[tuple[int, int]]]:
    """Lina-style same-model packing: two experts of ONE model per device.

    Pairs the most popular expert with the least popular (the paper's
    description of Lina's placement), producing an n/2-device deployment.
    Returns (merged n/2 x n/2 traffic matrix, expert pairs).
    """
    d = strip_diagonal(d)
    n = d.shape[0]
    if n % 2 != 0:
        raise ValueError("lina packing needs an even expert count")
    loads = d.sum(axis=0)
    order = np.argsort(-loads, kind="stable")
    pairs = [(int(order[k]), int(order[n - 1 - k])) for k in range(n // 2)]
    # Merge traffic of paired experts into single devices.
    group = np.empty(n, dtype=np.int64)
    for g, (e1, e2) in enumerate(pairs):
        group[e1] = g
        group[e2] = g
    m = n // 2
    merged = np.zeros((m, m))
    for i in range(n):
        for j in range(n):
            merged[group[i], group[j]] += d[i, j]
    np.fill_diagonal(merged, 0.0)  # colocated experts exchange on-device
    return merged, pairs
