"""Cluster model: GPUs (or TPU slices) behind a non-blocking "big switch".

The paper (§2.4) models the inter-accelerator network as a single big switch:
every device i has a full-duplex link of bandwidth ``B_i`` into the fabric and
the fabric itself is non-blocking — contention only happens at endpoints.

``DeviceType`` carries both network bandwidth and a relative compute speed
(FLOPs ratio); the paper assumes a device with higher compute never has lower
bandwidth (footnote 2), which ``Cluster.validate`` enforces.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class DeviceType:
    """A class of accelerator in the cluster."""

    name: str
    bandwidth: float  # link bandwidth into the switch (bytes or tokens / unit time)
    compute: float    # relative compute throughput (tokens / unit time, 1.0 = reference)

    def __post_init__(self) -> None:
        if self.bandwidth <= 0 or self.compute <= 0:
            raise ValueError(f"DeviceType {self.name}: bandwidth/compute must be > 0")


# The paper's evaluation setup (§8.1): homogeneous 100 Gbps; heterogeneous
# tiers of 100/80/50/40 Gbps ordered high→low performance. Compute scales are
# chosen proportional to tier (the paper orders tiers by overall performance).
V100G = DeviceType("gpu-100g", bandwidth=100.0, compute=1.00)
V80G = DeviceType("gpu-80g", bandwidth=80.0, compute=0.80)
V50G = DeviceType("gpu-50g", bandwidth=50.0, compute=0.50)
V40G = DeviceType("gpu-40g", bandwidth=40.0, compute=0.40)

PAPER_HET_TIERS: tuple[DeviceType, ...] = (V100G, V80G, V50G, V40G)


@dataclasses.dataclass(frozen=True)
class Cluster:
    """An ordered set of devices behind one big switch.

    ``devices[i]`` is the device that hosts expert slot ``i`` (before any
    assignment optimization; assignment permutes the expert→device map).
    """

    devices: tuple[DeviceType, ...]

    def __post_init__(self) -> None:
        if not self.devices:
            raise ValueError("Cluster must have at least one device")

    @property
    def n(self) -> int:
        return len(self.devices)

    @property
    def bandwidths(self) -> tuple[float, ...]:
        return tuple(d.bandwidth for d in self.devices)

    @property
    def computes(self) -> tuple[float, ...]:
        return tuple(d.compute for d in self.devices)

    @property
    def homogeneous(self) -> bool:
        return len({(d.bandwidth, d.compute) for d in self.devices}) == 1

    def validate(self) -> None:
        """Paper footnote 2: higher compute never pairs with lower bandwidth."""
        by_compute = sorted(self.devices, key=lambda d: d.compute)
        for lo, hi in zip(by_compute, by_compute[1:]):
            if hi.bandwidth < lo.bandwidth:
                raise ValueError(
                    f"device {hi.name} has more compute but less bandwidth than {lo.name}"
                )

    def sorted_indices_by_performance(self) -> list[int]:
        """Device indices from highest to lowest performance (Thm 5.1 order)."""
        return sorted(
            range(self.n),
            key=lambda i: (self.devices[i].compute, self.devices[i].bandwidth),
            reverse=True,
        )

    def subcluster(self, indices: Sequence[int]) -> "Cluster":
        """Survivor view for degraded re-planning: the same physical devices
        re-indexed 0..k-1 in the given order. ``indices`` are positions into
        this cluster; duplicates and out-of-range entries are rejected."""
        idx = [int(i) for i in indices]
        if len(set(idx)) != len(idx):
            raise ValueError(f"subcluster indices contain duplicates: {idx}")
        for i in idx:
            if not 0 <= i < self.n:
                raise ValueError(
                    f"subcluster index {i} out of range for {self.n} devices")
        return Cluster(devices=tuple(self.devices[i] for i in idx))


def homogeneous_cluster(n: int, device: DeviceType = V100G) -> Cluster:
    return Cluster(devices=(device,) * n)


def heterogeneous_cluster(
    n: int, tiers: Sequence[DeviceType] = PAPER_HET_TIERS
) -> Cluster:
    """Paper §8.1: equal device count per tier. ``n`` must divide evenly."""
    if n % len(tiers) != 0:
        raise ValueError(f"n={n} not divisible by {len(tiers)} tiers")
    per = n // len(tiers)
    devs: list[DeviceType] = []
    for t in tiers:
        devs.extend([t] * per)
    return Cluster(devices=tuple(devs))
