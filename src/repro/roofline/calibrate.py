"""Scan-aware cost calibration for the dry-run roofline.

XLA's ``cost_analysis()`` counts a ``while``-loop (``lax.scan``) body ONCE,
regardless of trip count — verified empirically on this container (a scan of
8 matmuls reports the FLOPs of 1). Our stacks scan over layer blocks, so raw
dry-run numbers undercount by ~n_layers.

Fix: lower small **calibration variants** of each config — every segment at
count 1, then each segment bumped to count 2 — and solve

    cost(c_1 … c_k) = base + Σ_s c_s · block_s

exactly from the differences. Remainder segments (e.g. gemma3's trailing
``LL``) are approximated as ``len(kinds_rem)/len(kinds_full)`` of the
matching full block — ≤2 of 62 layers, noise-level. The same extrapolation
applies to FLOPs, HBM bytes, and HLO-parsed collective bytes (collectives
inside the scan body also appear once in the HLO text).

All lowerings keep the REAL input shape and mesh, so embedding/LM-head and
batch-dependent costs sit in the (exact) base term.
"""

from __future__ import annotations

import dataclasses

from .analysis import collective_bytes_from_hlo


def _counts_of(cfg) -> list:
    from repro.models.transformer import segments_of
    segs = list(segments_of(cfg))
    if cfg.is_encoder_decoder:
        from repro.models.transformer import Segment
        segs.append(Segment(("B",), cfg.n_encoder_layers))  # encoder stack
    return segs


def _variant(cfg, seg_counts: list[int]):
    """Rebuild a config whose segments have the given counts (no remainder
    segments). seg_counts aligns with the NON-remainder segments of cfg plus
    the encoder segment for enc-dec archs."""
    if cfg.is_encoder_decoder:
        dec, enc = seg_counts
        return dataclasses.replace(cfg, n_layers=dec, n_encoder_layers=enc)
    if cfg.family == "hybrid":
        (k,) = seg_counts
        return dataclasses.replace(cfg, n_layers=k * (cfg.hybrid_period + 1))
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        kd, ke = seg_counts
        return dataclasses.replace(
            cfg, n_layers=kd + ke,
            moe=dataclasses.replace(cfg.moe, first_dense_layers=kd))
    if cfg.layer_pattern:
        (k,) = seg_counts
        return dataclasses.replace(cfg,
                                   n_layers=k * len(cfg.layer_pattern))
    (k,) = seg_counts
    return dataclasses.replace(cfg, n_layers=k)


def _main_segments(cfg) -> tuple[list, list]:
    """(main segments with their true counts, remainder segments)."""
    segs = _counts_of(cfg)
    if cfg.is_encoder_decoder:
        return segs, []          # [decoder, encoder], both exact
    if cfg.family == "hybrid" or cfg.layer_pattern:
        main, rem = segs[:1], segs[1:]
        return main, rem
    return segs, []


def _measure(cfg, shape, mesh, moe_impl: str) -> dict:
    import jax
    from repro.compat import set_mesh
    from repro.launch import specs as S

    # UNROLLED lowering: a lax.scan body is cost-counted once regardless of
    # trip count, so calibration variants must not scan. Donation matches
    # the full-model lowering (dryrun.run_one).
    step_fn, args = S.lowering_args(cfg, shape, mesh, moe_impl=moe_impl,
                                    unroll=True)
    donate = (0, 1) if shape.kind == "train" else (2,)
    with set_mesh(mesh):
        compiled = jax.jit(step_fn, donate_argnums=donate).lower(*args) \
            .compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": float(coll["link_bytes"]),
            "collective_by_kind": coll["by_kind"]}


def calibrated_cost(cfg, shape, mesh, moe_impl: str = "ep") -> dict:
    """Scan-corrected per-device cost terms for the REAL config.

    Returns {"flops", "bytes", "collective_bytes", "detail"}.
    """
    main, rem = _main_segments(cfg)
    k = len(main)
    base_counts = [1] * k
    base = _measure(_variant(cfg, base_counts), shape, mesh, moe_impl)
    blocks = []
    for i in range(k):
        counts = list(base_counts)
        counts[i] = 2
        hi = _measure(_variant(cfg, counts), shape, mesh, moe_impl)
        blocks.append({key: hi[key] - base[key]
                       for key in ("flops", "bytes", "collective_bytes")})

    out = {}
    for key in ("flops", "bytes", "collective_bytes"):
        total = base[key]
        for i, seg in enumerate(main):
            total += (seg.count - 1) * blocks[i][key]
        # Remainder segments ≈ fraction of the matching main block.
        for seg in rem:
            frac = len(seg.kinds) / len(main[0].kinds)
            total += seg.count * frac * blocks[0][key]
        out[key] = max(total, 0.0)
    out["detail"] = {"base": base, "blocks": blocks,
                     "main_counts": [s.count for s in main],
                     "remainder": [(list(s.kinds), s.count) for s in rem]}
    return out
