"""Three-term roofline from the compiled dry-run (deliverable g).

This container is CPU-only (TPU v5e is the TARGET), so instead of measured
MFU we derive, per (arch × shape × mesh):

    compute    = HLO_FLOPs        / (chips × peak_FLOP/s)
    memory     = HLO_bytes        / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis — they are parsed from the compiled HLO text by summing
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op. The dominant term is the bottleneck the
§Perf loop iterates on. We also record MODEL_FLOPS = 6·N·D (6·N_active·D
for MoE) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which
catches remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import re


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e hardware constants (per chip)."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s per ICI link


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\([^)]*\)|\S+)\s+"                    # result shape (maybe a tuple)
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Histogram of bytes moved per collective kind.

    Sizes are HLO result-shape sizes of the per-device (SPMD) program;
    '-done' halves of async pairs are skipped so each collective counts
    once. ``link_bytes`` approximates per-device ICI traffic: all-reduce
    counts twice its shape (ring reduce+broadcast), everything else once.
    """
    out: dict = {"total_bytes": 0.0, "link_bytes": 0.0, "by_kind": {},
                 "counts": {}}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # Skip the -done half of async pairs (shape repeats the -start's).
        tail = hlo_text[m.start():m.start() + 200]
        if f"{kind}-done" in tail.split("(")[0]:
            continue
        b = _shape_bytes(shape_str)
        out["by_kind"][kind] = out["by_kind"].get(kind, 0) + b
        out["counts"][kind] = out["counts"].get(kind, 0) + 1
        out["total_bytes"] += b
        out["link_bytes"] += 2 * b if kind == "all-reduce" else b
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D inference (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token per seq


def roofline_report(flops: float, hbm_bytes: float, collective_bytes: float,
                    n_devices: int, cfg=None, shape=None,
                    hw: HW = V5E, arg_bytes: float | None = None,
                    out_bytes: float | None = None) -> dict:
    """The three roofline terms (seconds) + bottleneck + useful-FLOP ratio.

    ``flops``/``hbm_bytes``/``collective_bytes`` are PER-DEVICE (XLA's
    cost_analysis reports the per-device SPMD program — verified on this
    container against known-FLOP matmuls), so each term divides by one
    chip's peak. ``n_devices`` scales MODEL_FLOPS (a global quantity) down
    to per-device for the useful-compute ratio.
    """
    compute_s = flops / hw.peak_flops if flops else 0.0
    memory_s = hbm_bytes / hw.hbm_bw if hbm_bytes else 0.0
    collective_s = collective_bytes / hw.link_bw if collective_bytes else 0.0
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "none"
    rec = dict(terms, dominant=dominant)
    if arg_bytes is not None:
        # Analytic HBM floor: every live byte (weights + state in, state
        # out) touched exactly once. cost_analysis "bytes accessed" counts
        # fusion-internal traffic and the CPU backend's f32 weight converts,
        # so it is an upper bound; the floor brackets the truth from below.
        rec["memory_floor_s"] = (arg_bytes + (out_bytes or 0.0)) / hw.hbm_bw
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        rec["model_flops"] = mf
        rec["useful_flop_ratio"] = (mf / n_devices / flops) if flops else 0.0
    return rec
