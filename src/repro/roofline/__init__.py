"""Roofline analysis from compiled dry-run artifacts."""

from .analysis import (HW, collective_bytes_from_hlo, model_flops,
                       roofline_report)

__all__ = ["HW", "collective_bytes_from_hlo", "model_flops",
           "roofline_report"]
