"""AdamW, implemented directly in JAX (no external optimizer dep).

``state_dtype`` controls the m/v moment precision: float32 by default,
bfloat16 for >100B-parameter configs so optimizer state fits HBM on the
production mesh (DESIGN.md §6 memory budget; the dry-run records both).
Moments inherit the parameter sharding, so optimizer state is automatically
ZeRO-sharded wherever parameters are sharded (experts → EP axis, etc.).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step with global-norm clipping. Returns (params, state)."""
    step = state["step"] + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim > 1:                       # no decay on norms/bias vectors
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    # The params pytree itself contains tuples (stacked segments), so we
    # flatten once rather than tree-mapping with tuple returns.
    lp, treedef = jax.tree.flatten(params)
    lg = jax.tree.leaves(grads)
    lm = jax.tree.leaves(state["m"])
    lv = jax.tree.leaves(state["v"])
    triples = [upd(p, g, m, v) for p, g, m, v in zip(lp, lg, lm, lv)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in triples])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in triples])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in triples])
    return new_params, {"m": new_m, "v": new_v, "step": step}
