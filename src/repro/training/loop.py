"""Train step + loop.

``make_train_step`` builds the jitted (params, opt, batch) → (params, opt,
metrics) function with explicit in/out shardings on a mesh (or unsharded on
a single device). The step is exactly what the multi-pod dry-run lowers for
``train_4k``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import Model, cross_entropy
from .optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: int = 0


def make_loss_fn(model: Model, aux_weight: float = 0.01) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch)
        # Next-token LM objective; labels are inputs shifted left.
        tokens = batch["tokens"]
        loss = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg.vocab)
        return loss + aux_weight * aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    aux_weight: float = 0.01) -> Callable:
    loss_fn = make_loss_fn(model, aux_weight)

    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return params, opt, metrics

    return train_step


def train_loop(model: Model, data, steps: int,
               opt_cfg: AdamWConfig | None = None, jit: bool = True,
               log_every: int = 10, params=None,
               aux_weight: float = 0.01) -> tuple[TrainState, list[dict]]:
    """Single-host training loop (examples / integration tests)."""
    opt_cfg = opt_cfg or AdamWConfig()
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, opt_cfg)
    step_fn = make_train_step(model, opt_cfg, aux_weight)
    if jit:
        step_fn = jax.jit(step_fn)

    history = []
    t0 = time.time()
    for i, batch in zip(range(steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall"] = time.time() - t0
            history.append(m)
    return TrainState(params=params, opt=opt, step=steps), history
