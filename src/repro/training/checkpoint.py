"""Checkpointing: params/optimizer pytrees → .npz + a JSON treedef manifest.

No external serialization deps (offline container); arrays are gathered to
host. Restore rebuilds the exact pytree and re-shards via device_put when a
sharding pytree is supplied.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree, step: int | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "step": step,
                "dtypes": [str(np.asarray(l).dtype) for l in leaves]}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore_checkpoint(path: str, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (shape/dtype template)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like_tree)
    if len(leaves) != len(data.files):
        raise ValueError(
            f"checkpoint has {len(data.files)} leaves, template "
            f"has {len(leaves)}")
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    tree = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def checkpoint_step(path: str) -> int | None:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("step")
