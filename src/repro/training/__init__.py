"""Training substrate: optimizer, data pipeline, checkpointing, train step."""

from .optim import AdamWConfig, adamw_init, adamw_update
from .data import SyntheticLMData
from .checkpoint import restore_checkpoint, save_checkpoint
from .loop import TrainState, make_train_step, train_loop

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "SyntheticLMData",
           "restore_checkpoint", "save_checkpoint", "TrainState",
           "make_train_step", "train_loop"]
