"""Synthetic LM data pipeline (offline container: no external corpora).

Deterministic, seeded, learnable structure: a fixed-order-2 Markov chain
over the vocab with Zipf-distributed unigram marginals. The chain gives the
model actual signal, so "loss decreases over a few hundred steps" is a
meaningful integration test rather than noise-fitting. Batches stream as
host numpy and are device_put with the train-step's input sharding.
"""

from __future__ import annotations

import numpy as np


class SyntheticLMData:
    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 frames_dim: int = 0, frames_len: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.frames_dim = frames_dim
        self.frames_len = frames_len
        rng = np.random.default_rng(seed)
        v_eff = min(vocab, 4096)            # transition table stays small
        self.v_eff = v_eff
        # Zipf marginal + sparse per-state transition kernels.
        marg = 1.0 / np.arange(1, v_eff + 1) ** 1.1
        self.marg = marg / marg.sum()
        self.n_succ = 8
        self.succ = rng.integers(0, v_eff, size=(v_eff, self.n_succ))
        self.rng = rng

    def _sample_tokens(self, n: int) -> np.ndarray:
        rng = self.rng
        out = np.empty((n, self.seq_len), np.int32)
        state = rng.choice(self.v_eff, size=n, p=self.marg)
        for t in range(self.seq_len):
            out[:, t] = state
            # 80%: follow the chain; 20%: resample from the marginal.
            follow = rng.random(n) < 0.8
            nxt = self.succ[state, rng.integers(0, self.n_succ, n)]
            resample = rng.choice(self.v_eff, size=n, p=self.marg)
            state = np.where(follow, nxt, resample)
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        tokens = self._sample_tokens(self.batch)
        batch = {"tokens": tokens}
        if self.frames_dim:
            batch["frames"] = self.rng.standard_normal(
                (self.batch, self.frames_len, self.frames_dim),
                dtype=np.float32)
        return batch
