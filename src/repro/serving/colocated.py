"""Aurora dual-model colocated serving (§6 of the paper, as a runtime).

The paper's key utilization insight: colocate experts of **two different
models** so one model's compute overlaps the other model's all-to-all
(Fig 3b) — same-model colocation (Lina) stays blocked behind its own
synchronous all-to-all.

TPU realization (DESIGN.md §3): GPU SM time-slicing has no literal TPU
analogue, so the interleave is program-level — a single jitted
``colocated_step`` evaluates model A's and model B's steps in one XLA
program. A's MoE dispatch collectives (all-to-all / ppermute rounds) are
async pairs in XLA (``collective-permute-start/done``), and B's compute is
data-independent of them, so XLA's latency-hiding scheduler hoists B's FFN
between A's start/done — the Fig 3(b) schedule, compiled in.

The expert→device pairing comes from ``AuroraPlanner.plan_colocated``; it is
applied by permuting model B's expert→device map before weights are placed
(``apply_pairing``), so the aggregated per-device traffic matches the plan.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import PlanError
from repro.models import Model
from repro.serving.config import (EngineConfig, TenantSpec, coerce_config,
                                  scale_admission)
from repro.serving.telemetry import record_adoption


def _pool_config_for(config: EngineConfig, spec: TenantSpec | None):
    """Single-tenant pool view of a (possibly multi-tenant) EngineConfig:
    kernels off (the engine kernelizes each model once, up front — the pool
    re-kernelizing would double-wrap), the tenant's own ``TenantSpec``
    installed so the pool stamps its SLO deadlines, and the shared admission
    budget scaled by the tenant's ``rate_share``."""
    admission = config.resolve_admission()
    if spec is not None and spec.rate_share is not None:
        admission = scale_admission(admission, spec.rate_share)
    # The resolved policy subsumes the chunk/budget/bucket shorthand —
    # clear those fields so the replaced config stays self-consistent.
    return dataclasses.replace(
        config, kernels=False, admission=admission, prefill_chunk=None,
        step_token_budget=None, bucket_policy="pow2",
        tenants=(spec,) if spec is not None else ())


def apply_pairing(params_b, pair: list[int], cfg_b):
    """Permute model B's expert dimension so b-expert ``pair[k]`` lands on
    the device slot of a-expert k (the planner's colocation choice).

    Expert weights live as stacked leaves (count, E, ...) under "experts";
    the router's output columns (count, d, E) are permuted with the SAME
    permutation so routing follows the moved experts — placement changes
    which device an expert sits on, never the function the model computes.
    Applying ``inverse_pair(pair)`` afterwards round-trips to the original
    params exactly.
    """
    perm = jnp.asarray(np.asarray(pair), jnp.int32)

    def permute(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if "experts" in names:
            return jnp.take(leaf, perm, axis=1)   # (count, E, …) — E axis
        if names and names[-1] == "router":
            return jnp.take(leaf, perm, axis=-1)  # (count, d, E) — columns
        return leaf

    return jax.tree_util.tree_map_with_path(permute, params_b)


def inverse_pair(pair: list[int]) -> list[int]:
    """The permutation that undoes ``apply_pairing(·, pair, ·)``."""
    inv = [0] * len(pair)
    for slot, expert in enumerate(pair):
        inv[expert] = slot
    return inv


def reseat_pairing(params, old_pair, new_pair, cfg):
    """Re-realize a slot->expert pairing IN PLACE: undo the permutation
    currently baked into ``params`` and apply the new one.

    This is the one shared placement-identity checkpoint for every adoption
    path (dual-model re-pair, N-tenant re-group, tenant churn): both maps
    must be permutations of the expert ids — anything else would silently
    duplicate or drop experts — and given that, the round-trip is exact:
    ``apply_pairing`` moves expert weights and router columns together, so
    the composed function (and every emitted token) is unchanged. Param
    shapes are preserved, so jitted steps do not recompile.
    """
    old_pair, new_pair = list(old_pair), list(new_pair)
    n = len(old_pair)
    ids = list(range(n))
    for name, pair in (("current", old_pair), ("new", new_pair)):
        if sorted(pair) != ids:
            raise PlanError(
                f"{name} pairing {pair} is not a permutation of the expert "
                f"ids 0..{n - 1} — re-seating it would duplicate/drop "
                "experts")
    if old_pair == new_pair:
        return params
    restored = apply_pairing(params, inverse_pair(old_pair), cfg)
    return apply_pairing(restored, new_pair, cfg)


def build_lockstep_step(models: list[Model], collect_stats: bool,
                        jit: bool = True):
    """One fused decode step over N tenants — the Fig 3(b) interleave for
    any tenant count: every tenant's dispatch collectives and every other
    tenant's compute live in the same XLA program, so the latency-hiding
    scheduler overlaps them.

    Returns ``step(params_list, tokens_list, caches_list, masks_list)``
    yielding ``(logits_list, caches_list)`` — plus a per-tenant routing-
    stats list when ``collect_stats`` (the live traffic signal for
    re-planning). ``masks_list`` holds one (B,) bool row mask per tenant:
    vacant slots (and the slot of an in-flight chunked prefill) freeze
    their cache rows. The caches list is donated; the compiled program is
    shared by the dual-model and N-tenant engines.
    """
    if collect_stats:
        def step(params, tokens, caches, masks):
            outs = [m.decode_step_stats(p, t, c, mask)
                    for m, p, t, c, mask
                    in zip(models, params, tokens, caches, masks)]
            return ([o[0] for o in outs], [o[1] for o in outs],
                    [o[2] for o in outs])
    else:
        def step(params, tokens, caches, masks):
            outs = [m.decode_step(p, t, c, mask)
                    for m, p, t, c, mask
                    in zip(models, params, tokens, caches, masks)]
            return [o[0] for o in outs], [o[1] for o in outs]
    return jax.jit(step, donate_argnums=(2,)) if jit else step


@dataclasses.dataclass
class ColocatedEngine:
    """Serve two models on one mesh with interleaved steps."""

    model_a: Model
    model_b: Model
    params_a: object
    params_b: object
    jit: bool = True

    def __post_init__(self):
        def step(params_a, params_b, tok_a, tok_b, cache_a, cache_b):
            # One XLA program: A's dispatch collectives overlap B's compute
            # (and vice versa) under the latency-hiding scheduler.
            logits_a, cache_a = self.model_a.decode_step(
                params_a, tok_a, cache_a)
            logits_b, cache_b = self.model_b.decode_step(
                params_b, tok_b, cache_b)
            return logits_a, logits_b, cache_a, cache_b

        def prefill(params_a, params_b, in_a, in_b, cache_a, cache_b):
            la, cache_a = self.model_a.prefill(params_a, in_a, cache_a)
            lb, cache_b = self.model_b.prefill(params_b, in_b, cache_b)
            return la, lb, cache_a, cache_b

        # Donate both models' caches (in-place update, no per-step copy).
        self._step = (jax.jit(step, donate_argnums=(4, 5))
                      if self.jit else step)
        self._prefill = (jax.jit(prefill, donate_argnums=(4, 5))
                         if self.jit else prefill)

    def serve(self, prompts_a, prompts_b, max_new_tokens: int,
              cache_cap: int):
        """Greedy-decode both batches in lockstep. Returns (out_a, out_b)."""
        ta = jnp.asarray(prompts_a, jnp.int32)
        tb = jnp.asarray(prompts_b, jnp.int32)
        ca = self.model_a.init_cache(ta.shape[0], cache_cap)
        cb = self.model_b.init_cache(tb.shape[0], cache_cap)
        la, lb, ca, cb = self._prefill(self.params_a, self.params_b,
                                       {"tokens": ta}, {"tokens": tb},
                                       ca, cb)
        va, vb = self.model_a.cfg.vocab, self.model_b.cfg.vocab
        tok_a = jnp.argmax(la[:, -1:, :va], -1).astype(jnp.int32)
        tok_b = jnp.argmax(lb[:, -1:, :vb], -1).astype(jnp.int32)
        out_a, out_b = [tok_a], [tok_b]
        for _ in range(max_new_tokens - 1):
            la, lb, ca, cb = self._step(self.params_a, self.params_b,
                                        tok_a, tok_b, ca, cb)
            tok_a = jnp.argmax(la[:, :, :va], -1).astype(jnp.int32)
            tok_b = jnp.argmax(lb[:, :, :vb], -1).astype(jnp.int32)
            out_a.append(tok_a)
            out_b.append(tok_b)
        return (jnp.concatenate(out_a, 1), jnp.concatenate(out_b, 1))


class ColocatedContinuousEngine:
    """Continuous batching for the Aurora dual-model runtime.

    Two ``ContinuousEngine`` slot pools — one per model — admit from their
    own request queues and decode in **lockstep** through one fused jitted
    step, preserving the Fig 3(b) overlap: model A's dispatch collectives
    and model B's compute live in the same XLA program, so the latency-
    hiding scheduler interleaves them exactly as in ``ColocatedEngine``,
    while each pool's slots fill and drain independently with traffic.

    With ``replan=OnlineReplanner(...)`` the engine closes the paper's
    §2.4 loop online: both pools harvest live per-layer routing counts into
    ``TrafficMonitor``s, and every ``replan.interval`` lockstep decodes the
    planner re-pairs from the live traces. An adopted plan is applied IN
    PLACE by un-permuting model B's experts with ``inverse_pair`` and
    re-permuting with the new pairing — placement-only, so a mid-stream
    re-plan never changes any emitted token.
    """

    def __init__(self, model_a: Model, model_b: Model, params_a, params_b,
                 batch_slots: int, cache_cap: int,
                 config: EngineConfig | None = None,
                 pair: list[int] | None = None,
                 replan=None, monitor_halflife: float = 128.0, **legacy):
        from .engine import ContinuousEngine
        from .monitor import TrafficMonitor

        config = coerce_config(config, legacy, type(self).__name__)
        self.config = config
        # Kernelize BEFORE the pools and the fused lockstep step are built,
        # so both models' decode/prefill programs share the path.
        model_a = config.kernelize(model_a)
        model_b = config.kernelize(model_b)
        self.model_a, self.model_b = model_a, model_b
        self.replan = replan
        self.monitor_a = self.monitor_b = None
        if replan is not None:
            ca, cb = model_a.cfg, model_b.cfg
            if (ca.moe is None or cb.moe is None
                    or ca.moe.n_experts != cb.moe.n_experts):
                raise ValueError(
                    "online re-planning needs two MoE models with equal "
                    "expert counts (the pairing is expert<->expert)")
            if model_a.n_moe_layers != model_b.n_moe_layers:
                raise ValueError(
                    "online re-planning needs equal MoE layer counts "
                    "(the planner simulates the traces layer-by-layer)")
            self.monitor_a = TrafficMonitor(
                ca.moe.n_experts, model_a.n_moe_layers, name=ca.arch_id,
                halflife=monitor_halflife)
            self.monitor_b = TrafficMonitor(
                cb.moe.n_experts, model_b.n_moe_layers, name=cb.arch_id,
                halflife=monitor_halflife)
        # The pairing currently REALIZED in pool_b's params (identity unless
        # the caller already applied a plan) — what a re-plan must undo.
        n_e = model_b.cfg.moe.n_experts if model_b.cfg.moe else 0
        self.pair = list(pair) if pair is not None else list(range(n_e))
        self.plan = None                        # last adopted online plan
        if self.monitor_b is not None:
            # Pool B's routing stats arrive in SLOT space (apply_pairing
            # permuted the router columns); the monitor translates them
            # back to original expert ids so the planner's traces and the
            # candidate pairings stay in one frame.
            self.monitor_b.slot_to_expert = list(self.pair)

        # Each pool gets a single-tenant view of the config: kernels off
        # (the models above are already kernelized), its own TenantSpec for
        # SLO deadlines, and its rate-share slice of the admission budget.
        if config.tenants and len(config.tenants) != 2:
            raise ValueError(
                f"{len(config.tenants)} TenantSpecs for the dual-model "
                "engine — declare exactly two (model A then model B) or "
                "none")
        self.tenant_specs = (list(config.tenants) if config.tenants
                             else [None, None])
        self.pool_a = ContinuousEngine(
            model_a, params_a, batch_slots, cache_cap,
            config=_pool_config_for(config, self.tenant_specs[0]),
            monitor=self.monitor_a)
        self.pool_b = ContinuousEngine(
            model_b, params_b, batch_slots, cache_cap,
            config=_pool_config_for(config, self.tenant_specs[1]),
            monitor=self.monitor_b)

        self._jit = config.jit
        self._step_wrapper = config.step_wrapper or (lambda fn: fn)
        self._telemetry = config.telemetry
        if replan is not None and config.telemetry is not None \
                and getattr(replan, "telemetry", None) is None:
            replan.telemetry = config.telemetry
        self._build_lockstep()
        self.decode_steps = 0

    def _build_lockstep(self) -> None:
        """(Re)build the fused lockstep step from the pools' current models
        (rebuilt when a distributed engine swaps ppermute rounds)."""
        step = self._step_wrapper(build_lockstep_step(
            [self.model_a, self.model_b],
            collect_stats=self.replan is not None, jit=self._jit))
        if self._telemetry is not None:
            step = self._telemetry.wrap_step(
                step, "lockstep_decode",
                rounds=lambda: getattr(self.model_a.pc, "aurora_rounds",
                                       None))
        self._step = step

    @property
    def replan_events(self) -> list:
        return [] if self.replan is None else self.replan.events

    def adopt(self, plan) -> None:
        """Adopt a colocation ``Plan`` mid-stream: re-realize its pairing on
        pool B's params via the shared ``reseat_pairing`` checkpoint.
        Placement-only — param shapes are unchanged, so the jitted step does
        not recompile and in-flight token streams are unaffected."""
        new_pair = list(plan.pair)
        self.pool_b.params = reseat_pairing(self.pool_b.params, self.pair,
                                            new_pair, self.model_b.cfg)
        self.pair = new_pair
        if self.monitor_b is not None:
            self.monitor_b.slot_to_expert = list(new_pair)
        self.plan = plan
        record_adoption(self._telemetry, "pairing", step=self.decode_steps,
                        pair=new_pair)

    def _adopt_online(self, plan) -> None:
        """Seam for the replanner loop (the distributed engine layers an
        Aurora-rounds refresh on top)."""
        self.adopt(plan)

    def _maybe_replan(self) -> None:
        new = self.replan.maybe_replan(self.decode_steps, self.monitor_a,
                                       self.monitor_b, self.pair)
        if new is not None:
            self._adopt_online(new)

    def step(self) -> bool:
        """Admit into both pools, then one fused lockstep decode."""
        tel = self._telemetry
        if tel is None or not tel.enabled:
            return self._step_impl()
        with tel.span("lockstep_step", step=self.decode_steps):
            return self._step_impl()

    def _step_impl(self) -> bool:
        a, b = self.pool_a, self.pool_b
        worked_a = a._admit_tick()
        worked_b = b._admit_tick()
        if a.num_active == 0 and b.num_active == 0:
            return worked_a or worked_b
        mask_a = np.array([r is not None for r in a.slots], bool)
        mask_b = np.array([r is not None for r in b.slots], bool)
        masks = [jnp.asarray(mask_a), jnp.asarray(mask_b)]
        if self.replan is not None:
            (la, lb), (a.cache, b.cache), (sa, sb) = self._step(
                [a.params, b.params], [a.tokens, b.tokens],
                [a.cache, b.cache], masks)
            self.monitor_a.observe(sa, mask_a)
            self.monitor_b.observe(sb, mask_b)
        else:
            (la, lb), (a.cache, b.cache) = self._step(
                [a.params, b.params], [a.tokens, b.tokens],
                [a.cache, b.cache], masks)
        self.decode_steps += 1
        a._postdecode(la)
        b._postdecode(lb)
        if self.replan is not None:
            self._maybe_replan()
        return True

    def serve(self, reqs_a, reqs_b):
        """Run both request streams to completion (``Request.arrival`` in
        lockstep-step units). Returns (reqs_a, reqs_b)."""
        from .engine import serve_stream

        serve_stream(self.step, [(self.pool_a, reqs_a),
                                 (self.pool_b, reqs_b)])
        return reqs_a, reqs_b


class MultiTenantContinuousEngine:
    """Continuous batching over N >= 2 colocated tenants.

    The dual-model engine generalized: one ``ContinuousEngine`` slot pool per
    tenant, each admitting from its own queue under the shared chunked-
    prefill budget scheduler, all decoding in lockstep through ONE fused
    jitted step (``build_lockstep_step``) — N tenants' collectives and
    compute in a single XLA program, so any tenant's dispatch overlaps the
    others' FFNs (the paper's §6 insight, N-fold).

    ``groups[g] = (e_0, .., e_{N-1})`` is the planner's k-way colocation
    choice (``AuroraPlanner.plan_multi``): tenant t's expert ``groups[g][t]``
    occupies device slot g, tenant 0 anchoring the slots
    (``groups[g][0] == g``). The grouping is REALIZED by the caller permuting
    tenant t's params with ``apply_pairing(params_t, [g[t] for g in groups])``
    for t >= 1 — placement-only, so any grouping serves identical tokens.

    Alternatively, construct from ``config.tenants`` alone: each
    ``TenantSpec`` carries its model, LOGICAL params, placement ``pair``,
    and SLO targets; the engine realizes the pairings, derives ``groups``,
    and gives every tenant's pool its own deadline source and rate-share
    slice of the admission budget — the same spec type ``admit_tenant``
    accepts for live churn.

    With ``replan=OnlineReplanner(...)`` every tenant harvests live routing
    counts into its own ``TrafficMonitor`` and the planner periodically
    re-groups from the N live traces (``OnlineReplanner.maybe_regroup``);
    an adopted grouping is applied in place per tenant via
    ``inverse_pair`` + ``apply_pairing`` — again placement-only, token
    streams provably unchanged.
    """

    def __init__(self, models: list[Model] | None = None,
                 params: list | None = None, batch_slots: int = None,
                 cache_cap: int = None, config: EngineConfig | None = None,
                 groups: list[tuple[int, ...]] | None = None,
                 replan=None, monitor_halflife: float = 128.0, **legacy):
        from .engine import ContinuousEngine
        from .monitor import TrafficMonitor

        if batch_slots is None or cache_cap is None:
            raise TypeError("batch_slots and cache_cap are required")
        config = coerce_config(config, legacy, type(self).__name__)
        self.config = config
        if models is None:
            # Config-driven construction: every tenant (model, params,
            # placement) comes from one validated TenantSpec — the same
            # spec type admit_tenant accepts for live churn.
            if params is not None:
                raise ValueError("params without models — declare both on "
                                 "the TenantSpecs instead")
            if groups is not None:
                raise ValueError("groups conflict with config-driven "
                                 "construction — declare per-tenant "
                                 "placement via TenantSpec.pair")
            specs = list(config.tenants)
            if len(specs) < 2:
                raise ValueError(
                    "config-driven construction needs >= 2 TenantSpecs in "
                    "config.tenants (or pass models/params explicitly)")
            missing = [t for t, s in enumerate(specs)
                       if s.model is None or s.params is None]
            if missing:
                raise ValueError(
                    f"TenantSpecs {missing} declare no model/params — "
                    "config-driven construction needs both on every spec")
            models = [s.model for s in specs]
            n_e = (models[0].cfg.moe.n_experts
                   if models[0].cfg.moe is not None else 0)
            pairs = [list(s.pair) if s.pair is not None else list(range(n_e))
                     for s in specs]
            if pairs and pairs[0] != list(range(len(pairs[0]))):
                raise ValueError("tenant 0 anchors the slots — its "
                                 "TenantSpec.pair must be the identity")
            # Specs carry LOGICAL (unpermuted) params; realize each
            # tenant's placement here, exactly as admit_tenant does.
            params = [apply_pairing(s.params, p, s.model.cfg)
                      if p != list(range(len(p))) else s.params
                      for s, p in zip(specs, pairs)]
            groups = [tuple(p[g] for p in pairs)
                      for g in range(len(pairs[0]) if pairs else 0)] or None
        else:
            specs = list(config.tenants)
            if specs and len(specs) != len(models):
                raise ValueError(f"{len(specs)} TenantSpecs for "
                                 f"{len(models)} models — declare one per "
                                 "tenant or none")
        self.tenant_specs = specs or [None] * len(models)
        if len(models) < 2:
            raise ValueError("MultiTenantContinuousEngine needs >= 2 tenants "
                             "(use ContinuousEngine for one)")
        if len(params) != len(models):
            raise ValueError("one params tree per model required")
        models = [config.kernelize(m) for m in models]
        self.models = list(models)
        self.n_tenants = len(models)
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.monitor_halflife = monitor_halflife
        self.replan = replan
        self.monitors = None
        if replan is not None:
            cfgs = [m.cfg for m in models]
            if (any(c.moe is None for c in cfgs)
                    or len({c.moe.n_experts for c in cfgs}) != 1):
                raise ValueError(
                    "online re-grouping needs MoE tenants with equal expert "
                    "counts (the grouping is expert<->expert)")
            if len({m.n_moe_layers for m in models}) != 1:
                raise ValueError(
                    "online re-grouping needs equal MoE layer counts "
                    "(the planner simulates the traces layer-by-layer)")
            self.monitors = [
                TrafficMonitor(c.moe.n_experts, m.n_moe_layers,
                               name=f"{c.arch_id}#{t}",
                               halflife=monitor_halflife)
                for t, (m, c) in enumerate(zip(models, cfgs))]
        # The grouping currently REALIZED in the tenants' params (identity
        # unless the caller already applied a plan) — what a re-group must
        # undo, per tenant.
        n_e = models[0].cfg.moe.n_experts if models[0].cfg.moe else 0
        if groups is None:
            groups = [(g,) * self.n_tenants for g in range(n_e)]
        self.groups = [tuple(g) for g in groups]
        if n_e and len(self.groups) != n_e:
            raise ValueError(f"{len(self.groups)} groups for {n_e} experts "
                             "(one device slot per expert group)")
        for g, grp in enumerate(self.groups):
            if len(grp) != self.n_tenants:
                raise ValueError(f"group {g} has {len(grp)} entries for "
                                 f"{self.n_tenants} tenants")
            if grp[0] != g:
                raise ValueError("tenant 0 anchors the slots: "
                                 f"groups[{g}][0] must be {g}, got {grp[0]}")
        for t in range(1, self.n_tenants):
            if sorted(g[t] for g in self.groups) != list(
                    range(len(self.groups))):
                raise ValueError(f"tenant {t}'s column is not a permutation "
                                 "of the expert ids (each expert must sit "
                                 "on exactly one slot)")
        self.plan = None                        # last adopted online plan
        if self.monitors is not None:
            # Permuted tenants' routing stats arrive in SLOT space; each
            # monitor translates back to original expert ids (tenant 0 is
            # the identity anchor and needs no translation).
            for t in range(1, self.n_tenants):
                self.monitors[t].slot_to_expert = [g[t] for g in self.groups]

        # Each pool gets a single-tenant view of the config (kernels off,
        # its own TenantSpec, rate-share-scaled admission budget).
        self.pools = [
            ContinuousEngine(m, p, batch_slots, cache_cap,
                             config=_pool_config_for(
                                 config, self.tenant_specs[t]),
                             monitor=(self.monitors[t] if self.monitors
                                      else None))
            for t, (m, p) in enumerate(zip(models, params))]
        self._jit = config.jit
        self._step_wrapper = config.step_wrapper or (lambda fn: fn)
        self._telemetry = config.telemetry
        if replan is not None and config.telemetry is not None \
                and getattr(replan, "telemetry", None) is None:
            replan.telemetry = config.telemetry
        self._build_lockstep()
        self.decode_steps = 0

    def _build_lockstep(self) -> None:
        """(Re)build the fused N-tenant step from the pools' current models
        (rebuilt when a distributed engine swaps ppermute rounds)."""
        step = self._step_wrapper(build_lockstep_step(
            self.models, collect_stats=self.replan is not None,
            jit=self._jit))
        if self._telemetry is not None:
            step = self._telemetry.wrap_step(
                step, "lockstep_decode",
                rounds=lambda: getattr(self.models[0].pc, "aurora_rounds",
                                       None))
        self._step = step

    @property
    def replan_events(self) -> list:
        return [] if self.replan is None else self.replan.events

    def tenant_pair(self, t: int) -> list[int]:
        """Slot->expert permutation realized for tenant t."""
        return [g[t] for g in self.groups]

    def adopt(self, plan) -> None:
        """Adopt a k-way grouping ``Plan`` mid-stream: per tenant, re-seat
        the realized slot->expert permutation to the plan's via the shared
        ``reseat_pairing`` checkpoint. Placement-only — param shapes are
        unchanged, so the fused step does not recompile and in-flight token
        streams are unaffected. All tenants are re-seated (tenant 0 included
        — after churn the anchor column need not be the identity)."""
        new_groups = [tuple(g) for g in plan.groups]
        if any(len(g) != self.n_tenants for g in new_groups):
            raise PlanError(
                f"plan groups tenant count {[len(g) for g in new_groups]} "
                f"!= engine tenant count {self.n_tenants}")
        for t in range(self.n_tenants):
            old_p = self.tenant_pair(t)
            new_p = [g[t] for g in new_groups]
            if old_p == new_p:
                continue
            self.pools[t].params = reseat_pairing(
                self.pools[t].params, old_p, new_p, self.models[t].cfg)
            if self.monitors is not None:
                self.monitors[t].slot_to_expert = new_p
        self.groups = new_groups
        self.plan = plan
        record_adoption(self._telemetry, "grouping", step=self.decode_steps,
                        groups=new_groups)

    def _adopt_online(self, plan) -> None:
        """Seam for the replanner loop (the distributed engine layers an
        Aurora-rounds refresh on top)."""
        self.adopt(plan)

    def _maybe_regroup(self) -> None:
        new = self.replan.maybe_regroup(self.decode_steps, self.monitors,
                                        self.groups)
        if new is not None:
            self._adopt_online(new)

    # -- tenant churn ------------------------------------------------------
    def admit_tenant(self, model: Model | TenantSpec = None, params=None, *,
                     pair: list[int] | None = None,
                     spec: TenantSpec | None = None) -> int:
        """Admit a NEW tenant into the live pool. Returns its tenant index.

        Accepts either a ``TenantSpec`` carrying model/params/pair (and SLO
        targets, honored by the new pool) — the same validated type
        ``EngineConfig.tenants`` uses for construction — or the unbundled
        ``(model, params, pair=...)`` spelling. ``params`` arrive in the
        LOGICAL (unpermuted) frame; ``pair`` is the slot->expert placement
        to realize for it (identity when omitted) — realized here via
        ``apply_pairing``, exactly as the constructor documents for
        pre-permuted tenants. The tenant gets its own slot pool and (under
        a replanner) its own ``TrafficMonitor``; colocation groups gain its
        column, and the replanner re-derives the grouping online once the
        fresh monitor passes warmup. Every existing tenant's pool, cache,
        and token stream are untouched — admission is placement-only for
        the incumbents (lockstep rows are tenant-independent).
        """
        from .engine import ContinuousEngine
        from .monitor import TrafficMonitor

        if isinstance(model, TenantSpec):
            if spec is not None:
                raise ValueError("pass the TenantSpec once (positionally "
                                 "or as spec=, not both)")
            spec, model = model, None
        if spec is not None:
            if model is not None or params is not None or pair is not None:
                raise ValueError("pass EITHER a TenantSpec or unbundled "
                                 "model/params/pair — not both")
            if spec.model is None or spec.params is None:
                raise ValueError("admit_tenant needs model and params on "
                                 "the TenantSpec")
            model, params, pair = spec.model, spec.params, spec.pair
        elif model is None or params is None:
            raise TypeError("admit_tenant needs a TenantSpec or "
                            "(model, params)")
        model = self.config.kernelize(model)
        cfg = model.cfg
        n_e = len(self.groups)
        if self.replan is not None:
            if cfg.moe is None or cfg.moe.n_experts != n_e:
                raise ValueError(
                    "online re-grouping needs MoE tenants with equal expert "
                    "counts (the grouping is expert<->expert)")
            if model.n_moe_layers != self.models[0].n_moe_layers:
                raise ValueError(
                    "online re-grouping needs equal MoE layer counts "
                    "(the planner simulates the traces layer-by-layer)")
        pair = list(pair) if pair is not None else list(range(n_e))
        if n_e and sorted(pair) != list(range(n_e)):
            raise ValueError(f"pair {pair} is not a permutation of the "
                             f"expert ids 0..{n_e - 1}")
        if pair != list(range(n_e)):
            params = apply_pairing(params, pair, cfg)
        t = self.n_tenants
        monitor = None
        if self.monitors is not None:
            monitor = TrafficMonitor(n_e, model.n_moe_layers,
                                     name=f"{cfg.arch_id}#{t}",
                                     halflife=self.monitor_halflife)
            monitor.slot_to_expert = list(pair)
            self.monitors.append(monitor)
        self.models.append(model)
        self.pools.append(ContinuousEngine(
            model, params, self.batch_slots, self.cache_cap,
            config=_pool_config_for(self.config, spec), monitor=monitor))
        self.tenant_specs.append(spec)
        self.groups = [grp + (pair[g],) for g, grp in enumerate(self.groups)]
        self.n_tenants += 1
        self._build_lockstep()
        return t

    def evict_tenant(self, t: int):
        """Remove tenant ``t`` from the live pool. Returns its (detached)
        slot pool — still serveable standalone.

        The tenant's queued and in-flight requests leave with its pool
        (drain the engine first to finish them); its colocation column,
        monitor, and lockstep row disappear. Every surviving tenant's pool
        and cache are untouched, so eviction is placement-only for them —
        their token streams are byte-identical to a churn-free run.
        """
        if not 0 <= t < self.n_tenants:
            raise ValueError(f"no tenant {t} (have {self.n_tenants})")
        if self.n_tenants <= 1:
            raise ValueError("cannot evict the last tenant")
        if self.n_tenants == 2 and self.replan is not None:
            raise ValueError(
                "eviction would leave one tenant — nothing to re-group; "
                "drop the replanner (or keep >= 2 tenants)")
        pool = self.pools.pop(t)
        self.models.pop(t)
        self.tenant_specs.pop(t)
        if self.monitors is not None:
            self.monitors.pop(t)
        self.groups = [g[:t] + g[t + 1:] for g in self.groups]
        self.n_tenants -= 1
        self._build_lockstep()
        return pool

    def step(self) -> bool:
        """Admit into every pool, then one fused lockstep decode."""
        tel = self._telemetry
        if tel is None or not tel.enabled:
            return self._step_impl()
        with tel.span("lockstep_step", step=self.decode_steps,
                      tenants=self.n_tenants):
            return self._step_impl()

    def _step_impl(self) -> bool:
        worked = [p._admit_tick() for p in self.pools]
        if all(p.num_active == 0 for p in self.pools):
            return any(worked)
        masks = [np.array([r is not None for r in p.slots], bool)
                 for p in self.pools]
        jmasks = [jnp.asarray(m) for m in masks]
        if self.replan is not None:
            logits, caches, stats = self._step(
                [p.params for p in self.pools],
                [p.tokens for p in self.pools],
                [p.cache for p in self.pools], jmasks)
            for mon, s, mask in zip(self.monitors, stats, masks):
                mon.observe(s, mask)
        else:
            logits, caches = self._step(
                [p.params for p in self.pools],
                [p.tokens for p in self.pools],
                [p.cache for p in self.pools], jmasks)
        for p, c in zip(self.pools, caches):
            p.cache = c
        self.decode_steps += 1
        for p, lg in zip(self.pools, logits):
            p._postdecode(lg)
        if self.replan is not None:
            self._maybe_regroup()
        return True

    def serve(self, streams: list[list]) -> list[list]:
        """Run one request stream per tenant to completion
        (``Request.arrival`` in lockstep-step units)."""
        from .engine import serve_stream

        if len(streams) != self.n_tenants:
            raise ValueError(f"{self.n_tenants} tenants need "
                             f"{self.n_tenants} request streams")
        serve_stream(self.step, list(zip(self.pools, streams)))
        return streams
