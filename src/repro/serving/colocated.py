"""Aurora dual-model colocated serving (§6 of the paper, as a runtime).

The paper's key utilization insight: colocate experts of **two different
models** so one model's compute overlaps the other model's all-to-all
(Fig 3b) — same-model colocation (Lina) stays blocked behind its own
synchronous all-to-all.

TPU realization (DESIGN.md §3): GPU SM time-slicing has no literal TPU
analogue, so the interleave is program-level — a single jitted
``colocated_step`` evaluates model A's and model B's steps in one XLA
program. A's MoE dispatch collectives (all-to-all / ppermute rounds) are
async pairs in XLA (``collective-permute-start/done``), and B's compute is
data-independent of them, so XLA's latency-hiding scheduler hoists B's FFN
between A's start/done — the Fig 3(b) schedule, compiled in.

The expert→device pairing comes from ``AuroraPlanner.plan_colocated``; it is
applied by permuting model B's expert→device map before weights are placed
(``apply_pairing``), so the aggregated per-device traffic matches the plan.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


def apply_pairing(params_b, pair: list[int], cfg_b):
    """Permute model B's expert dimension so b-expert ``pair[k]`` lands on
    the device slot of a-expert k (the planner's colocation choice).

    Expert weights live as stacked leaves (count, E, ...) under "experts".
    """
    perm = jnp.asarray(np.asarray(pair), jnp.int32)

    def permute(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if "experts" in names:
            return jnp.take(leaf, perm, axis=1)   # (count, E, …) — E axis
        return leaf

    return jax.tree_util.tree_map_with_path(permute, params_b)


@dataclasses.dataclass
class ColocatedEngine:
    """Serve two models on one mesh with interleaved steps."""

    model_a: Model
    model_b: Model
    params_a: object
    params_b: object
    jit: bool = True

    def __post_init__(self):
        def step(params_a, params_b, tok_a, tok_b, cache_a, cache_b):
            # One XLA program: A's dispatch collectives overlap B's compute
            # (and vice versa) under the latency-hiding scheduler.
            logits_a, cache_a = self.model_a.decode_step(
                params_a, tok_a, cache_a)
            logits_b, cache_b = self.model_b.decode_step(
                params_b, tok_b, cache_b)
            return logits_a, logits_b, cache_a, cache_b

        def prefill(params_a, params_b, in_a, in_b, cache_a, cache_b):
            la, cache_a = self.model_a.prefill(params_a, in_a, cache_a)
            lb, cache_b = self.model_b.prefill(params_b, in_b, cache_b)
            return la, lb, cache_a, cache_b

        # Donate both models' caches (in-place update, no per-step copy).
        self._step = (jax.jit(step, donate_argnums=(4, 5))
                      if self.jit else step)
        self._prefill = (jax.jit(prefill, donate_argnums=(4, 5))
                         if self.jit else prefill)

    def serve(self, prompts_a, prompts_b, max_new_tokens: int,
              cache_cap: int):
        """Greedy-decode both batches in lockstep. Returns (out_a, out_b)."""
        ta = jnp.asarray(prompts_a, jnp.int32)
        tb = jnp.asarray(prompts_b, jnp.int32)
        ca = self.model_a.init_cache(ta.shape[0], cache_cap)
        cb = self.model_b.init_cache(tb.shape[0], cache_cap)
        la, lb, ca, cb = self._prefill(self.params_a, self.params_b,
                                       {"tokens": ta}, {"tokens": tb},
                                       ca, cb)
        va, vb = self.model_a.cfg.vocab, self.model_b.cfg.vocab
        tok_a = jnp.argmax(la[:, -1:, :va], -1).astype(jnp.int32)
        tok_b = jnp.argmax(lb[:, -1:, :vb], -1).astype(jnp.int32)
        out_a, out_b = [tok_a], [tok_b]
        for _ in range(max_new_tokens - 1):
            la, lb, ca, cb = self._step(self.params_a, self.params_b,
                                        tok_a, tok_b, ca, cb)
            tok_a = jnp.argmax(la[:, :, :va], -1).astype(jnp.int32)
            tok_b = jnp.argmax(lb[:, :, :vb], -1).astype(jnp.int32)
            out_a.append(tok_a)
            out_b.append(tok_b)
        return (jnp.concatenate(out_a, 1), jnp.concatenate(out_b, 1))
