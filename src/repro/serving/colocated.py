"""Aurora dual-model colocated serving (§6 of the paper, as a runtime).

The paper's key utilization insight: colocate experts of **two different
models** so one model's compute overlaps the other model's all-to-all
(Fig 3b) — same-model colocation (Lina) stays blocked behind its own
synchronous all-to-all.

TPU realization (DESIGN.md §3): GPU SM time-slicing has no literal TPU
analogue, so the interleave is program-level — a single jitted
``colocated_step`` evaluates model A's and model B's steps in one XLA
program. A's MoE dispatch collectives (all-to-all / ppermute rounds) are
async pairs in XLA (``collective-permute-start/done``), and B's compute is
data-independent of them, so XLA's latency-hiding scheduler hoists B's FFN
between A's start/done — the Fig 3(b) schedule, compiled in.

The expert→device pairing comes from ``AuroraPlanner.plan_colocated``; it is
applied by permuting model B's expert→device map before weights are placed
(``apply_pairing``), so the aggregated per-device traffic matches the plan.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


def apply_pairing(params_b, pair: list[int], cfg_b):
    """Permute model B's expert dimension so b-expert ``pair[k]`` lands on
    the device slot of a-expert k (the planner's colocation choice).

    Expert weights live as stacked leaves (count, E, ...) under "experts";
    the router's output columns (count, d, E) are permuted with the SAME
    permutation so routing follows the moved experts — placement changes
    which device an expert sits on, never the function the model computes.
    Applying ``inverse_pair(pair)`` afterwards round-trips to the original
    params exactly.
    """
    perm = jnp.asarray(np.asarray(pair), jnp.int32)

    def permute(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if "experts" in names:
            return jnp.take(leaf, perm, axis=1)   # (count, E, …) — E axis
        if names and names[-1] == "router":
            return jnp.take(leaf, perm, axis=-1)  # (count, d, E) — columns
        return leaf

    return jax.tree_util.tree_map_with_path(permute, params_b)


def inverse_pair(pair: list[int]) -> list[int]:
    """The permutation that undoes ``apply_pairing(·, pair, ·)``."""
    inv = [0] * len(pair)
    for slot, expert in enumerate(pair):
        inv[expert] = slot
    return inv


def build_lockstep_step(models: list[Model], collect_stats: bool,
                        jit: bool = True):
    """One fused decode step over N tenants — the Fig 3(b) interleave for
    any tenant count: every tenant's dispatch collectives and every other
    tenant's compute live in the same XLA program, so the latency-hiding
    scheduler overlaps them.

    Returns ``step(params_list, tokens_list, caches_list, masks_list)``
    yielding ``(logits_list, caches_list)`` — plus a per-tenant routing-
    stats list when ``collect_stats`` (the live traffic signal for
    re-planning). ``masks_list`` holds one (B,) bool row mask per tenant:
    vacant slots (and the slot of an in-flight chunked prefill) freeze
    their cache rows. The caches list is donated; the compiled program is
    shared by the dual-model and N-tenant engines.
    """
    if collect_stats:
        def step(params, tokens, caches, masks):
            outs = [m.decode_step_stats(p, t, c, mask)
                    for m, p, t, c, mask
                    in zip(models, params, tokens, caches, masks)]
            return ([o[0] for o in outs], [o[1] for o in outs],
                    [o[2] for o in outs])
    else:
        def step(params, tokens, caches, masks):
            outs = [m.decode_step(p, t, c, mask)
                    for m, p, t, c, mask
                    in zip(models, params, tokens, caches, masks)]
            return [o[0] for o in outs], [o[1] for o in outs]
    return jax.jit(step, donate_argnums=(2,)) if jit else step


@dataclasses.dataclass
class ColocatedEngine:
    """Serve two models on one mesh with interleaved steps."""

    model_a: Model
    model_b: Model
    params_a: object
    params_b: object
    jit: bool = True

    def __post_init__(self):
        def step(params_a, params_b, tok_a, tok_b, cache_a, cache_b):
            # One XLA program: A's dispatch collectives overlap B's compute
            # (and vice versa) under the latency-hiding scheduler.
            logits_a, cache_a = self.model_a.decode_step(
                params_a, tok_a, cache_a)
            logits_b, cache_b = self.model_b.decode_step(
                params_b, tok_b, cache_b)
            return logits_a, logits_b, cache_a, cache_b

        def prefill(params_a, params_b, in_a, in_b, cache_a, cache_b):
            la, cache_a = self.model_a.prefill(params_a, in_a, cache_a)
            lb, cache_b = self.model_b.prefill(params_b, in_b, cache_b)
            return la, lb, cache_a, cache_b

        # Donate both models' caches (in-place update, no per-step copy).
        self._step = (jax.jit(step, donate_argnums=(4, 5))
                      if self.jit else step)
        self._prefill = (jax.jit(prefill, donate_argnums=(4, 5))
                         if self.jit else prefill)

    def serve(self, prompts_a, prompts_b, max_new_tokens: int,
              cache_cap: int):
        """Greedy-decode both batches in lockstep. Returns (out_a, out_b)."""
        ta = jnp.asarray(prompts_a, jnp.int32)
        tb = jnp.asarray(prompts_b, jnp.int32)
        ca = self.model_a.init_cache(ta.shape[0], cache_cap)
        cb = self.model_b.init_cache(tb.shape[0], cache_cap)
        la, lb, ca, cb = self._prefill(self.params_a, self.params_b,
                                       {"tokens": ta}, {"tokens": tb},
                                       ca, cb)
        va, vb = self.model_a.cfg.vocab, self.model_b.cfg.vocab
        tok_a = jnp.argmax(la[:, -1:, :va], -1).astype(jnp.int32)
        tok_b = jnp.argmax(lb[:, -1:, :vb], -1).astype(jnp.int32)
        out_a, out_b = [tok_a], [tok_b]
        for _ in range(max_new_tokens - 1):
            la, lb, ca, cb = self._step(self.params_a, self.params_b,
                                        tok_a, tok_b, ca, cb)
            tok_a = jnp.argmax(la[:, :, :va], -1).astype(jnp.int32)
            tok_b = jnp.argmax(lb[:, :, :vb], -1).astype(jnp.int32)
            out_a.append(tok_a)
            out_b.append(tok_b)
        return (jnp.concatenate(out_a, 1), jnp.concatenate(out_b, 1))


class ColocatedContinuousEngine:
    """Continuous batching for the Aurora dual-model runtime.

    Two ``ContinuousEngine`` slot pools — one per model — admit from their
    own request queues and decode in **lockstep** through one fused jitted
    step, preserving the Fig 3(b) overlap: model A's dispatch collectives
    and model B's compute live in the same XLA program, so the latency-
    hiding scheduler interleaves them exactly as in ``ColocatedEngine``,
    while each pool's slots fill and drain independently with traffic.

    With ``replan=OnlineReplanner(...)`` the engine closes the paper's
    §2.4 loop online: both pools harvest live per-layer routing counts into
    ``TrafficMonitor``s, and every ``replan.interval`` lockstep decodes the
    planner re-pairs from the live traces. An adopted plan is applied IN
    PLACE by un-permuting model B's experts with ``inverse_pair`` and
    re-permuting with the new pairing — placement-only, so a mid-stream
    re-plan never changes any emitted token.
    """

    def __init__(self, model_a: Model, model_b: Model, params_a, params_b,
                 batch_slots: int, cache_cap: int,
                 prefill_len: int | None = None, jit: bool = True,
                 prefill_chunk: int | None = None,
                 step_token_budget: int | None = None,
                 bucket_policy="pow2", pair: list[int] | None = None,
                 replan=None, monitor_halflife: float = 128.0,
                 kernels=False, step_wrapper=None):
        from .engine import ContinuousEngine
        from .monitor import TrafficMonitor

        if kernels:
            # Kernelize BEFORE the pools and the fused lockstep step are
            # built, so both models' decode/prefill programs share the path.
            model_a = model_a.with_kernels(kernels)
            model_b = model_b.with_kernels(kernels)
        self.model_a, self.model_b = model_a, model_b
        self.replan = replan
        self.monitor_a = self.monitor_b = None
        if replan is not None:
            ca, cb = model_a.cfg, model_b.cfg
            if (ca.moe is None or cb.moe is None
                    or ca.moe.n_experts != cb.moe.n_experts):
                raise ValueError(
                    "online re-planning needs two MoE models with equal "
                    "expert counts (the pairing is expert<->expert)")
            if model_a.n_moe_layers != model_b.n_moe_layers:
                raise ValueError(
                    "online re-planning needs equal MoE layer counts "
                    "(the planner simulates the traces layer-by-layer)")
            self.monitor_a = TrafficMonitor(
                ca.moe.n_experts, model_a.n_moe_layers, name=ca.arch_id,
                halflife=monitor_halflife)
            self.monitor_b = TrafficMonitor(
                cb.moe.n_experts, model_b.n_moe_layers, name=cb.arch_id,
                halflife=monitor_halflife)
        # The pairing currently REALIZED in pool_b's params (identity unless
        # the caller already applied a plan) — what a re-plan must undo.
        n_e = model_b.cfg.moe.n_experts if model_b.cfg.moe else 0
        self.pair = list(pair) if pair is not None else list(range(n_e))
        self.plan = None                        # last adopted online plan
        if self.monitor_b is not None:
            # Pool B's routing stats arrive in SLOT space (apply_pairing
            # permuted the router columns); the monitor translates them
            # back to original expert ids so the planner's traces and the
            # candidate pairings stay in one frame.
            self.monitor_b.slot_to_expert = list(self.pair)

        kw = dict(prefill_len=prefill_len, jit=jit,
                  prefill_chunk=prefill_chunk,
                  step_token_budget=step_token_budget,
                  bucket_policy=bucket_policy, step_wrapper=step_wrapper)
        self.pool_a = ContinuousEngine(model_a, params_a, batch_slots,
                                       cache_cap, monitor=self.monitor_a,
                                       **kw)
        self.pool_b = ContinuousEngine(model_b, params_b, batch_slots,
                                       cache_cap, monitor=self.monitor_b,
                                       **kw)

        self._jit = jit
        self._step_wrapper = step_wrapper or (lambda fn: fn)
        self._build_lockstep()
        self.decode_steps = 0

    def _build_lockstep(self) -> None:
        """(Re)build the fused lockstep step from the pools' current models
        (rebuilt when a distributed engine swaps ppermute rounds)."""
        self._step = self._step_wrapper(build_lockstep_step(
            [self.model_a, self.model_b],
            collect_stats=self.replan is not None, jit=self._jit))

    @property
    def replan_events(self) -> list:
        return [] if self.replan is None else self.replan.events

    def _maybe_replan(self) -> None:
        new = self.replan.maybe_replan(self.decode_steps, self.monitor_a,
                                       self.monitor_b, self.pair)
        if new is None:
            return
        # Placement-only re-pair: undo the realized permutation, apply the
        # new one. Params shapes are unchanged, so the jitted step does not
        # recompile and in-flight token streams are unaffected.
        restored = apply_pairing(self.pool_b.params, inverse_pair(self.pair),
                                 self.model_b.cfg)
        self.pool_b.params = apply_pairing(restored, list(new.pair),
                                           self.model_b.cfg)
        self.pair = list(new.pair)
        self.monitor_b.slot_to_expert = list(new.pair)
        self.plan = new

    def step(self) -> bool:
        """Admit into both pools, then one fused lockstep decode."""
        a, b = self.pool_a, self.pool_b
        worked_a = a._admit_tick()
        worked_b = b._admit_tick()
        if a.num_active == 0 and b.num_active == 0:
            return worked_a or worked_b
        mask_a = np.array([r is not None for r in a.slots], bool)
        mask_b = np.array([r is not None for r in b.slots], bool)
        masks = [jnp.asarray(mask_a), jnp.asarray(mask_b)]
        if self.replan is not None:
            (la, lb), (a.cache, b.cache), (sa, sb) = self._step(
                [a.params, b.params], [a.tokens, b.tokens],
                [a.cache, b.cache], masks)
            self.monitor_a.observe(sa, mask_a)
            self.monitor_b.observe(sb, mask_b)
        else:
            (la, lb), (a.cache, b.cache) = self._step(
                [a.params, b.params], [a.tokens, b.tokens],
                [a.cache, b.cache], masks)
        self.decode_steps += 1
        a._postdecode(la)
        b._postdecode(lb)
        if self.replan is not None:
            self._maybe_replan()
        return True

    def serve(self, reqs_a, reqs_b):
        """Run both request streams to completion (``Request.arrival`` in
        lockstep-step units). Returns (reqs_a, reqs_b)."""
        from .engine import serve_stream

        serve_stream(self.step, [(self.pool_a, reqs_a),
                                 (self.pool_b, reqs_b)])
        return reqs_a, reqs_b


class MultiTenantContinuousEngine:
    """Continuous batching over N >= 2 colocated tenants.

    The dual-model engine generalized: one ``ContinuousEngine`` slot pool per
    tenant, each admitting from its own queue under the shared chunked-
    prefill budget scheduler, all decoding in lockstep through ONE fused
    jitted step (``build_lockstep_step``) — N tenants' collectives and
    compute in a single XLA program, so any tenant's dispatch overlaps the
    others' FFNs (the paper's §6 insight, N-fold).

    ``groups[g] = (e_0, .., e_{N-1})`` is the planner's k-way colocation
    choice (``AuroraPlanner.plan_multi``): tenant t's expert ``groups[g][t]``
    occupies device slot g, tenant 0 anchoring the slots
    (``groups[g][0] == g``). The grouping is REALIZED by the caller permuting
    tenant t's params with ``apply_pairing(params_t, [g[t] for g in groups])``
    for t >= 1 — placement-only, so any grouping serves identical tokens.

    With ``replan=OnlineReplanner(...)`` every tenant harvests live routing
    counts into its own ``TrafficMonitor`` and the planner periodically
    re-groups from the N live traces (``OnlineReplanner.maybe_regroup``);
    an adopted grouping is applied in place per tenant via
    ``inverse_pair`` + ``apply_pairing`` — again placement-only, token
    streams provably unchanged.
    """

    def __init__(self, models: list[Model], params: list, batch_slots: int,
                 cache_cap: int, prefill_len: int | None = None,
                 jit: bool = True, prefill_chunk: int | None = None,
                 step_token_budget: int | None = None,
                 bucket_policy="pow2",
                 groups: list[tuple[int, ...]] | None = None,
                 replan=None, monitor_halflife: float = 128.0,
                 kernels=False, step_wrapper=None):
        from .engine import ContinuousEngine
        from .monitor import TrafficMonitor

        if len(models) < 2:
            raise ValueError("MultiTenantContinuousEngine needs >= 2 tenants "
                             "(use ContinuousEngine for one)")
        if len(params) != len(models):
            raise ValueError("one params tree per model required")
        if kernels:
            models = [m.with_kernels(kernels) for m in models]
        self.models = list(models)
        self.n_tenants = len(models)
        self.replan = replan
        self.monitors = None
        if replan is not None:
            cfgs = [m.cfg for m in models]
            if (any(c.moe is None for c in cfgs)
                    or len({c.moe.n_experts for c in cfgs}) != 1):
                raise ValueError(
                    "online re-grouping needs MoE tenants with equal expert "
                    "counts (the grouping is expert<->expert)")
            if len({m.n_moe_layers for m in models}) != 1:
                raise ValueError(
                    "online re-grouping needs equal MoE layer counts "
                    "(the planner simulates the traces layer-by-layer)")
            self.monitors = [
                TrafficMonitor(c.moe.n_experts, m.n_moe_layers,
                               name=f"{c.arch_id}#{t}",
                               halflife=monitor_halflife)
                for t, (m, c) in enumerate(zip(models, cfgs))]
        # The grouping currently REALIZED in the tenants' params (identity
        # unless the caller already applied a plan) — what a re-group must
        # undo, per tenant.
        n_e = models[0].cfg.moe.n_experts if models[0].cfg.moe else 0
        if groups is None:
            groups = [(g,) * self.n_tenants for g in range(n_e)]
        self.groups = [tuple(g) for g in groups]
        if n_e and len(self.groups) != n_e:
            raise ValueError(f"{len(self.groups)} groups for {n_e} experts "
                             "(one device slot per expert group)")
        for g, grp in enumerate(self.groups):
            if len(grp) != self.n_tenants:
                raise ValueError(f"group {g} has {len(grp)} entries for "
                                 f"{self.n_tenants} tenants")
            if grp[0] != g:
                raise ValueError("tenant 0 anchors the slots: "
                                 f"groups[{g}][0] must be {g}, got {grp[0]}")
        for t in range(1, self.n_tenants):
            if sorted(g[t] for g in self.groups) != list(
                    range(len(self.groups))):
                raise ValueError(f"tenant {t}'s column is not a permutation "
                                 "of the expert ids (each expert must sit "
                                 "on exactly one slot)")
        self.plan = None                        # last adopted online plan
        if self.monitors is not None:
            # Permuted tenants' routing stats arrive in SLOT space; each
            # monitor translates back to original expert ids (tenant 0 is
            # the identity anchor and needs no translation).
            for t in range(1, self.n_tenants):
                self.monitors[t].slot_to_expert = [g[t] for g in self.groups]

        kw = dict(prefill_len=prefill_len, jit=jit,
                  prefill_chunk=prefill_chunk,
                  step_token_budget=step_token_budget,
                  bucket_policy=bucket_policy, step_wrapper=step_wrapper)
        self.pools = [
            ContinuousEngine(m, p, batch_slots, cache_cap,
                             monitor=(self.monitors[t] if self.monitors
                                      else None), **kw)
            for t, (m, p) in enumerate(zip(models, params))]
        self._jit = jit
        self._step_wrapper = step_wrapper or (lambda fn: fn)
        self._build_lockstep()
        self.decode_steps = 0

    def _build_lockstep(self) -> None:
        """(Re)build the fused N-tenant step from the pools' current models
        (rebuilt when a distributed engine swaps ppermute rounds)."""
        self._step = self._step_wrapper(build_lockstep_step(
            self.models, collect_stats=self.replan is not None,
            jit=self._jit))

    @property
    def replan_events(self) -> list:
        return [] if self.replan is None else self.replan.events

    def tenant_pair(self, t: int) -> list[int]:
        """Slot->expert permutation realized for tenant t."""
        return [g[t] for g in self.groups]

    def _maybe_regroup(self) -> None:
        new = self.replan.maybe_regroup(self.decode_steps, self.monitors,
                                        self.groups)
        if new is None:
            return
        # Placement-only re-group: per tenant, undo the realized permutation
        # and apply the new one. Param shapes are unchanged, so the fused
        # step does not recompile and in-flight token streams are unaffected.
        new_groups = [tuple(g) for g in new.groups]
        for t in range(1, self.n_tenants):
            old_p = self.tenant_pair(t)
            new_p = [g[t] for g in new_groups]
            if old_p == new_p:
                continue
            cfg = self.models[t].cfg
            restored = apply_pairing(self.pools[t].params,
                                     inverse_pair(old_p), cfg)
            self.pools[t].params = apply_pairing(restored, new_p, cfg)
            self.monitors[t].slot_to_expert = new_p
        self.groups = new_groups
        self.plan = new

    def step(self) -> bool:
        """Admit into every pool, then one fused lockstep decode."""
        worked = [p._admit_tick() for p in self.pools]
        if all(p.num_active == 0 for p in self.pools):
            return any(worked)
        masks = [np.array([r is not None for r in p.slots], bool)
                 for p in self.pools]
        jmasks = [jnp.asarray(m) for m in masks]
        if self.replan is not None:
            logits, caches, stats = self._step(
                [p.params for p in self.pools],
                [p.tokens for p in self.pools],
                [p.cache for p in self.pools], jmasks)
            for mon, s, mask in zip(self.monitors, stats, masks):
                mon.observe(s, mask)
        else:
            logits, caches = self._step(
                [p.params for p in self.pools],
                [p.tokens for p in self.pools],
                [p.cache for p in self.pools], jmasks)
        for p, c in zip(self.pools, caches):
            p.cache = c
        self.decode_steps += 1
        for p, lg in zip(self.pools, logits):
            p._postdecode(lg)
        if self.replan is not None:
            self._maybe_regroup()
        return True

    def serve(self, streams: list[list]) -> list[list]:
        """Run one request stream per tenant to completion
        (``Request.arrival`` in lockstep-step units)."""
        from .engine import serve_stream

        if len(streams) != self.n_tenants:
            raise ValueError(f"{self.n_tenants} tenants need "
                             f"{self.n_tenants} request streams")
        serve_stream(self.step, list(zip(self.pools, streams)))
        return streams
