"""Unified serving telemetry: metrics registry, structured spans, event bus.

One :class:`Telemetry` hub threads through every engine family via
``EngineConfig(telemetry=...)`` and answers the questions the paper's
§6 measurements ask of a live system — which ppermute round is the step
spending its time in, which expert is hot, which tenant is burning its
TTFT budget — without touching the compiled programs (telemetry never
changes tokens; it only watches).

Three surfaces:

* **Metrics registry** — labelled counters / gauges / histograms
  (tokens, TTFT/TPOT per tenant, expert-load imbalance and estimated
  drop rate per layer, ppermute round counts/bytes, replan / shed /
  fault / adoption totals, queue depth, per-device step-time EWMAs)
  with Prometheus text exposition and a JSON snapshot.
* **Structured spans** — nested, exception-safe ``span("decode_step")``
  records captured around the jitted steps through the existing
  ``step_wrapper`` seam, exported as JSONL and as Chrome trace-event
  JSON (open the file directly in Perfetto / ``chrome://tracing``).
  For engines with a BvN round schedule the compiled-step window is
  subdivided into per-round ``dispatch_round`` child spans (host-side
  reconstruction of the paper's Fig. 3 view: timing is the measured
  step split evenly across rounds, marked ``estimated``).
* **Event bus** — ``ShedEvent`` / ``ReplanEvent`` / ``FaultEvent`` /
  adoption / recovery notices publish into one bounded, deterministic
  stream (:mod:`repro.serving.events`) that interleaves with spans in
  the exports.

Disabled is free: ``EngineConfig(telemetry=None)`` (the default) keeps
every engine on the exact pre-telemetry code path — no wrapper, no
per-step allocation — and ``Telemetry(enabled=False)`` is a cheap
runtime off-switch (``span`` returns a shared no-op context manager).
"""

from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Callable, Iterable

from repro.serving.events import BusEvent, EventBus, RingBuffer

__all__ = [
    "Telemetry", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SpanRecord", "record_adoption", "BusEvent", "EventBus", "RingBuffer",
    "STEP_BOUNDS",
]


# --------------------------------------------------------------------------
# JSON sanitizing — bus payloads are arbitrary dataclasses (ReplanEvent
# carries tuples of tuples; ShedEvent carries the full Request).  Exports
# must never fail on a payload, so everything degrades to repr().

def _jsonable(obj, depth: int = 0):
    if depth > 6:
        return repr(obj)
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else repr(obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _jsonable(getattr(obj, f.name), depth + 1)
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): _jsonable(v, depth + 1) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        seq = list(obj)
        if len(seq) > 64:  # bound payload size (long prompts, big tables)
            return [_jsonable(v, depth + 1) for v in seq[:64]] + [
                f"... ({len(seq) - 64} more)"]
        return [_jsonable(v, depth + 1) for v in seq]
    # numpy scalars / 0-d arrays without importing numpy here
    item = getattr(obj, "item", None)
    if callable(item) and getattr(obj, "ndim", 1) == 0:
        try:
            return _jsonable(item(), depth + 1)
        except Exception:
            return repr(obj)
    tolist = getattr(obj, "tolist", None)
    if callable(tolist):
        try:
            return _jsonable(tolist(), depth + 1)
        except Exception:
            return repr(obj)
    return repr(obj)


# --------------------------------------------------------------------------
# Metrics

def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: dict[tuple, Any] = {}

    def labelsets(self):
        return self._values.items()


class Counter(_Metric):
    """Monotonic counter; ``inc(amount, **labels)``."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return float(self._values.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Last-write-wins gauge; ``set(value, **labels)``."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._values[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._values.get(_label_key(labels), 0.0))


_DEFAULT_BOUNDS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
# Step-clock quantities (TTFT in engine steps) need integer-ish bounds.
STEP_BOUNDS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


class Histogram(_Metric):
    """Fixed-bucket histogram; ``observe(value, **labels)``.

    Tracks per-labelset count / sum / min / max plus cumulative bucket
    counts (Prometheus ``le`` semantics, implicit ``+Inf``).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 bounds: Iterable[float] = _DEFAULT_BOUNDS):
        super().__init__(name, help)
        self.bounds = tuple(float(b) for b in bounds)

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        key = _label_key(labels)
        st = self._values.get(key)
        if st is None:
            st = {"count": 0, "sum": 0.0, "min": v, "max": v,
                  "buckets": [0] * (len(self.bounds) + 1)}
            self._values[key] = st
        st["count"] += 1
        st["sum"] += v
        st["min"] = min(st["min"], v)
        st["max"] = max(st["max"], v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                st["buckets"][i] += 1
                break
        else:
            st["buckets"][-1] += 1


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` return the
    existing metric when already registered (re-registration with a
    different type raises).  Exposition: :meth:`prometheus_text` and
    :meth:`snapshot`.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds: Iterable[float] = _DEFAULT_BOUNDS) -> Histogram:
        return self._get(Histogram, name, help, bounds=bounds)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> _Metric:
        return self._metrics[name]

    def prometheus_text(self) -> str:
        """Prometheus text exposition (``# HELP`` / ``# TYPE`` + samples)."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, val in sorted(m.labelsets()):
                if m.kind == "histogram":
                    cum = 0
                    for b, n in zip(m.bounds, val["buckets"]):
                        cum += n
                        lkey = key + (("le", f"{b:g}"),)
                        lines.append(
                            f"{name}_bucket{_label_str(lkey)} {cum}")
                    lkey = key + (("le", "+Inf"),)
                    lines.append(
                        f"{name}_bucket{_label_str(lkey)} {val['count']}")
                    lines.append(f"{name}_sum{_label_str(key)} "
                                 f"{val['sum']:g}")
                    lines.append(f"{name}_count{_label_str(key)} "
                                 f"{val['count']}")
                else:
                    lines.append(f"{name}{_label_str(key)} {val:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        """JSON-ready snapshot: ``{name: {kind, help, values: [...]}}``."""
        out: dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            values = []
            for key, val in sorted(m.labelsets()):
                entry: dict[str, Any] = {"labels": dict(key)}
                if m.kind == "histogram":
                    entry.update(count=val["count"], sum=val["sum"],
                                 min=val["min"], max=val["max"])
                else:
                    entry["value"] = val
                values.append(entry)
            out[name] = {"kind": m.kind, "help": m.help, "values": values}
        return out


# --------------------------------------------------------------------------
# Spans

@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span: host wall-clock window plus nesting metadata."""

    name: str
    ts: float          # start, seconds (Telemetry clock)
    dur: float         # duration, seconds
    depth: int         # nesting depth at entry (0 = top-level)
    seq: int           # per-hub monotonic finish order
    attrs: dict = dataclasses.field(default_factory=dict)
    error: str | None = None


class _NullSpan:
    """Shared no-op context manager for disabled telemetry.

    A single module-level instance is reused for every call so the
    disabled fast path allocates nothing per step; ``__enter__`` /
    ``__exit__`` hold no state, so reentrant/nested use is safe.
    """

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span context manager; exception-safe (closes in ``__exit__``
    regardless, recording the exception type and re-raising)."""

    __slots__ = ("_hub", "name", "attrs", "ts", "dur", "depth", "record")

    def __init__(self, hub: "Telemetry", name: str, attrs: dict):
        self._hub = hub
        self.name = name
        self.attrs = attrs
        self.ts = 0.0
        self.dur = 0.0
        self.depth = 0
        self.record: SpanRecord | None = None

    def __enter__(self):
        hub = self._hub
        self.depth = len(hub._stack)
        hub._stack.append(self)
        self.ts = hub._clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        hub = self._hub
        self.dur = hub._clock() - self.ts
        # Pop self even if an inner span leaked (exception paths): the
        # stack is truncated back to this span's depth.
        del hub._stack[self.depth:]
        self.record = SpanRecord(
            name=self.name, ts=self.ts, dur=self.dur, depth=self.depth,
            seq=hub._next_span_seq(), attrs=self.attrs,
            error=None if exc_type is None else exc_type.__name__)
        hub._finish_span(self.record)
        return False


# --------------------------------------------------------------------------
# Hub

class Telemetry:
    """The hub: metrics + spans + event bus + exports.

    Parameters
    ----------
    capacity:
        Ring capacity for finished spans and for the event bus
        (evictions are drop-oldest and counted in
        ``telemetry_spans_dropped_total`` / ``telemetry_events_dropped_total``).
    enabled:
        Runtime switch.  When False every hot-path entry point
        (``span`` / ``count`` / ``gauge`` / ``observe`` / ``publish`` /
        wrapped steps) is a guarded no-op with no per-call allocation.
    jax_profiler:
        When True, wrapped compiled steps also enter a
        ``jax.profiler.TraceAnnotation`` so host spans line up with
        device traces captured by ``jax.profiler``.
    block_steps:
        When True (default) wrapped compiled steps call
        ``jax.block_until_ready`` on their outputs so span durations
        measure execution, not dispatch.  Only affects enabled hubs.
    """

    def __init__(self, capacity: int = 4096, enabled: bool = True,
                 jax_profiler: bool = False, block_steps: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = bool(enabled)
        self.jax_profiler = bool(jax_profiler)
        self.block_steps = bool(block_steps)
        self._clock = clock
        self.metrics = MetricsRegistry()
        self._spans_dropped = self.metrics.counter(
            "telemetry_spans_dropped_total",
            "finished spans evicted from the bounded span ring")
        self._events_dropped = self.metrics.counter(
            "telemetry_events_dropped_total",
            "bus events evicted from the bounded event ring")
        self.spans: RingBuffer = RingBuffer(
            capacity, on_drop=lambda _e: self._spans_dropped.inc())
        self.bus = EventBus(
            capacity, clock=self._clock,
            on_drop=lambda _e: self._events_dropped.inc())
        self._stack: list[_Span] = []
        self._span_seq = 0
        self._span_seconds = self.metrics.histogram(
            "span_seconds", "wall-clock duration of telemetry spans")

    # -- spans -------------------------------------------------------------

    def span(self, name: str, **attrs):
        """Nested span context manager; no-op singleton when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def _next_span_seq(self) -> int:
        s = self._span_seq
        self._span_seq += 1
        return s

    def _finish_span(self, rec: SpanRecord) -> None:
        self.spans.append(rec)
        self._span_seconds.observe(rec.dur, name=rec.name)

    def emit_span(self, name: str, ts: float, dur: float, depth: int = 0,
                  **attrs) -> SpanRecord:
        """Record a synthetic (already-timed) span, e.g. per-round
        subdivisions of a measured compiled-step window."""
        rec = SpanRecord(name=name, ts=ts, dur=dur, depth=depth,
                         seq=self._next_span_seq(), attrs=attrs)
        self._finish_span(rec)
        return rec

    # -- metrics shorthands (no-ops when disabled) -------------------------

    def count(self, name: str, amount: float = 1.0, help: str = "",
              **labels) -> None:
        if self.enabled:
            self.metrics.counter(name, help).inc(amount, **labels)

    def gauge(self, name: str, value: float, help: str = "",
              **labels) -> None:
        if self.enabled:
            self.metrics.gauge(name, help).set(value, **labels)

    def observe(self, name: str, value: float, help: str = "",
                bounds: Iterable[float] = _DEFAULT_BOUNDS, **labels) -> None:
        if self.enabled:
            self.metrics.histogram(name, help, bounds=bounds).observe(
                value, **labels)

    # -- events ------------------------------------------------------------

    def publish(self, kind: str, payload, step: int | None = None):
        """Publish a typed event to the bus (None when disabled)."""
        if not self.enabled:
            return None
        self.metrics.counter(
            "serving_events_total",
            "events published to the unified bus").inc(kind=kind)
        return self.bus.publish(kind, payload, step=step)

    # -- step wrapping (the step_wrapper seam) -----------------------------

    def wrap_step(self, fn: Callable, name: str, tenant: str | None = None,
                  rounds: Callable[[], Any] | None = None) -> Callable:
        """Wrap a compiled step so each call is a span.

        ``rounds`` (optional) returns the engine's *current* BvN round
        schedule; when present and non-empty, the measured step window
        is subdivided into per-round ``dispatch_round`` child spans
        (equal split, ``estimated=True`` — a host can't see intra-step
        device timing without a device profiler).
        """
        attrs = {} if tenant is None else {"tenant": tenant}

        def wrapped(*args, **kwargs):
            if not self.enabled:
                return fn(*args, **kwargs)
            sp = _Span(self, name, dict(attrs))
            with sp:
                ann = None
                if self.jax_profiler:
                    try:
                        import jax.profiler
                        ann = jax.profiler.TraceAnnotation(name)
                        ann.__enter__()
                    except Exception:
                        ann = None
                try:
                    out = fn(*args, **kwargs)
                    if self.block_steps:
                        import jax
                        out = jax.block_until_ready(out)
                finally:
                    if ann is not None:
                        ann.__exit__(None, None, None)
            if rounds is not None:
                self._emit_rounds(sp, rounds(), tenant)
            return out

        return wrapped

    def _emit_rounds(self, sp: _Span, rounds, tenant: str | None) -> None:
        if rounds is None:
            return
        r_list = list(rounds)
        n = len(r_list)
        if n == 0:
            return
        sub = sp.dur / n
        for i, perm in enumerate(r_list):
            attrs = {"r": i, "estimated": True, "parent": sp.name,
                     "perm": _jsonable(perm)}
            if tenant is not None:
                attrs["tenant"] = tenant
            self.emit_span("dispatch_round", ts=sp.ts + i * sub, dur=sub,
                           depth=sp.depth + 1, **attrs)
        self.metrics.counter(
            "ppermute_rounds_total",
            "BvN dispatch rounds executed (per compiled step x schedule "
            "length)").inc(n)
        self.metrics.gauge(
            "ppermute_rounds_per_step",
            "length of the live BvN round schedule").set(n)

    # -- exports -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Full JSON snapshot: metrics + bus counts + ring stats."""
        return {
            "metrics": self.metrics.snapshot(),
            "events": {"counts": dict(self.bus.counts),
                       "published": sum(self.bus.counts.values()),
                       "retained": len(self.bus),
                       "dropped": self.bus.dropped},
            "spans": {"retained": len(self.spans),
                      "dropped": self.spans.dropped},
        }

    def records(self) -> list[dict]:
        """Spans + bus events as JSON-ready dicts, timeline-ordered."""
        recs: list[tuple[float, int, dict]] = []
        for s in self.spans:
            recs.append((s.ts, s.seq, {
                "type": "span", "name": s.name, "ts": s.ts, "dur": s.dur,
                "depth": s.depth, "seq": s.seq,
                "attrs": _jsonable(s.attrs), "error": s.error}))
        for e in self.bus:
            recs.append((e.ts, e.seq, {
                "type": "event", "kind": e.kind, "ts": e.ts, "seq": e.seq,
                "step": e.step, "payload": _jsonable(e.payload)}))
        recs.sort(key=lambda r: (r[0], r[1]))
        return [r[2] for r in recs]

    def jsonl(self) -> str:
        return "\n".join(json.dumps(r) for r in self.records()) + "\n"

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.jsonl())

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON (loads directly in Perfetto).

        Spans become ``ph: "X"`` complete events (µs since the first
        record); bus events become ``ph: "i"`` instants, so replans /
        faults / sheds interleave with the step timeline.  Tenant maps
        to ``tid`` so colocated tenants get separate tracks.
        """
        events: list[dict] = []
        t0 = None
        for s in self.spans:
            t0 = s.ts if t0 is None else min(t0, s.ts)
        for e in self.bus:
            t0 = e.ts if t0 is None else min(t0, e.ts)
        if t0 is None:
            t0 = 0.0
        tids: dict[str, int] = {}

        def tid_for(tenant) -> int:
            if tenant is None:
                return 0
            return tids.setdefault(str(tenant), len(tids) + 1)

        for s in self.spans:
            ev = {"name": s.name, "ph": "X", "cat": "span",
                  "ts": (s.ts - t0) * 1e6, "dur": s.dur * 1e6,
                  "pid": 0, "tid": tid_for(s.attrs.get("tenant")),
                  "args": _jsonable(s.attrs)}
            if s.error is not None:
                ev["args"]["error"] = s.error
            events.append(ev)
        for e in self.bus:
            events.append({"name": e.kind, "ph": "i", "cat": "event",
                           "s": "p", "ts": (e.ts - t0) * 1e6,
                           "pid": 0, "tid": 0,
                           "args": {"seq": e.seq, "step": e.step,
                                    "payload": _jsonable(e.payload)}})
        events.sort(key=lambda ev: ev["ts"])
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "ts": 0,
                 "args": {"name": "serving"}},
                {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
                 "ts": 0, "args": {"name": "engine"}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
                  "ts": 0, "args": {"name": f"tenant:{name}"}}
                 for name, t in sorted(tids.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def prometheus_text(self) -> str:
        return self.metrics.prometheus_text()


def record_adoption(tel: Telemetry | None, kind: str,
                    step: int | None = None, **detail) -> None:
    """Count + publish a mid-stream adoption (rounds swap, re-pairing,
    replication change, degraded rebuild).  No-op when ``tel`` is None
    or disabled — safe to call unconditionally from engine adopt paths.
    """
    if tel is None or not tel.enabled:
        return
    tel.count("serving_adoptions_total",
              help="mid-stream placement adoptions", kind=kind)
    tel.publish("adoption", {"kind": kind, **detail}, step=step)
