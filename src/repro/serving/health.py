"""HealthMonitor: failure detection from live serving signals.

Sits alongside ``TrafficMonitor`` (which watches WHERE tokens route; this
watches WHETHER the cluster is healthy) and turns three live signals into
typed ``FaultEvent``s:

* **NaN/inf guards** — every wrapped engine step's outputs (logits, cache
  writes) are screened for non-finite values. Corrupt expert weights (bit
  flips, bad checkpoint shards) surface here the first step the router
  sends a token through them.
* **Straggler detection** — per-device step-time EWMAs. A device whose
  smoothed step time exceeds ``straggler_ratio`` x the median of its peers
  stalls every synchronous all-to-all round (the §3 synchrony weakness), so
  it is flagged as soon as the EWMA has warmed up.
* **Missing heartbeats** — devices report liveness each engine step
  (``heartbeat``); one silent for ``heartbeat_timeout`` steps is declared
  lost (fail-stop model), which is the trigger for degraded re-planning
  (``AuroraPlanner.plan_degraded`` -> ``adopt``/``adopt_degraded``).

Detection is detection only: the monitor never mutates the engine. The
recovery loop (``serving.faults.ChaosHarness``, or a production driver)
drains ``events`` and decides — repair weights from a replica, re-queue a
lost device's slots, adopt a survivor-only plan.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serving.events import RingBuffer


__all__ = ["FaultEvent", "HealthMonitor"]


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One detected failure. ``kind`` is "nan", "straggler" or
    "device_loss"; ``step`` is the engine step of DETECTION (injection may
    be earlier — a corrupt expert is invisible until routed to); ``device``
    is the suspect device (None for model-wide signals like NaN outputs)."""

    kind: str
    step: int
    device: int | None = None
    detail: str = ""


class HealthMonitor:
    """Streaming failure detector over ``n_devices`` devices.

    ``observe_step_time(device, dt)`` feeds the straggler EWMAs (halflife
    in steps); ``observe_output(out, step)`` screens a pytree of step
    outputs for non-finite values; ``heartbeat(device, step)`` marks
    liveness; ``check(step)`` sweeps the heartbeat table and EWMAs and
    appends any NEW events (each device is reported lost once, flagged
    straggler once per episode). ``drain()`` hands the accumulated events
    to the recovery loop and clears the queue; ``events`` keeps recent
    history for audits — a bounded drop-oldest ring (``capacity``), so a
    long-running monitor cannot grow without limit; evictions are counted
    on the ring's ``dropped``.

    The first ``min_observations`` step-time samples are averaged with
    EQUAL weight (no decay) before the EWMA takes over: decay-folding
    from zero would make a slow cold-start step dominate the baseline for
    ~a halflife and mis-arm straggler detection. ``armed(device)`` (and
    the ``device_detector_armed`` gauge when ``telemetry`` is attached)
    exposes the warming/armed state.

    ``telemetry`` (optional ``repro.serving.Telemetry``) receives every
    FaultEvent on the unified bus plus per-device step-time/armed gauges.
    """

    def __init__(self, n_devices: int = 1, halflife: float = 16.0,
                 straggler_ratio: float = 3.0, heartbeat_timeout: int = 8,
                 min_observations: int = 4, capacity: int = 4096,
                 telemetry=None):
        if n_devices < 1:
            raise ValueError("HealthMonitor.n_devices must be >= 1")
        if halflife <= 0:
            raise ValueError("HealthMonitor.halflife must be > 0 steps")
        if straggler_ratio <= 1:
            raise ValueError("HealthMonitor.straggler_ratio must be > 1 "
                             "(1.0 would flag every device)")
        if heartbeat_timeout < 1:
            raise ValueError("HealthMonitor.heartbeat_timeout must be >= 1")
        self.n_devices = int(n_devices)
        self.halflife = float(halflife)
        self.straggler_ratio = float(straggler_ratio)
        self.heartbeat_timeout = int(heartbeat_timeout)
        self.min_observations = int(min_observations)
        self._decay = 0.5 ** (1.0 / self.halflife)
        self._ewma_num = np.zeros(self.n_devices)
        self._ewma_den = np.zeros(self.n_devices)
        self._n_obs = np.zeros(self.n_devices, dtype=int)
        self._last_beat: dict[int, int] = {}
        self._lost: set[int] = set()
        self._straggling: set[int] = set()
        self._nan_steps: set[int] = set()
        self.events: RingBuffer = RingBuffer(capacity)
        self._pending: RingBuffer = RingBuffer(capacity)
        self.telemetry = telemetry

    # -- signal feeds ------------------------------------------------------
    def heartbeat(self, device: int, step: int) -> None:
        self._last_beat[int(device)] = int(step)

    def observe_step_time(self, device: int, dt: float) -> None:
        d = int(device)
        if self._n_obs[d] < self.min_observations:
            # Warm-up: equal-weight mean. Decay-folding from zero would
            # weight the very first sample by a full decay factor over
            # each later one, so one slow cold step (compile, cache fill)
            # would bias the straggler baseline long after warm-up.
            self._ewma_num[d] += float(dt)
            self._ewma_den[d] += 1.0
        else:
            self._ewma_num[d] = self._ewma_num[d] * self._decay + float(dt)
            self._ewma_den[d] = self._ewma_den[d] * self._decay + 1.0
        self._n_obs[d] += 1
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.gauge("device_step_seconds",
                      float(self._ewma_num[d]
                            / max(self._ewma_den[d], 1e-12)),
                      help="per-device EWMA step time (seconds)", device=d)
            tel.gauge("device_detector_armed", float(self.armed(d)),
                      help="1 once the straggler detector has warmed up "
                           "(min_observations samples)", device=d)

    def armed(self, device: int) -> bool:
        """True once ``device`` has enough samples for straggler checks."""
        return bool(self._n_obs[int(device)] >= self.min_observations)

    @property
    def warming_devices(self) -> tuple[int, ...]:
        """Devices still inside the equal-weight warm-up window."""
        return tuple(int(d) for d in range(self.n_devices)
                     if self._n_obs[d] < self.min_observations)

    def observe_output(self, out, step: int) -> bool:
        """Screen a pytree of step outputs for NaN/inf. Returns True when
        clean; records (at most one per step) a "nan" event when not."""
        import jax

        clean = True
        for leaf in jax.tree_util.tree_leaves(out):
            arr = np.asarray(leaf)
            if arr.dtype.kind == "f" and not np.isfinite(arr).all():
                clean = False
                break
        if not clean and step not in self._nan_steps:
            self._nan_steps.add(step)
            self._emit(FaultEvent(
                kind="nan", step=int(step),
                detail="non-finite values in step outputs — corrupt "
                       "weights or numeric overflow"))
        return clean

    # -- detection sweep ---------------------------------------------------
    def step_times(self) -> np.ndarray:
        """Per-device EWMA step times (NaN where unobserved)."""
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(self._ewma_den > 0,
                            self._ewma_num / np.maximum(self._ewma_den,
                                                        1e-12),
                            math.nan)

    def check(self, step: int) -> list[FaultEvent]:
        """Sweep heartbeats and EWMAs at engine step ``step``; emit NEW
        events. A device with no heartbeat for ``heartbeat_timeout`` steps
        is lost (once); a warmed-up device whose EWMA exceeds
        ``straggler_ratio`` x the median of the others straggles (once per
        episode — recovery below the threshold re-arms the flag)."""
        new: list[FaultEvent] = []
        for d, last in sorted(self._last_beat.items()):
            if d in self._lost:
                continue
            if step - last >= self.heartbeat_timeout:
                self._lost.add(d)
                ev = FaultEvent(
                    kind="device_loss", step=int(step), device=d,
                    detail=f"no heartbeat for {step - last} steps "
                           f"(timeout {self.heartbeat_timeout})")
                self._emit(ev)
                new.append(ev)
        times = self.step_times()
        for d in range(self.n_devices):
            if d in self._lost or self._n_obs[d] < self.min_observations:
                continue
            peers = [times[o] for o in range(self.n_devices)
                     if o != d and not math.isnan(times[o])]
            if not peers:
                continue
            med = float(np.median(peers))
            if med > 0 and times[d] > self.straggler_ratio * med:
                if d not in self._straggling:
                    self._straggling.add(d)
                    ev = FaultEvent(
                        kind="straggler", step=int(step), device=d,
                        detail=f"EWMA step time {times[d]:.3g} > "
                               f"{self.straggler_ratio:g}x peer median "
                               f"{med:.3g}")
                    self._emit(ev)
                    new.append(ev)
            else:
                self._straggling.discard(d)
        return new

    @property
    def lost_devices(self) -> tuple[int, ...]:
        return tuple(sorted(self._lost))

    def _emit(self, ev: FaultEvent) -> None:
        self.events.append(ev)
        self._pending.append(ev)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.count("serving_faults_total",
                      help="detected faults by kind", kind=ev.kind)
            tel.publish("fault", ev, step=ev.step)

    def drain(self) -> list[FaultEvent]:
        """Events since the last drain (the recovery loop's work queue)."""
        out = list(self._pending)
        self._pending.clear()
        return out
