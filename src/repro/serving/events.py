"""Bounded event storage and the unified typed event bus.

Two small primitives shared by the telemetry hub and the engines:

``RingBuffer``
    A drop-oldest bounded sequence.  The per-engine event lists
    (``ContinuousEngine.shed_events``, ``HealthMonitor.events``) were
    unbounded — a long-running engine grew them forever.  They are now
    RingBuffers: list-like for every existing consumer (iteration,
    ``len``, indexing, slicing), but capped, with a ``dropped`` counter
    so evicted history is visible rather than silent.

``EventBus``
    The single stream that ``ShedEvent`` / ``ReplanEvent`` /
    ``FaultEvent`` (and adoption / recovery notices) all publish into.
    Every publish gets a monotonic ``seq`` and a wall-clock timestamp,
    so recovery and replan timelines interleave deterministically with
    spans in one exported trace.  The bus itself is a RingBuffer of
    ``BusEvent`` records; per-kind counts survive eviction.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Iterator


class RingBuffer:
    """Bounded drop-oldest buffer with list-like reads.

    Supports ``append``, ``len``, iteration, integer and slice
    indexing (slices return plain lists), and ``clear``.  When full,
    ``append`` evicts the oldest item, increments ``dropped``, and
    invokes ``on_drop(item)`` if given (the telemetry hub uses this to
    count evictions as a metric).
    """

    __slots__ = ("capacity", "dropped", "_buf", "_on_drop")

    def __init__(self, capacity: int = 4096,
                 on_drop: Callable[[Any], None] | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.dropped = 0
        self._buf: collections.deque = collections.deque(maxlen=self.capacity)
        self._on_drop = on_drop

    def append(self, item) -> None:
        if len(self._buf) == self.capacity:
            self.dropped += 1
            evicted = self._buf[0]
            if self._on_drop is not None:
                self._on_drop(evicted)
        self._buf.append(item)

    def extend(self, items) -> None:
        for it in items:
            self.append(it)

    def clear(self) -> None:
        self._buf.clear()

    def __len__(self) -> int:
        return len(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)

    def __iter__(self) -> Iterator:
        return iter(self._buf)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._buf)[idx]
        return self._buf[idx]

    def __eq__(self, other) -> bool:
        if isinstance(other, (list, tuple, RingBuffer, collections.deque)):
            return list(self._buf) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return (f"RingBuffer(capacity={self.capacity}, len={len(self._buf)}, "
                f"dropped={self.dropped})")


@dataclasses.dataclass(frozen=True)
class BusEvent:
    """One published event: a typed payload plus ordering metadata.

    ``seq`` is a per-bus monotonic counter — the deterministic order —
    and ``ts`` is the wall-clock publish time used only for interleaving
    with spans in trace exports.
    """

    seq: int
    kind: str
    ts: float
    step: int | None
    payload: Any


class EventBus:
    """Unified bounded stream of typed serving events.

    ``publish(kind, payload, step=)`` wraps the payload in a
    :class:`BusEvent` with the next ``seq`` and appends it to a bounded
    ring.  ``counts`` tracks per-kind totals independent of eviction;
    ``subscribe`` registers a callback invoked synchronously (in
    publish order) for every event.
    """

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.time,
                 on_drop: Callable[[Any], None] | None = None):
        self._ring = RingBuffer(capacity, on_drop=on_drop)
        self._seq = 0
        self._clock = clock
        self.counts: collections.Counter = collections.Counter()
        self._subscribers: list[Callable[[BusEvent], None]] = []

    def subscribe(self, fn: Callable[[BusEvent], None]) -> None:
        self._subscribers.append(fn)

    def publish(self, kind: str, payload, step: int | None = None) -> BusEvent:
        ev = BusEvent(seq=self._seq, kind=str(kind), ts=self._clock(),
                      step=None if step is None else int(step),
                      payload=payload)
        self._seq += 1
        self.counts[ev.kind] += 1
        self._ring.append(ev)
        for fn in self._subscribers:
            fn(ev)
        return ev

    def events(self, kind: str | None = None) -> list[BusEvent]:
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e.kind == kind]

    @property
    def dropped(self) -> int:
        return self._ring.dropped

    def __len__(self) -> int:
        return len(self._ring)

    def __iter__(self) -> Iterator[BusEvent]:
        return iter(self._ring)
