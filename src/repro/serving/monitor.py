"""Live traffic monitoring + online re-planning for the serving loop.

Aurora's plans (pairing, GPU assignment, BvN schedules) are computed from
HISTORICAL traffic matrices (§3, Table 1), but the continuous engines observe
every request's live routing. ``TrafficMonitor`` folds the per-step routing
counts harvested by ``Model.decode_step_stats`` / ``prefill(collect_moe_stats)``
into an exponentially-weighted per-layer expert-popularity estimate and turns
it into a ``MoETrace`` on demand; ``OnlineReplanner`` periodically re-runs
``AuroraPlanner`` on that live trace and recommends a new plan when it beats
the current placement — re-simulated on the SAME live trace — by a margin.

Re-planning is placement-only: applying a new pairing permutes model B's
expert weights and router columns (``apply_pairing``), never the function
either model computes, so a mid-stream re-plan cannot change emitted tokens.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.planner import AuroraPlanner, Plan, PlanDiff
from repro.core.traffic import MoETrace, trace_from_counts


class TrafficMonitor:
    """EWMA accumulator of per-layer expert routing counts.

    ``observe`` takes the (n_layers, B, E) count arrays the stats model
    methods return, masks out inactive slots, and folds the per-step totals
    into a decayed sum with a matching decayed weight (bias-corrected EWMA:
    ``rates = counts / weight`` is a tokens-per-observation estimate from the
    first step on). ``halflife`` is measured in observations.
    """

    def __init__(self, n_experts: int, n_layers: int,
                 halflife: float = 128.0, name: str = "live"):
        if n_layers <= 0:
            raise ValueError("TrafficMonitor needs a model with MoE layers")
        self.n_experts = n_experts
        self.n_layers = n_layers
        self.name = name
        self.decay = 0.5 ** (1.0 / float(halflife))
        self.counts = np.zeros((n_layers, n_experts), np.float64)
        self.weight = 0.0
        self.observations = 0
        # Expert-index frame: routing stats from a model whose experts were
        # physically permuted (``apply_pairing``) arrive in SLOT space —
        # column k is original expert slot_to_expert[k]. The monitor
        # translates every observation back to original-expert space, so
        # the EWMA stays frame-consistent across re-plans and the planner/
        # simulator (which index traces by original expert id) read it
        # directly. None = identity (unpermuted model).
        self.slot_to_expert: list[int] | None = None

    def observe(self, stats, mask=None) -> None:
        """stats: (n_layers, B, E) routed-choice counts for one engine step;
        mask: (B,) truthy for rows that hold a real request (None = all)."""
        arr = np.asarray(stats, np.float64)
        if arr.shape[0] != self.n_layers or arr.shape[-1] != self.n_experts:
            raise ValueError(f"stats shape {arr.shape} does not match "
                             f"({self.n_layers}, B, {self.n_experts})")
        if mask is not None:
            arr = arr * np.asarray(mask, np.float64)[None, :, None]
        if self.slot_to_expert is not None:
            orig = np.empty_like(arr)
            orig[..., np.asarray(self.slot_to_expert)] = arr
            arr = orig
        self.counts = self.decay * self.counts + arr.sum(axis=1)
        self.weight = self.decay * self.weight + 1.0
        self.observations += 1

    @property
    def rates(self) -> np.ndarray:
        """(n_layers, E) EWMA routed tokens per observation."""
        return self.counts / max(self.weight, 1e-12)

    def trace(self, tokens_per_device: float = 1024.0, **times) -> MoETrace:
        """Live ``MoETrace`` from the current popularity estimate. ``times``
        forwards gate/ffn_per_token/agg/ffn_fixed to ``trace_from_counts``."""
        return trace_from_counts(self.name, self.rates,
                                 tokens_per_device=tokens_per_device, **times)


@dataclasses.dataclass
class ReplanEvent:
    """One re-plan decision point (kept on ``OnlineReplanner.events``)."""

    step: int
    stale_time: float          # current placement re-simulated on live trace
    candidate_time: float      # fresh plan's prediction on the same trace
    pair: list[int]            # candidate pairing (2-tenant view)
    applied: bool
    baseline_time: float | None = None   # frozen baseline on same trace
    # N-tenant re-grouping events carry the full candidate grouping
    # (groups[g][t] = tenant-t expert on slot g); None for pair events.
    groups: list[tuple[int, ...]] | None = None


class OnlineReplanner:
    """Traffic-driven re-planning policy for the colocated engine.

    Every ``interval`` decode steps (once both monitors have at least
    ``warmup`` observations), plan fresh from the live traces and compare
    against the CURRENT pairing evaluated on the same traces. Recommend the
    switch only when the placement actually changes and the predicted
    inference time improves by at least ``threshold`` (relative) — hysteresis
    against replanning churn on noisy traffic.
    """

    def __init__(self, planner: AuroraPlanner, interval: int = 64,
                 threshold: float = 0.02, warmup: int | None = None,
                 tokens_per_device: float = 1024.0,
                 baseline_pair: list[int] | None = None,
                 baseline_groups: list[tuple[int, ...]] | None = None):
        if interval <= 0:
            raise ValueError("interval must be positive")
        self.planner = planner
        self.interval = interval
        self.threshold = threshold
        self.warmup = interval if warmup is None else warmup
        self.tokens_per_device = tokens_per_device
        # Optional frozen reference placement (e.g. the historical plan):
        # scored on the live trace at every checkpoint, so a benchmark can
        # compare the adaptive trajectory against never-replanning at all.
        # ``baseline_pair`` for the 2-tenant pairing loop, ``baseline_groups``
        # for the N-tenant re-grouping loop.
        self.baseline_pair = (None if baseline_pair is None
                              else list(baseline_pair))
        self.baseline_groups = (None if baseline_groups is None
                                else [tuple(g) for g in baseline_groups])
        self.events: list[ReplanEvent] = []

    def maybe_replan(self, step: int, monitor_a: TrafficMonitor,
                     monitor_b: TrafficMonitor,
                     current_pair: list[int]) -> Plan | None:
        """Returns the new plan to apply, or None to keep the current one."""
        if step == 0 or step % self.interval:
            return None
        if min(monitor_a.observations, monitor_b.observations) < self.warmup:
            return None
        tr_a = monitor_a.trace(tokens_per_device=self.tokens_per_device)
        tr_b = monitor_b.trace(tokens_per_device=self.tokens_per_device)
        stale = self.planner.evaluate_colocated(tr_a, tr_b, current_pair)
        cand = self.planner.plan_colocated(tr_a, tr_b)
        diff = PlanDiff(
            pair_changed=list(cand.pair) != list(current_pair),
            assignment_changed=False,     # homogeneous pairing re-plan only
            old_time=stale.inference_time,
            new_time=cand.predicted.inference_time)
        apply = diff.pair_changed and diff.rel_improvement > self.threshold
        base_t = None
        if self.baseline_pair is not None:
            base_t = self.planner.evaluate_colocated(
                tr_a, tr_b, self.baseline_pair).inference_time
        self.events.append(ReplanEvent(
            step=step, stale_time=stale.inference_time,
            candidate_time=cand.predicted.inference_time,
            pair=list(cand.pair), applied=apply, baseline_time=base_t))
        return cand if apply else None

    def maybe_regroup(self, step: int, monitors: list[TrafficMonitor],
                      current_groups: list[tuple[int, ...]]) -> Plan | None:
        """N-tenant ``maybe_replan``: plan a fresh k-way grouping from the N
        live traces and compare it against the CURRENT grouping evaluated on
        the same traces. Returns the new plan to apply, or None to keep."""
        if step == 0 or step % self.interval:
            return None
        if min(m.observations for m in monitors) < self.warmup:
            return None
        traces = [m.trace(tokens_per_device=self.tokens_per_device)
                  for m in monitors]
        cur = [tuple(g) for g in current_groups]
        stale = self.planner.evaluate_multi(traces, cur)
        cand = self.planner.plan_multi(traces)
        cand_groups = [tuple(g) for g in cand.groups]
        # Score the candidate under the IDENTITY slot->device assignment —
        # what the engine actually realizes (re-grouping is placement-only;
        # it never re-matches groups to devices). On homogeneous clusters
        # this equals cand.predicted; on heterogeneous ones cand.predicted
        # includes an unapplied device re-matching and would let phantom
        # improvement defeat the hysteresis.
        cand_time = self.planner.evaluate_multi(
            traces, cand_groups).inference_time
        diff = PlanDiff(
            pair_changed=cand_groups != cur,
            assignment_changed=False,     # placement-only re-grouping
            old_time=stale.inference_time,
            new_time=cand_time)
        apply = diff.pair_changed and diff.rel_improvement > self.threshold
        base_t = None
        if self.baseline_groups is not None:
            base_t = self.planner.evaluate_multi(
                traces, self.baseline_groups).inference_time
        self.events.append(ReplanEvent(
            step=step, stale_time=stale.inference_time,
            candidate_time=cand_time,
            pair=list(cand.pair) if cand.pair is not None else [],
            applied=apply, baseline_time=base_t, groups=cand_groups))
        return cand if apply else None
