"""Live traffic monitoring + online re-planning for the serving loop.

Aurora's plans (pairing, GPU assignment, BvN schedules) are computed from
HISTORICAL traffic matrices (§3, Table 1), but the continuous engines observe
every request's live routing. ``TrafficMonitor`` folds the per-step routing
counts harvested by ``Model.decode_step_stats`` / ``prefill(collect_moe_stats)``
into an exponentially-weighted per-layer expert-popularity estimate and turns
it into a ``MoETrace`` on demand; ``OnlineReplanner`` periodically re-runs
``AuroraPlanner`` on that live trace and recommends a new plan when it beats
the current placement — re-simulated on the SAME live trace — by a margin.

Re-planning is placement-only: applying a new pairing permutes model B's
expert weights and router columns (``apply_pairing``), never the function
either model computes, so a mid-stream re-plan cannot change emitted tokens.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.planner import AuroraPlanner, Plan, PlanDiff
from repro.core.traffic import MoETrace, trace_from_counts
from repro.serving.events import RingBuffer


class TrafficMonitor:
    """EWMA accumulator of per-layer expert routing counts.

    ``observe`` takes the (n_layers, B, E) count arrays the stats model
    methods return, masks out inactive slots, and folds the per-step totals
    into a decayed sum with a matching decayed weight (bias-corrected EWMA:
    ``rates = counts / weight`` is a tokens-per-observation estimate from the
    first step on). ``halflife`` is measured in observations.
    """

    def __init__(self, n_experts: int, n_layers: int,
                 halflife: float = 128.0, name: str = "live"):
        if n_layers <= 0:
            raise ValueError("TrafficMonitor needs a model with MoE layers")
        self.n_experts = n_experts
        self.n_layers = n_layers
        self.name = name
        self.decay = 0.5 ** (1.0 / float(halflife))
        self.counts = np.zeros((n_layers, n_experts), np.float64)
        self.weight = 0.0
        # Predictive side-channels (see ``predicted_rates``): a faster EWMA
        # (halflife/4) that reacts to drift sooner than the planning EWMA,
        # and per-layer-pair router affinities — EWMA of the co-routing mass
        # between layer l's experts and layer l+1's experts, folded at the
        # slow decay so the learned transition structure stays stable while
        # the fast popularity it is applied to moves.
        self.decay_fast = 0.5 ** (4.0 / float(halflife))
        self.fast_counts = np.zeros((n_layers, n_experts), np.float64)
        self.fast_weight = 0.0
        self.affinity = np.zeros((max(n_layers - 1, 0), n_experts, n_experts),
                                 np.float64)
        self.observations = 0
        self.slot_to_expert = None

    @property
    def slot_to_expert(self) -> list[int] | None:
        """Expert-index frame: routing stats from a model whose experts were
        physically permuted (``apply_pairing``) arrive in SLOT space — column
        k is original expert ``slot_to_expert[k]``. The monitor translates
        every observation back to original-expert space, so the EWMA stays
        frame-consistent across re-plans and the planner/simulator (which
        index traces by original expert id) read it directly. None = identity
        (unpermuted model)."""
        return self._slot_to_expert

    @slot_to_expert.setter
    def slot_to_expert(self, value) -> None:
        # A wrong-length or non-permutation mapping would silently misindex
        # (scatter into a garbage-initialized frame) — reject on assignment.
        if value is None:
            self._slot_to_expert = None
            return
        perm = [int(v) for v in value]
        if sorted(perm) != list(range(self.n_experts)):
            raise ValueError(
                f"slot_to_expert must be a permutation of "
                f"range({self.n_experts}) — the monitor's stats frame is "
                f"(n_layers={self.n_layers}, B, E={self.n_experts}) — "
                f"got {value!r}")
        self._slot_to_expert = perm

    def observe(self, stats, mask=None) -> None:
        """stats: (n_layers, B, E) routed-choice counts for one engine step;
        mask: (B,) truthy for rows that hold a real request (None = all)."""
        arr = np.asarray(stats, np.float64)
        if arr.shape[0] != self.n_layers or arr.shape[-1] != self.n_experts:
            raise ValueError(f"stats shape {arr.shape} does not match "
                             f"({self.n_layers}, B, {self.n_experts})")
        if mask is not None:
            arr = arr * np.asarray(mask, np.float64)[None, :, None]
        if self.slot_to_expert is not None:
            orig = np.empty_like(arr)
            orig[..., np.asarray(self.slot_to_expert)] = arr
            arr = orig
        totals = arr.sum(axis=1)
        self.counts = self.decay * self.counts + totals
        self.weight = self.decay * self.weight + 1.0
        self.fast_counts = self.decay_fast * self.fast_counts + totals
        self.fast_weight = self.decay_fast * self.fast_weight + 1.0
        if self.n_layers > 1:
            # Per-slot co-occurrence: which layer-(l+1) experts fire for the
            # batch rows currently feeding each layer-l expert.
            self.affinity = (self.decay * self.affinity
                             + np.einsum("lbe,lbf->lef", arr[:-1], arr[1:]))
        self.observations += 1

    @property
    def rates(self) -> np.ndarray:
        """(n_layers, E) EWMA routed tokens per observation."""
        return self.counts / max(self.weight, 1e-12)

    @property
    def fast_rates(self) -> np.ndarray:
        """(n_layers, E) fast-EWMA (halflife/4) rates — drift-sensitive."""
        return self.fast_counts / max(self.fast_weight, 1e-12)

    def predicted_rates(self) -> np.ndarray:
        """(n_layers, E) next-layer router prediction.

        Layer 0 takes the fast EWMA directly; every deeper layer propagates
        the fast estimate of the layer ABOVE it through the learned
        row-normalized affinity matrix, then rescales to that layer's own
        observed mass. When traffic drifts, the shallow layers see the new
        mix first; pushing it through the affinities lets replication
        decisions for deep layers LEAD the traffic instead of trailing the
        slow planning EWMA. Layers whose affinity rows carry no mass yet
        fall back to their own fast estimate."""
        fast = self.fast_rates
        out = np.empty_like(fast)
        out[0] = fast[0]
        for layer in range(1, self.n_layers):
            aff = self.affinity[layer - 1]
            row = aff.sum(axis=1, keepdims=True)
            trans = np.divide(aff, row, out=np.zeros_like(aff),
                              where=row > 1e-12)
            pred = fast[layer - 1] @ trans
            total, target = pred.sum(), fast[layer].sum()
            if total <= 1e-12 or target <= 1e-12:
                out[layer] = fast[layer]
            else:
                out[layer] = pred * (target / total)
        return out

    def trace(self, tokens_per_device: float = 1024.0, **times) -> MoETrace:
        """Live ``MoETrace`` from the current popularity estimate. ``times``
        forwards gate/ffn_per_token/agg/ffn_fixed to ``trace_from_counts``."""
        return trace_from_counts(self.name, self.rates,
                                 tokens_per_device=tokens_per_device, **times)

    def predicted_trace(self, tokens_per_device: float = 1024.0,
                        **times) -> MoETrace:
        """``trace`` built from ``predicted_rates`` — what the replicator
        plans against when predictive routing is enabled."""
        return trace_from_counts(self.name + "+pred", self.predicted_rates(),
                                 tokens_per_device=tokens_per_device, **times)


@dataclasses.dataclass
class ReplanEvent:
    """One re-plan decision point (kept on ``OnlineReplanner.events``)."""

    step: int
    stale_time: float          # current placement re-simulated on live trace
    candidate_time: float      # fresh plan's prediction on the same trace
    pair: list[int]            # candidate pairing (2-tenant view)
    applied: bool
    baseline_time: float | None = None   # frozen baseline on same trace
    # N-tenant re-grouping events carry the full candidate grouping
    # (groups[g][t] = tenant-t expert on slot g); None for pair events.
    groups: list[tuple[int, ...]] | None = None
    # Replication events carry the candidate host map (replication[e] =
    # devices hosting expert e, home first); None for pairing/grouping.
    replication: tuple[tuple[int, ...], ...] | None = None
    # Exclusive re-assignment events carry the candidate expert→device map
    # (scenario 2); None for pairing/grouping/replication events.
    assignment: tuple[int, ...] | None = None


class OnlineReplanner:
    """Traffic-driven re-planning policy for the colocated engine.

    Every ``interval`` decode steps (once both monitors have at least
    ``warmup`` observations), plan fresh from the live traces and compare
    against the CURRENT pairing evaluated on the same traces. Recommend the
    switch only when the placement actually changes and the predicted
    inference time improves by at least ``threshold`` (relative) — hysteresis
    against replanning churn on noisy traffic.
    """

    def __init__(self, planner: AuroraPlanner, interval: int = 64,
                 threshold: float = 0.02, warmup: int | None = None,
                 tokens_per_device: float = 1024.0,
                 baseline_pair: list[int] | None = None,
                 baseline_groups: list[tuple[int, ...]] | None = None,
                 predictive: bool = False,
                 baseline_replication=None,
                 baseline_assignment=None,
                 telemetry=None,
                 event_capacity: int = 4096):
        if interval <= 0:
            raise ValueError("interval must be positive")
        self.planner = planner
        self.interval = interval
        self.threshold = threshold
        self.warmup = interval if warmup is None else warmup
        self.tokens_per_device = tokens_per_device
        # Optional frozen reference placement (e.g. the historical plan):
        # scored on the live trace at every checkpoint, so a benchmark can
        # compare the adaptive trajectory against never-replanning at all.
        # ``baseline_pair`` for the 2-tenant pairing loop, ``baseline_groups``
        # for the N-tenant re-grouping loop.
        self.baseline_pair = (None if baseline_pair is None
                              else list(baseline_pair))
        self.baseline_groups = (None if baseline_groups is None
                                else [tuple(g) for g in baseline_groups])
        # ``predictive=True`` makes ``maybe_replicate`` plan against the
        # monitor's next-layer router prediction (fast EWMA pushed through
        # the learned inter-layer affinities) instead of the slow EWMA, so
        # replication decisions lead drifting traffic. ``baseline_replication``
        # is the frozen reference host map scored at every checkpoint.
        self.predictive = predictive
        self.baseline_replication = (
            None if baseline_replication is None
            else tuple(tuple(h) for h in baseline_replication))
        # Frozen reference expert→device map for the exclusive
        # re-assignment loop (scenario 2), scored at every checkpoint.
        self.baseline_assignment = (
            None if baseline_assignment is None
            else [int(d) for d in baseline_assignment])
        # Bounded drop-oldest history: a long-lived replanner keeps only
        # the newest ``event_capacity`` decision points (evictions are
        # counted on ``events.dropped``).
        self.events: RingBuffer = RingBuffer(event_capacity)
        # Optional repro.serving.Telemetry hub: every ReplanEvent is also
        # published on the unified bus (kind="replan") and counted. Engines
        # wire this automatically when their config carries a hub.
        self.telemetry = telemetry

    def _record(self, ev: ReplanEvent) -> None:
        self.events.append(ev)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.count("serving_replans_total",
                      help="re-plan checkpoints by outcome",
                      applied=ev.applied)
            tel.publish("replan", ev, step=ev.step)

    def maybe_replan(self, step: int, monitor_a: TrafficMonitor,
                     monitor_b: TrafficMonitor,
                     current_pair: list[int]) -> Plan | None:
        """Returns the new plan to apply, or None to keep the current one."""
        if step == 0 or step % self.interval:
            return None
        if min(monitor_a.observations, monitor_b.observations) < self.warmup:
            return None
        tr_a = monitor_a.trace(tokens_per_device=self.tokens_per_device)
        tr_b = monitor_b.trace(tokens_per_device=self.tokens_per_device)
        stale = self.planner.evaluate_colocated(tr_a, tr_b, current_pair)
        cand = self.planner.plan_colocated(tr_a, tr_b)
        diff = PlanDiff(
            pair_changed=list(cand.pair) != list(current_pair),
            assignment_changed=False,     # homogeneous pairing re-plan only
            old_time=stale.inference_time,
            new_time=cand.predicted.inference_time)
        apply = diff.pair_changed and diff.rel_improvement > self.threshold
        base_t = None
        if self.baseline_pair is not None:
            base_t = self.planner.evaluate_colocated(
                tr_a, tr_b, self.baseline_pair).inference_time
        self._record(ReplanEvent(
            step=step, stale_time=stale.inference_time,
            candidate_time=cand.predicted.inference_time,
            pair=list(cand.pair), applied=apply, baseline_time=base_t))
        return cand if apply else None

    def maybe_reassign(self, step: int, monitor: TrafficMonitor,
                       current_assignment) -> Plan | None:
        """Exclusive-deployment re-ASSIGNMENT (scenario 2): re-run Thm 5.1
        on the live trace and compare against the CURRENT expert→device map
        evaluated on the same trace. Returns the new plan to apply, or None
        to keep. On homogeneous clusters ``plan_exclusive`` always returns
        the identity map (observation 1: assignment is irrelevant there), so
        this loop only ever fires on heterogeneous clusters."""
        if step == 0 or step % self.interval:
            return None
        if monitor.observations < self.warmup:
            return None
        tr = monitor.trace(tokens_per_device=self.tokens_per_device)
        cur = [int(d) for d in current_assignment]
        stale = self.planner.evaluate_exclusive(tr, cur)
        cand = self.planner.plan_exclusive(tr)
        cand_e2d = [int(d) for d in cand.expert_to_device]
        diff = PlanDiff(
            pair_changed=False,
            assignment_changed=cand_e2d != cur,
            old_time=stale.inference_time,
            new_time=cand.predicted.inference_time)
        apply = (diff.assignment_changed
                 and diff.rel_improvement > self.threshold)
        base_t = None
        if self.baseline_assignment is not None:
            base_t = self.planner.evaluate_exclusive(
                tr, self.baseline_assignment).inference_time
        self._record(ReplanEvent(
            step=step, stale_time=stale.inference_time,
            candidate_time=cand.predicted.inference_time,
            pair=[], applied=apply, baseline_time=base_t,
            assignment=tuple(cand_e2d)))
        return cand if apply else None

    def maybe_regroup(self, step: int, monitors: list[TrafficMonitor],
                      current_groups: list[tuple[int, ...]]) -> Plan | None:
        """N-tenant ``maybe_replan``: plan a fresh k-way grouping from the N
        live traces and compare it against the CURRENT grouping evaluated on
        the same traces. Returns the new plan to apply, or None to keep."""
        if step == 0 or step % self.interval:
            return None
        if min(m.observations for m in monitors) < self.warmup:
            return None
        traces = [m.trace(tokens_per_device=self.tokens_per_device)
                  for m in monitors]
        cur = [tuple(g) for g in current_groups]
        stale = self.planner.evaluate_multi(traces, cur)
        cand = self.planner.plan_multi(traces)
        cand_groups = [tuple(g) for g in cand.groups]
        n = len(cand_groups)
        s2d = np.asarray(cand.expert_to_device)
        if not np.array_equal(s2d, np.arange(n)):
            # Heterogeneous plan: §7.2's group↔device matching says group k
            # belongs on device s2d[k]. The engine's slots ARE devices
            # (identity frame), so REALIZE the matching as a row
            # permutation — the group matched to device d moves to slot d —
            # and hand the engine an identity-assignment plan. The
            # re-matching becomes part of the same placement-only reseat
            # (every tenant's column is still a permutation), so its gains
            # are real, not phantom, and token identity is untouched.
            inv = np.empty(n, dtype=int)
            inv[s2d] = np.arange(n)
            cand_groups = [cand_groups[int(inv[d])] for d in range(n)]
            cand = dataclasses.replace(
                cand, expert_to_device=np.arange(n),
                groups=tuple(cand_groups),
                pair=([g[1] for g in cand_groups]
                      if cand.pair is not None else None))
        # Score the candidate exactly as the engine will realize it:
        # identity slot->device over the (possibly re-matched) groups. On
        # homogeneous clusters this equals cand.predicted.
        cand_time = self.planner.evaluate_multi(
            traces, cand_groups).inference_time
        diff = PlanDiff(
            pair_changed=cand_groups != cur,
            assignment_changed=False,     # placement-only re-grouping
            old_time=stale.inference_time,
            new_time=cand_time)
        apply = diff.pair_changed and diff.rel_improvement > self.threshold
        base_t = None
        if self.baseline_groups is not None:
            base_t = self.planner.evaluate_multi(
                traces, self.baseline_groups).inference_time
        self._record(ReplanEvent(
            step=step, stale_time=stale.inference_time,
            candidate_time=cand_time,
            pair=list(cand.pair) if cand.pair is not None else [],
            applied=apply, baseline_time=base_t, groups=cand_groups))
        return cand if apply else None

    def maybe_replicate(self, step: int, monitor: TrafficMonitor,
                        current_replication=None, *,
                        tolerance: float = 0.1,
                        max_total_replicas: int | None = None,
                        total_multiple: int | None = None) -> Plan | None:
        """Exclusive-deployment ``maybe_replan``: pick a fresh hot-expert
        replication from the live (or predicted, if ``self.predictive``)
        trace and compare against the CURRENT host map evaluated on the same
        trace. Returns the new plan to apply, or None to keep.

        ``current_replication`` is the engine's live host map
        (``Plan.replication`` tuples; None = no replicas). ``total_multiple``
        forwards to the planner so EP engines get a physical expert count
        divisible by their device count."""
        from repro.core.traffic import identity_replication

        if step == 0 or step % self.interval:
            return None
        if monitor.observations < self.warmup:
            return None
        kw = dict(tokens_per_device=self.tokens_per_device)
        tr = (monitor.predicted_trace(**kw) if self.predictive
              else monitor.trace(**kw))
        cur = (identity_replication(monitor.n_experts)
               if current_replication is None
               else tuple(tuple(h) for h in current_replication))
        stale = self.planner.evaluate_replicated(tr, cur)
        cand = self.planner.plan_replicated(
            tr, tolerance=tolerance, max_total_replicas=max_total_replicas,
            total_multiple=total_multiple)
        changed = cand.replication != cur
        diff = PlanDiff(
            pair_changed=changed,
            assignment_changed=False,     # placement-only replication
            old_time=stale.inference_time,
            new_time=cand.predicted.inference_time)
        apply = changed and diff.rel_improvement > self.threshold
        base_t = None
        if self.baseline_replication is not None:
            base_t = self.planner.evaluate_replicated(
                tr, self.baseline_replication).inference_time
        self._record(ReplanEvent(
            step=step, stale_time=stale.inference_time,
            candidate_time=cand.predicted.inference_time,
            pair=[], applied=apply, baseline_time=base_t,
            replication=cand.replication))
        return cand if apply else None
