"""EP-sharded distributed serving: the continuous engines on a real mesh.

This is the layer that turns the planner/simulator/kernel stack into an
actual distributed server. The three continuous engines run unchanged
host-side schedulers; only their compiled step programs change:

- the MoE hot path dispatches expert-parallel over the mesh's flat EP axis
  (``moe_impl="ep"``: monolithic all_to_all; ``"aurora"``: the paper's BvN
  ppermute rounds; ``overlap=True``: rounds software-pipelined with the
  grouped expert FFN — ``repro.distributed.overlap``);
- live routing counts keep flowing to ``TrafficMonitor`` (the EP paths now
  psum them in-collective), so online re-planning works distributed;
- a replan **also refreshes the BvN rounds**: ``adopt(plan)`` recomputes
  ``aurora_schedule`` → ``aurora_rounds_from_schedule`` at device granularity
  and swaps the rounds into freshly compiled steps. The swap is
  placement-only — rounds change *when* bytes move, never what arrives —
  so in-flight token streams are unaffected (tested).

CI has no multi-chip hardware; the mesh is a host-platform device mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8

set **before** the jax backend initializes (``repro.launch.mesh
.force_host_device_count``). Everything here is shape- and
collective-identical to a TPU/GPU mesh run.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.core.errors import PlanError
from repro.core.schedule import aurora_schedule
from repro.core.traffic import MoETrace, strip_diagonal
from repro.distributed.alltoall import (aurora_rounds_from_schedule,
                                        round_robin_rounds,
                                        validate_rounds_cover)
from repro.models import Model
from repro.sharding import make_pc

from .colocated import ColocatedContinuousEngine, MultiTenantContinuousEngine
from .config import EngineConfig, coerce_config
from .engine import ContinuousEngine
from .telemetry import record_adoption


# ---------------------------------------------------------------------------
# Rounds derivation: expert-granularity plans → device-granularity ppermutes
# ---------------------------------------------------------------------------

def device_traffic(d: np.ndarray, n_devices: int) -> np.ndarray:
    """Aggregate an (E, E) expert-granularity traffic matrix onto the EP
    devices hosting the experts.

    Experts shard over the flat EP axis in contiguous blocks (expert e lives
    on device ``e // (E / n_devices)`` — the layout ``P(ep_axes)`` realizes
    on the stacked (E, ...) weight leaves), so device-pair traffic is the
    block sum. The diagonal (now including intra-device expert pairs) is
    stripped: self-traffic never crosses the network.
    """
    d = np.asarray(d, dtype=np.float64)
    e = d.shape[0]
    if d.ndim != 2 or d.shape[1] != e:
        raise ValueError(f"traffic matrix must be square, got {d.shape}")
    if n_devices <= 0 or e % n_devices:
        raise ValueError(f"{e} experts do not shard over {n_devices} devices")
    epd = e // n_devices
    agg = d.reshape(n_devices, epd, n_devices, epd).sum(axis=(1, 3))
    return strip_diagonal(agg)


def rounds_from_traffic(d: np.ndarray, n_ep: int):
    """BvN ppermute rounds for an expert- or device-granularity matrix."""
    d = np.asarray(d, dtype=np.float64)
    if d.shape[0] != n_ep:
        d = device_traffic(d, n_ep)
    sched = aurora_schedule(strip_diagonal(d))
    return aurora_rounds_from_schedule(sched, n_ep)


def rounds_from_plan(plan, n_ep: int):
    """Device-granularity rounds from a planner ``Plan``.

    The plan's per-layer ``CommSchedule``s live at expert granularity (the
    cluster the planner models has one slot per expert); their realized
    traffic matrices (``CommSchedule.traffic``) are averaged over layers —
    one static round sequence serves every MoE layer of the compiled step —
    and re-scheduled at device granularity.
    """
    mats = [s.traffic() for s in plan.schedules if s.slots]
    if not mats:
        return round_robin_rounds(n_ep)
    return rounds_from_traffic(np.mean(mats, axis=0), n_ep)


def rounds_from_trace(trace: MoETrace, n_ep: int):
    """Device-granularity rounds from a (historical or live) ``MoETrace``."""
    return rounds_from_traffic(np.mean(trace.layers, axis=0), n_ep)


def resolve_rounds(source, n_ep: int):
    """Rounds from whatever traffic evidence the caller has: a ``Plan``
    (uses its schedules), a ``MoETrace``, or a raw traffic matrix.

    Explicit round sequences are deliberately NOT accepted — an (R, n)
    stack of dst vectors is indistinguishable from a traffic matrix when
    R == n (8 devices routinely schedule into exactly 8 rounds). Callers
    holding literal rounds use ``swap_rounds`` / the ``rounds=`` ctor
    argument, which install them after a full-cover validation.
    """
    if hasattr(source, "schedules"):
        return rounds_from_plan(source, n_ep)
    if isinstance(source, MoETrace):
        return rounds_from_trace(source, n_ep)
    arr = np.asarray(source)
    if arr.ndim == 2 and arr.dtype != object and arr.shape[0] == arr.shape[1]:
        return rounds_from_traffic(arr, n_ep)
    raise TypeError(
        "adopt()/resolve_rounds take traffic evidence — a Plan, a MoETrace, "
        f"or a square traffic matrix — got {type(source).__name__}; to "
        "install literal ppermute rounds, call swap_rounds (or pass "
        "rounds=... at construction)")


# ---------------------------------------------------------------------------
# Model distribution
# ---------------------------------------------------------------------------

def ep_size(pc) -> int:
    n = 1
    for ax in pc.ep_axes or ():
        n *= pc.mesh.shape[ax]
    return n


def distribute(model: Model, mesh, moe_impl: str = "aurora",
               overlap: bool = False) -> Model:
    """Bind an EP-sharded ``ParallelContext`` for ``mesh`` onto ``model``.

    Unlike ``make_pc``'s silent dense fallback, this *demands* expert
    parallelism: a config whose expert count does not divide the mesh's EP
    axis is an error here (the caller asked for a distributed MoE server).
    """
    if model.cfg.moe is None:
        raise ValueError(f"{model.cfg.arch_id} has no MoE layers — "
                         "distributed EP serving needs experts to shard")
    pc = make_pc(model.cfg, mesh, moe_impl=moe_impl)
    if pc.moe_impl not in ("ep", "aurora"):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        raise ValueError(
            f"{model.cfg.moe.n_experts} experts do not shard over this mesh "
            f"({sizes}): the expert count must divide the flat EP axis "
            "(data*model, or model alone)")
    pc = dataclasses.replace(pc, ep_overlap=overlap,
                             kernels=model.pc.kernels)
    return dataclasses.replace(model, pc=pc)


def _ctor_rounds(rounds, plan, n_ep: int):
    """Shared constructor logic of the three Distributed* engines: literal
    rounds win (validated as a full cover), else derive them from the
    plan's traffic evidence; None means round-robin until adoption."""
    if rounds is None and plan is not None:
        return resolve_rounds(plan, n_ep)
    if rounds is not None:
        return validate_rounds_cover(rounds, n_ep)
    return None


def _with_rounds(model: Model, rounds) -> Model:
    return dataclasses.replace(
        model, pc=dataclasses.replace(model.pc, aurora_rounds=rounds))


def _require_aurora(pc) -> None:
    """Rounds only steer the 'aurora' dispatch path; swapping them on 'ep'
    would pay a full recompile for a schedule the monolithic all_to_all
    never reads."""
    if pc.moe_impl != "aurora":
        raise ValueError("rounds only exist on the 'aurora' dispatch path, "
                         f"this engine runs '{pc.moe_impl}'")


def _with_mesh(mesh):
    """Step wrapper: run a compiled step under the mesh context (legacy jax
    resolves bare ``PartitionSpec`` sharding constraints from it)."""
    def wrap(fn):
        def run(*args, **kwargs):
            with set_mesh(mesh):
                return fn(*args, **kwargs)
        return run
    return wrap


def _compose_wrapper(user, mesh):
    """Mesh-context wrapper composed UNDER any user ``step_wrapper`` (the
    mesh must be innermost — it has to be active when the compiled step
    actually runs)."""
    inner = _with_mesh(mesh)
    return inner if user is None else (lambda fn: user(inner(fn)))


def _mesh_config(config, kw, owner, mesh):
    """Resolve the effective ``EngineConfig`` for a Distributed* engine and
    compose the mesh-context wrapper under any user ``step_wrapper``.
    Legacy keywords are coerced here non-strictly: ``kw`` still carries
    real pass-through arguments (``monitor``, ``pair``, ...) for the parent
    constructor, which runs the strict pass on the rest. Returns
    ``(config, user_wrapper)`` — the engines stash the USER's original
    wrapper so a degraded mesh rebuild (``adopt_degraded``) can recompose
    it around the survivor mesh's context."""
    config = coerce_config(config, kw, owner, strict=False)
    user = config.step_wrapper
    wrapper = _compose_wrapper(user, mesh)
    return dataclasses.replace(config, step_wrapper=wrapper), user


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

class DistributedEngine(ContinuousEngine):
    """``ContinuousEngine`` with its jitted steps EP-sharded over a mesh.

    ``moe_impl="aurora"`` (default) runs the scheduled ppermute rounds —
    traffic-blind round robin until a plan is adopted; ``overlap=True``
    pipelines the grouped expert FFN with in-flight rounds. ``adopt(plan)``
    refreshes the rounds from a fresh plan/trace/traffic matrix mid-stream
    (placement-only: recompiles the steps, never changes a token).
    """

    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, *, mesh, moe_impl: str = "aurora",
                 rounds=None, plan=None, overlap: bool = False,
                 config: EngineConfig | None = None, **kw):
        config, self._user_wrapper = _mesh_config(
            config, kw, type(self).__name__, mesh)
        model = distribute(model, mesh, moe_impl=moe_impl, overlap=overlap)
        self.mesh = mesh
        self.n_ep = ep_size(model.pc)
        rounds = _ctor_rounds(rounds, plan, self.n_ep)
        if rounds is not None:
            model = _with_rounds(model, rounds)
        super().__init__(model, params, batch_slots, cache_cap,
                         config=config, **kw)

    @property
    def rounds(self):
        return self.model.pc.aurora_rounds

    def swap_rounds(self, rounds) -> None:
        """Swap the compiled ppermute schedule — placement-only: serving
        state (cache, slots, queue) is untouched and token streams are
        provably unchanged (the rounds decide WHEN buckets move, never what
        arrives)."""
        _require_aurora(self.model.pc)
        pc = dataclasses.replace(
            self.model.pc,
            aurora_rounds=validate_rounds_cover(rounds, self.n_ep))
        self._rebind(dataclasses.replace(self.model, pc=pc))
        record_adoption(self._telemetry, "rounds", step=self.decode_steps,
                        n_rounds=len(pc.aurora_rounds))

    def adopt(self, plan):
        """Refresh the BvN rounds from a fresh ``Plan`` / ``MoETrace`` /
        traffic matrix (closing the PR 2 follow-up: a replan now refreshes
        the communication schedule, not just the placement). A full ``Plan``
        also carries its hot-expert replication: the expert leaves are
        re-widened under the new host map (placement-only — see
        ``ContinuousEngine._set_replication``) before the rounds swap, so
        one adoption moves placement AND schedule together. An exclusive
        plan whose only content is a fresh expert→device assignment
        (scenario 2: ``OnlineReplanner.maybe_reassign``) re-seats the
        expert leaves onto their new EP blocks first — placement-only as
        well. Returns the adopted rounds."""
        if hasattr(plan, "schedules"):   # a full Plan carries placement too
            if (plan.pair is None and plan.groups is None
                    and plan.replication is None
                    and self.assignment is not None
                    and len(plan.expert_to_device) == len(self.assignment)):
                self.adopt_assignment(plan.expert_to_device)
            rep = plan.replication
            if rep is not None:
                n_phys = sum(len(h) for h in rep)
                if n_phys % self.n_ep:
                    raise PlanError(
                        f"plan replicates to {n_phys} physical experts, "
                        f"which do not shard over the {self.n_ep}-device EP "
                        f"axis — plan with total_multiple={self.n_ep}")
            self.adopt_replication(rep)
        rounds = resolve_rounds(plan, self.n_ep)
        self.swap_rounds(rounds)
        return rounds

    def adopt_degraded(self, plan) -> None:
        """Adopt a survivor-only degraded ``Plan`` (``AuroraPlanner
        .plan_degraded``): rebuild the mesh over the surviving devices and
        carry every byte of serving state across.

        ``plan.survivors`` indexes the ORIGINAL flat EP device order (mesh
        device i == cluster device i). The rebuild pulls params (back to
        the logical frame), cache and the token buffer to host, constructs
        the survivor mesh from the surviving jax devices, re-shards the
        model over it, recomposes the step wrapper (the user's wrapper —
        stashed at construction — around the NEW mesh's context), refreshes
        the BvN rounds from the plan's degraded schedules, and re-adopts
        the plan's replication counts. Host state is bit-copied, so
        surviving requests' token streams are unchanged; requests resident
        on lost devices must be ``requeue``d by the caller (the
        ``ChaosHarness`` does both in order)."""
        survivors = getattr(plan, "survivors", None)
        if survivors is None:
            raise PlanError(
                "adopt_degraded needs a degraded Plan (built by "
                "AuroraPlanner.plan_degraded) — this plan has no "
                ".survivors device list")
        flat = list(self.mesh.devices.flat)
        n_old = len(flat)
        surv = [int(s) for s in survivors]
        if any(not 0 <= s < n_old for s in surv):
            raise PlanError(
                f"plan survivors {surv} do not index this mesh's "
                f"{n_old} devices")
        if self.n_ep != n_old:
            raise PlanError(
                "adopt_degraded needs the flat EP axis to cover the whole "
                f"mesh ({self.n_ep} EP devices over {n_old} mesh devices)")
        n_e = self.model.cfg.moe.n_experts
        if n_e % len(surv):
            raise PlanError(
                f"{n_e} experts do not shard over {len(surv)} survivors — "
                "plan with plan_degraded(ep_compatible=True) so the "
                "survivor subset divides the expert count")
        # Drop to the canonical logical frame through the tested
        # placement-only paths, then pull everything to host.
        if self.model.pc.moe_replication is not None:
            self.adopt_replication(None)
        if self.assignment is not None \
                and self.assignment != list(range(n_e)):
            self.adopt_assignment(list(range(n_e)))
        params = jax.tree_util.tree_map(np.asarray, self.params)
        cache = jax.tree_util.tree_map(np.asarray, self.cache)
        tokens = np.asarray(self.tokens)
        # Survivor mesh: same axis names, all-singleton leading axes, the
        # surviving devices (ascending original order) on the last.
        shape = tuple(1 for _ in self.mesh.axis_names[:-1]) + (len(surv),)
        mesh = jax.sharding.Mesh(
            np.array([flat[s] for s in surv]).reshape(shape),
            self.mesh.axis_names)
        model = distribute(self.model, mesh,
                           moe_impl=self.model.pc.moe_impl,
                           overlap=self.model.pc.ep_overlap)
        self.mesh = mesh
        self.n_ep = ep_size(model.pc)
        self._step_wrapper = _compose_wrapper(self._user_wrapper, mesh)
        if model.pc.moe_impl == "aurora":
            model = _with_rounds(model,
                                 resolve_rounds(plan, self.n_ep))
        self.params = jax.tree_util.tree_map(jnp.asarray, params)
        self.cache = jax.tree_util.tree_map(jnp.asarray, cache)
        self.tokens = jnp.asarray(tokens)
        self.assignment = list(range(n_e))
        self._rebind(model)
        self.adopt_replication(plan.replication)
        record_adoption(self._telemetry, "degraded", step=self.decode_steps,
                        survivors=surv)


class DistributedColocatedEngine(ColocatedContinuousEngine):
    """Aurora dual-model continuous serving, EP-sharded over a mesh.

    Both tenants' dispatch collectives run over the same flat EP axis inside
    one fused lockstep program. With ``replan=OnlineReplanner(...)`` the
    engine closes the full distributed loop: live in-collective routing
    counts → monitors → re-pairing, and every ADOPTED re-plan also refreshes
    the ppermute rounds from the plan's schedules (``refresh_rounds=False``
    opts out; the swap itself is placement-only either way).
    """

    def __init__(self, model_a: Model, model_b: Model, params_a, params_b,
                 batch_slots: int, cache_cap: int, *, mesh,
                 moe_impl: str = "aurora", rounds=None, plan=None,
                 overlap: bool = False, refresh_rounds: bool = True,
                 config: EngineConfig | None = None, **kw):
        config, self._user_wrapper = _mesh_config(
            config, kw, type(self).__name__, mesh)
        model_a = distribute(model_a, mesh, moe_impl=moe_impl,
                             overlap=overlap)
        model_b = distribute(model_b, mesh, moe_impl=moe_impl,
                             overlap=overlap)
        self.mesh = mesh
        self.n_ep = ep_size(model_a.pc)
        self.refresh_rounds = refresh_rounds
        rounds = _ctor_rounds(rounds, plan, self.n_ep)
        if rounds is not None:
            model_a, model_b = (_with_rounds(m, rounds)
                                for m in (model_a, model_b))
        if plan is not None and kw.get("pair") is None and plan.pair:
            kw["pair"] = list(plan.pair)
        super().__init__(model_a, model_b, params_a, params_b, batch_slots,
                         cache_cap, config=config, **kw)

    @property
    def rounds(self):
        return self.model_a.pc.aurora_rounds

    def swap_rounds(self, rounds) -> None:
        """Swap both tenants' ppermute schedules and rebuild the fused
        lockstep step — placement-only (see ``DistributedEngine``)."""
        _require_aurora(self.model_a.pc)
        rounds = validate_rounds_cover(rounds, self.n_ep)
        for pool in (self.pool_a, self.pool_b):
            pc = dataclasses.replace(pool.model.pc, aurora_rounds=rounds)
            pool._rebind(dataclasses.replace(pool.model, pc=pc))
        self.model_a, self.model_b = self.pool_a.model, self.pool_b.model
        self._build_lockstep()
        record_adoption(self._telemetry, "rounds", step=self.decode_steps,
                        n_rounds=len(rounds))

    def adopt(self, source):
        """One adoption surface for placement AND schedule: a full ``Plan``
        re-realizes its pairing on pool B (placement-only, via the shared
        ``reseat_pairing`` checkpoint) and then refreshes the ppermute
        rounds from its schedules; a ``MoETrace`` / traffic matrix refreshes
        rounds only. Returns the adopted rounds."""
        if hasattr(source, "schedules") and source.pair:
            ColocatedContinuousEngine.adopt(self, source)
        rounds = resolve_rounds(source, self.n_ep)
        self.swap_rounds(rounds)
        return rounds

    def _adopt_online(self, plan) -> None:
        ColocatedContinuousEngine.adopt(self, plan)
        if self.refresh_rounds and self.model_a.pc.moe_impl == "aurora":
            # The adopted plan was computed from the LIVE traces, so its
            # schedules already reflect current traffic under the new
            # pairing — exactly what the rounds should realize.
            self.swap_rounds(resolve_rounds(plan, self.n_ep))


class DistributedMultiTenantEngine(MultiTenantContinuousEngine):
    """N-tenant colocated continuous serving, EP-sharded over a mesh, with
    re-grouping-triggered rounds refresh (the N-way analogue of
    ``DistributedColocatedEngine``)."""

    def __init__(self, models: list[Model], params: list, batch_slots: int,
                 cache_cap: int, *, mesh, moe_impl: str = "aurora",
                 rounds=None, plan=None, overlap: bool = False,
                 refresh_rounds: bool = True,
                 config: EngineConfig | None = None, **kw):
        config, self._user_wrapper = _mesh_config(
            config, kw, type(self).__name__, mesh)
        models = [distribute(m, mesh, moe_impl=moe_impl, overlap=overlap)
                  for m in models]
        self.mesh = mesh
        self.n_ep = ep_size(models[0].pc)
        self.refresh_rounds = refresh_rounds
        rounds = _ctor_rounds(rounds, plan, self.n_ep)
        if rounds is not None:
            models = [_with_rounds(m, rounds) for m in models]
        if plan is not None and kw.get("groups") is None and plan.groups:
            kw["groups"] = [tuple(g) for g in plan.groups]
        super().__init__(models, params, batch_slots, cache_cap,
                         config=config, **kw)

    @property
    def rounds(self):
        return self.models[0].pc.aurora_rounds

    def swap_rounds(self, rounds) -> None:
        _require_aurora(self.models[0].pc)
        rounds = validate_rounds_cover(rounds, self.n_ep)
        for pool in self.pools:
            pc = dataclasses.replace(pool.model.pc, aurora_rounds=rounds)
            pool._rebind(dataclasses.replace(pool.model, pc=pc))
        self.models = [p.model for p in self.pools]
        self._build_lockstep()
        record_adoption(self._telemetry, "rounds", step=self.decode_steps,
                        n_rounds=len(rounds))

    def adopt(self, source):
        """One adoption surface: a full ``Plan`` re-seats every tenant to
        its grouping (placement-only) and refreshes the rounds; a
        ``MoETrace`` / traffic matrix refreshes rounds only. Returns the
        adopted rounds."""
        if hasattr(source, "schedules") and source.groups:
            MultiTenantContinuousEngine.adopt(self, source)
        rounds = resolve_rounds(source, self.n_ep)
        self.swap_rounds(rounds)
        return rounds

    def _adopt_online(self, plan) -> None:
        MultiTenantContinuousEngine.adopt(self, plan)
        if self.refresh_rounds and self.models[0].pc.moe_impl == "aurora":
            self.swap_rounds(resolve_rounds(plan, self.n_ep))
