"""Deterministic fault injection and the closed recovery loop.

Three pieces, layered so each is usable alone:

* **FaultPlan** — a seedable, declarative script of faults (device loss at
  step t, expert-weight NaN corruption, straggler slowdown). Frozen
  dataclasses, so a plan is hashable/reproducible; ``FaultPlan.random``
  derives one deterministically from a seed for chaos property tests.
* **FaultInjector** — realizes a plan against a live engine through the
  existing ``EngineConfig.step_wrapper`` seam (the same seam the
  distributed engines use for their mesh context), so it works unchanged
  on all three engines and their distributed variants. The wrapper times
  every compiled step and feeds a ``HealthMonitor``; ``tick()`` (called
  once per ENGINE step by the driver — the wrapper alone cannot tell
  engine steps from compiled-fn calls) applies due faults: poisons expert
  weights with NaN, silences a lost device's heartbeat, arms stragglers.
  Straggler slowdown is SYNTHETIC — the injector inflates the step-time
  signal reported for the straggling device rather than sleeping, so CI
  wall-clock is unchanged while detection exercises the real EWMA path.
* **ChaosHarness** — the recovery loop: tick, (optionally) checkpoint,
  step, then drain the monitor's events and react. NaN => rollback to the
  pre-step checkpoint, repair the weights from a healthy replica
  (``repair_moe_params``; pristine logical-frame copy as last resort) and
  re-run the step — deterministic greedy decoding makes the re-run
  byte-identical to a never-faulted run. Device loss => re-queue the lost
  device's slots (fail-stop; re-admission re-emits identical streams) and,
  when a planner+trace are wired in, adopt a survivor-only degraded plan
  (``AuroraPlanner.plan_degraded`` -> ``adopt_degraded``/``adopt``).
  Stragglers are recorded (re-planning against them is the traffic
  monitor's drift story, not a failover).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import FaultError
from repro.serving.health import HealthMonitor

__all__ = ["DeviceLoss", "ExpertCorruption", "Straggler", "FaultPlan",
           "FaultInjector", "ChaosHarness", "corrupt_moe_params"]


# -- fault plan -------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceLoss:
    """Fail-stop loss of ``device`` at engine step ``step``: its heartbeat
    goes silent (detection lags by the monitor's timeout — that lag is the
    bounded TTFT spike the chaos bench gates on)."""
    step: int
    device: int


@dataclasses.dataclass(frozen=True)
class ExpertCorruption:
    """Expert ``expert``'s weights turn NaN at step ``step`` (bit flip /
    bad shard). ``layer=None`` corrupts every layer's copy of the expert;
    an int corrupts one layer. Detection happens the first step the router
    sends a token through the poisoned slot."""
    step: int
    expert: int
    layer: int | None = None


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Device ``device`` runs ``factor``x slow for ``duration`` steps
    starting at ``step`` (synthetic: the reported step-time signal is
    inflated; no real sleep)."""
    step: int
    device: int
    factor: float = 4.0
    duration: int = 32


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic script of faults, ordered by step."""
    faults: tuple = ()
    name: str = "chaos"

    def at(self, step: int) -> tuple:
        return tuple(f for f in self.faults if f.step == step)

    def horizon(self) -> int:
        """Last step at which any fault is active."""
        h = 0
        for f in self.faults:
            end = f.step + (f.duration if isinstance(f, Straggler) else 0)
            h = max(h, end)
        return h

    @property
    def has_corruption(self) -> bool:
        return any(isinstance(f, ExpertCorruption) for f in self.faults)

    @classmethod
    def random(cls, seed: int, horizon: int, n_devices: int, n_experts: int,
               n_faults: int = 2, kinds: tuple = ("device_loss",
                                                  "corruption",
                                                  "straggler"),
               max_losses: int | None = None) -> "FaultPlan":
        """Deterministic random plan for chaos property tests. At most
        ``max_losses`` (default: n_devices - 1) distinct devices die, so a
        survivor always exists for ``plan_degraded``."""
        rng = np.random.default_rng(seed)
        if max_losses is None:
            max_losses = n_devices - 1
        faults, lost = [], set()
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max(horizon, 2)))
            if kind == "device_loss":
                alive = [d for d in range(n_devices) if d not in lost]
                if len(lost) >= max_losses or not alive:
                    kind = "straggler"
                else:
                    d = alive[int(rng.integers(len(alive)))]
                    lost.add(d)
                    faults.append(DeviceLoss(step=step, device=d))
                    continue
            if kind == "corruption":
                faults.append(ExpertCorruption(
                    step=step, expert=int(rng.integers(n_experts))))
            else:
                faults.append(Straggler(
                    step=step, device=int(rng.integers(n_devices)),
                    factor=float(2.0 + 4.0 * rng.random()),
                    duration=int(rng.integers(8, 33))))
        return cls(faults=tuple(sorted(faults, key=lambda f: f.step)),
                   name=f"random-{seed}")


# -- weight corruption ------------------------------------------------------
def corrupt_moe_params(params, phys_slot: int, layer: int | None = None,
                       axis: int = 1):
    """Poison one physical expert slot's float leaves with NaN (the
    injected fault ``repair_moe_params`` undoes). ``axis`` is the expert
    axis of the stacked leaves — 1 for full-model (layer, E, ...) segments,
    matching ``replicate_moe_params``."""
    from repro.models.moe import _is_experts_leaf

    def poison(path, leaf):
        if not _is_experts_leaf(path) or leaf.dtype.kind != "f":
            return leaf
        leaf = jnp.asarray(leaf)
        idx = [slice(None)] * leaf.ndim
        idx[axis] = phys_slot
        if layer is not None and axis > 0:
            idx[0] = layer
        return leaf.at[tuple(idx)].set(jnp.nan)
    return jax.tree_util.tree_map_with_path(poison, params)


# -- injector ---------------------------------------------------------------
class FaultInjector:
    """Realize a ``FaultPlan`` against a live engine.

    Construction order matters: the injector exists FIRST (its ``wrap`` is
    the ``EngineConfig.step_wrapper``), the engine is built with that
    config, then ``attach(engine)`` closes the loop. ``tick()`` must be
    called once per engine step, before ``engine.step()`` — the chaos
    harness does this; a custom driver can too.
    """

    def __init__(self, plan: FaultPlan, n_devices: int,
                 health: HealthMonitor | None = None):
        self.plan = plan
        self.n_devices = int(n_devices)
        self.health = health or HealthMonitor(n_devices=self.n_devices)
        self.engine = None
        self.step = 0                    # engine steps ticked so far
        self.lost: set[int] = set()
        self.corrupted_phys: set[int] = set()
        self._stragglers: dict[int, tuple[float, int]] = {}  # d -> (f, end)
        self._applied: set[int] = set()

    def attach(self, engine) -> None:
        self.engine = engine

    # The step_wrapper seam: time every compiled step, feed the monitor's
    # EWMAs (straggler-inflated for the afflicted device — synthetic, no
    # sleep) and NaN guard. Works on any engine because every compiled
    # step of every engine flows through this one seam.
    def wrap(self, fn):
        def wrapped(*args, **kwargs):
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            out = jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            step = max(self.step - 1, 0)
            for d in range(self.n_devices):
                if d in self.lost:
                    continue
                f = self._stragglers.get(d)
                self.health.observe_step_time(
                    d, dt * f[0] if f is not None else dt)
            self.health.observe_output(out, step)
            return out
        return wrapped

    def tick(self) -> None:
        """Advance the fault clock one ENGINE step: apply newly due faults,
        expire finished stragglers, heartbeat the alive devices."""
        now = self.step
        for i, f in enumerate(self.plan.faults):
            if i in self._applied or f.step > now:
                continue
            self._applied.add(i)
            self._apply(f)
        for d, (factor, end) in list(self._stragglers.items()):
            if now >= end:
                del self._stragglers[d]
        for d in range(self.n_devices):
            if d not in self.lost:
                self.health.heartbeat(d, now)
        self.step = now + 1

    def _apply(self, f) -> None:
        tel = getattr(self.health, "telemetry", None)
        if tel is not None and tel.enabled:
            tel.count("serving_faults_injected_total",
                      help="faults injected by the chaos plan",
                      kind=type(f).__name__)
            tel.publish("fault_injected", f, step=self.step)
        if isinstance(f, DeviceLoss):
            self.lost.add(int(f.device))
        elif isinstance(f, Straggler):
            self._stragglers[int(f.device)] = (
                float(f.factor), f.step + int(f.duration))
        elif isinstance(f, ExpertCorruption):
            if self.engine is None:
                raise FaultError(
                    "ExpertCorruption needs an attached engine — call "
                    "FaultInjector.attach(engine) before serving")
            spec = self.engine.model.pc.moe_replication
            e = int(f.expert)
            phys = spec.base[e] if spec is not None else e
            self.engine.params = corrupt_moe_params(
                self.engine.params, phys, layer=f.layer)
            self.corrupted_phys.add(phys)
        else:
            raise FaultError(f"unknown fault type {type(f).__name__}")

    def clear_corrupted(self) -> None:
        self.corrupted_phys.clear()


# -- recovery loop ----------------------------------------------------------
class ChaosHarness:
    """Closed detect-and-recover loop around one continuous engine.

    Per step: ``injector.tick()`` (faults land), checkpoint when the plan
    can corrupt weights, ``engine.step()``, ``health.check()``, then react
    to drained events:

    * ``nan`` — restore the pre-step checkpoint, repair the poisoned slots
      from a healthy replica (``repair_moe_params``) or, when no replica
      survives, from the pristine logical-frame copy snapshotted at
      construction, and re-run the step. Greedy decoding is deterministic,
      so the recovered stream is byte-identical to a never-faulted run.
    * ``device_loss`` — fail-stop: re-queue the slots resident on the lost
      device (``slots_of_device``; default round-robin ``slot % n``), and
      when a planner + trace are wired in, compute
      ``plan_degraded(failed_devices=...)`` and adopt it
      (``engine.adopt_degraded`` when the engine moves real devices,
      ``engine.adopt`` otherwise).
    * ``straggler`` — recorded in ``recoveries`` (re-planning around slow
      devices is the traffic monitor's drift loop, not a failover).
    """

    def __init__(self, engine, injector: FaultInjector, planner=None,
                 trace=None, slots_of_device=None):
        injector.attach(engine)
        self.engine = engine
        self.injector = injector
        self.health = injector.health
        self.planner = planner
        self.trace = trace
        self._slots_of_device = slots_of_device or (
            lambda d: [s for s in range(engine.batch_slots)
                       if s % injector.n_devices == d])
        self.recoveries: list[dict] = []
        self._handled_loss: set[int] = set()
        # Pristine logical-frame weights for last-resort repair when no
        # healthy replica of a corrupted expert survives.
        from repro.models.moe import dereplicate_moe_params
        spec = engine.model.pc.moe_replication
        logical = (dereplicate_moe_params(engine.params, spec)
                   if spec is not None else engine.params)
        self._pristine = jax.tree_util.tree_map(np.asarray, logical)

    def step(self) -> bool:
        inj, eng = self.injector, self.engine
        inj.tick()
        now = inj.step - 1
        snap = eng.checkpoint() if inj.plan.has_corruption else None
        worked = eng.step()
        self.health.check(now)
        for ev in self.health.drain():
            if ev.kind == "nan":
                worked = self._recover_nan(ev, snap) or worked
            elif ev.kind == "device_loss":
                self._recover_loss(ev)
            else:
                self._record_recovery(
                    {"event": ev, "action": "observed"})
        return worked

    def _record_recovery(self, entry: dict) -> None:
        self.recoveries.append(entry)
        tel = getattr(self.health, "telemetry", None)
        if tel is not None and tel.enabled:
            tel.count("serving_recoveries_total",
                      help="recovery actions taken by the chaos harness",
                      action=entry["action"])
            tel.publish("recovery", entry,
                        step=max(self.injector.step - 1, 0))

    def serve(self, reqs) -> list:
        from repro.serving.engine import serve_stream
        serve_stream(self.step, [(self.engine, reqs)])
        return reqs

    # -- reactions ---------------------------------------------------------
    def _recover_nan(self, ev, snap) -> bool:
        eng, inj = self.engine, self.injector
        if snap is None:
            raise FaultError(
                "NaN detected but no pre-step checkpoint exists — the "
                "fault plan declared no corruption faults, so this is a "
                "genuine numeric failure, not an injected one")
        eng.restore(snap)
        bad = sorted(inj.corrupted_phys)
        spec = eng.model.pc.moe_replication
        try:
            from repro.models.moe import repair_moe_params
            eng.params = repair_moe_params(eng.params, spec, bad)
            action = "repaired-from-replica"
        except FaultError:
            # No healthy replica: rebuild from the pristine logical copy
            # (byte-identical by definition) under the live layout.
            from repro.models.moe import replicate_moe_params
            params = jax.tree_util.tree_map(jnp.asarray, self._pristine)
            if spec is not None:
                params = replicate_moe_params(params, spec)
            eng.params = params
            action = "restored-pristine"
        inj.clear_corrupted()
        self._record_recovery({"event": ev, "action": action,
                               "bad_phys": bad})
        return eng.step()                 # re-run the rolled-back step

    def _recover_loss(self, ev) -> None:
        eng = self.engine
        d = int(ev.device)
        if d in self._handled_loss:
            return
        self._handled_loss.add(d)
        victims = eng.requeue(self._slots_of_device(d))
        entry = {"event": ev, "action": "requeued",
                 "requeued": len(victims)}
        if self.planner is not None and self.trace is not None:
            # Distributed engines rebuild a survivor mesh: the survivor
            # subset must divide the expert count (EP sharding), so ask
            # the planner for an EP-compatible degraded plan.
            distributed = hasattr(eng, "adopt_degraded")
            plan = self.planner.plan_degraded(
                self.trace, failed_devices=sorted(self._handled_loss),
                ep_compatible=distributed)
            if distributed:
                eng.adopt_degraded(plan)
            else:
                eng.adopt(plan.replication)
            entry["action"] = "requeued+replanned"
            entry["survivors"] = plan.survivors
        self._record_recovery(entry)
