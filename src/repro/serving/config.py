"""Engine configuration + admission policies: the serving public API.

``EngineConfig`` is the one knob surface shared by every serving engine
(single, colocated, multi-tenant, and their EP-sharded distributed
variants). It absorbs what used to be a sprawl of per-engine constructor
keywords; engines now take ``Engine(model, params, batch_slots, cache_cap,
config=EngineConfig(...))``. The old keywords still work as deprecated
shims (``coerce_config`` folds them into an ``EngineConfig`` and emits a
``DeprecationWarning``) so downstream callers migrate on their own clock —
the repo itself is fully migrated and CI runs with
``-W error::DeprecationWarning``.

``AdmissionPolicy`` replaces the loose ``prefill_chunk`` /
``step_token_budget`` / ``bucket_policy`` trio with one object that decides
how queued prompts enter the slot pool (t2t's ``data_reader.py`` bucketing
schemes are the exemplar):

* ``FifoAdmission`` — one-shot admission in arrival order: a free slot
  absorbs the whole (bucketed) prompt in one prefill program.
* ``LengthBucketedAdmission`` — chunked admission: prompts are bucketed to
  a pad length and absorbed ``chunk`` tokens per engine step, so a long
  prompt never stalls the decode loop for more than one chunk.
* ``TokenBudgetAdmission`` — chunked admission under a per-step token
  budget: decode always runs and eats ``num_active`` tokens of the budget;
  prefill chunks only proceed on leftover budget.

The legacy trio maps 1:1 onto the three policies (``resolve_admission``),
so existing behavior is reproduced exactly — the policy object is the same
scheduler, named.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Protocol, Sequence


def make_bucketer(policy) -> Callable[[int], int]:
    """Resolve a prefill bucketing policy to ``fn(prompt_len) -> pad_len``.

    Policies:
      "pow2"     next power of two — few compiled prefill programs (default)
      "exact"    no padding — one compilation per distinct prompt length
      "step:K"   round up to a multiple of K — linear compile count, less pad
      callable   custom ``fn(n) -> >= n``
    """
    if callable(policy):
        return policy
    if policy == "pow2":
        def pow2(n: int) -> int:
            p = 1
            while p < n:
                p *= 2
            return p
        return pow2
    if policy == "exact":
        return lambda n: n
    if isinstance(policy, str) and policy.startswith("step:"):
        k = int(policy.split(":", 1)[1])
        if k <= 0:
            raise ValueError(f"bucket step must be positive, got {k}")
        return lambda n: -(-n // k) * k
    raise ValueError(f"unknown bucket policy {policy!r} "
                     "(expected 'pow2', 'exact', 'step:K', or a callable)")


class AdmissionPolicy(Protocol):
    """How queued prompts enter the slot pool.

    ``chunk`` is the per-step prefill granularity (None = one-shot whole
    prompts), ``budget`` the per-step token budget (None = unbudgeted);
    ``pad`` buckets a prompt length to its compiled pad length, and
    ``chunk_budget`` is the scheduler decision: given the decode load and
    the pending prefills' next chunk sizes (FIFO order), how many of those
    chunks run this step (a prefix count — admission never reorders).
    """

    chunk: int | None
    budget: int | None

    def pad(self, prompt_len: int) -> int: ...

    def chunk_budget(self, num_active: int,
                     chunks: Sequence[int]) -> int: ...


@dataclasses.dataclass(frozen=True)
class FifoAdmission:
    """One-shot admission in arrival order (no chunking): each free slot
    absorbs a whole bucketed prompt in one prefill program."""

    bucket_policy: object = "pow2"
    chunk = None
    budget = None

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        return len(chunks)


@dataclasses.dataclass(frozen=True)
class LengthBucketedAdmission:
    """Chunked admission: prompts bucketed to a pad length and absorbed
    ``chunk`` tokens per engine step, unbudgeted (every in-flight prefill
    may advance one chunk per step)."""

    chunk: int
    bucket_policy: object = "pow2"
    budget = None

    def __post_init__(self):
        if self.chunk <= 0:
            raise ValueError("prefill_chunk must be a positive token count")

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        return len(chunks)


@dataclasses.dataclass(frozen=True)
class TokenBudgetAdmission:
    """Chunked admission under a per-step token budget.

    Decode always runs and eats ``num_active`` tokens of the budget; pending
    prefills advance in FIFO order on the leftover — the prefix of chunks
    whose sizes fit ``budget - num_active``. An empty pool bypasses the gate
    entirely (nothing is decoding, so there is nothing to protect), which is
    also the progress guarantee: decode drains slots, ``num_active`` falls,
    and the leftover eventually covers the head chunk.
    """

    chunk: int
    budget: int
    bucket_policy: object = "pow2"

    def __post_init__(self):
        if self.chunk <= 0:
            raise ValueError("prefill_chunk must be a positive token count")
        if self.budget <= 0:
            raise ValueError("step_token_budget must be a positive "
                             "token count")

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        if num_active == 0:
            return len(chunks)
        left = self.budget - num_active
        k = 0
        for c in chunks:
            if c > left:
                break
            left -= c
            k += 1
        return k


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Scheduling/compilation knobs shared by every serving engine.

    ``admission`` is the full-control path (any ``AdmissionPolicy``); the
    ``prefill_chunk``/``step_token_budget``/``bucket_policy`` fields are the
    shorthand that maps onto the three stock policies (and mirrors the old
    keyword API) — set one or the other, not both.

    ``prefill_pool = K`` admits up to K chunked prefills CONCURRENTLY: all
    their due chunks (and the decode step, in the single-model engine) run
    in ONE jitted program per engine step instead of one chunk per step.
    Each prompt is still absorbed as batch-1 sub-calls inside that program,
    so MoE capacity/drop semantics — computed per token group — are
    bit-identical to serialized admission and token streams cannot change;
    only the schedule (and the dispatch count) does. Requires chunked
    admission.

    ``kernels`` unifies kernel-path selection: ``False`` (dense reference),
    ``True`` (default ``KernelConfig``), or an explicit ``KernelConfig`` —
    one code path (``kernelize`` -> ``Model.with_kernels``, which also picks
    ``moe_impl="kernel"`` for non-EP MoE configs).

    ``step_wrapper`` wraps every compiled step (the distributed engines
    compose their mesh-context wrapper under it); ``jit=False`` runs steps
    eagerly (debugging).
    """

    prefill_len: int | None = None
    prefill_chunk: int | None = None
    step_token_budget: int | None = None
    bucket_policy: object = "pow2"
    prefill_pool: int = 1
    admission: AdmissionPolicy | None = None
    kernels: object = False          # bool | KernelConfig
    jit: bool = True
    step_wrapper: Callable | None = None

    def __post_init__(self):
        if self.admission is not None:
            if (self.prefill_chunk is not None
                    or self.step_token_budget is not None):
                raise ValueError(
                    "admission= replaces the prefill_chunk/step_token_budget "
                    "shorthand — configure chunking inside the policy")
            if self.bucket_policy != "pow2":
                raise ValueError(
                    "with admission= set, pass bucket_policy inside the "
                    "admission policy (the config-level field would be "
                    "silently ignored)")
        if self.prefill_chunk is not None and self.prefill_chunk <= 0:
            raise ValueError("prefill_chunk must be a positive token count")
        if self.step_token_budget is not None and self.prefill_chunk is None:
            raise ValueError(
                "step_token_budget only gates CHUNKED prefill scheduling — "
                "one-shot admission absorbs whole prompts regardless; set "
                "prefill_chunk to give the budget something to schedule")
        if self.prefill_pool < 1:
            raise ValueError("prefill_pool must be >= 1")
        if self.prefill_pool > 1 and self.resolve_admission().chunk is None:
            raise ValueError(
                "prefill_pool > 1 pools CHUNKED prefills — one-shot "
                "admission has nothing to interleave; set prefill_chunk "
                "(or a chunked admission policy)")

    def resolve_admission(self) -> AdmissionPolicy:
        """The admission policy this config realizes (explicit ``admission``
        wins; else the legacy-trio mapping)."""
        if self.admission is not None:
            return self.admission
        if self.prefill_chunk is None:
            return FifoAdmission(bucket_policy=self.bucket_policy)
        if self.step_token_budget is None:
            return LengthBucketedAdmission(chunk=self.prefill_chunk,
                                           bucket_policy=self.bucket_policy)
        return TokenBudgetAdmission(chunk=self.prefill_chunk,
                                    budget=self.step_token_budget,
                                    bucket_policy=self.bucket_policy)

    def kernelize(self, model):
        """The ONE kernel-selection code path: route ``model`` through the
        Pallas serving hot path per ``self.kernels`` (no-op when False;
        ``Model.with_kernels`` picks ``moe_impl`` for bool/KernelConfig)."""
        return model.with_kernels(self.kernels) if self.kernels else model


# Old per-engine constructor keywords, foldable 1:1 into EngineConfig.
_LEGACY_KEYS = ("prefill_len", "prefill_chunk", "step_token_budget",
                "bucket_policy", "kernels", "jit", "step_wrapper")


def coerce_config(config: EngineConfig | None, kwargs: dict, owner: str,
                  strict: bool = True) -> EngineConfig:
    """Deprecated-kwarg shim: pop legacy engine keywords out of ``kwargs``,
    fold them into an ``EngineConfig`` (with a ``DeprecationWarning``), and
    return the effective config.

    ``strict=True`` (the engine constructors) rejects any leftover key —
    the catch-all ``**legacy`` must not silently eat typos. The distributed
    engines pre-coerce with ``strict=False`` because their ``kwargs`` still
    carry real pass-through arguments (``monitor``, ``pair``, ...) for the
    parent constructor, which then runs the strict pass on what remains.
    """
    legacy = {k: kwargs.pop(k) for k in _LEGACY_KEYS if k in kwargs}
    if strict and kwargs:
        raise TypeError(f"{owner}: unexpected keyword argument(s) "
                        f"{sorted(kwargs)}")
    if not legacy:
        return config if config is not None else EngineConfig()
    if config is not None:
        raise ValueError(
            f"{owner}: pass either config=EngineConfig(...) or the "
            f"deprecated keyword(s) {sorted(legacy)}, not both")
    warnings.warn(
        f"{owner}({', '.join(sorted(legacy))}=...) is deprecated — pass "
        "config=EngineConfig(...) (repro.serving.EngineConfig)",
        DeprecationWarning, stacklevel=3)
    return EngineConfig(**legacy)
