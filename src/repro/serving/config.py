"""Engine configuration + admission policies: the serving public API.

``EngineConfig`` is the one knob surface shared by every serving engine
(single, colocated, multi-tenant, and their EP-sharded distributed
variants). It absorbs what used to be a sprawl of per-engine constructor
keywords; engines now take ``Engine(model, params, batch_slots, cache_cap,
config=EngineConfig(...))``. The old keywords still work as deprecated
shims (``coerce_config`` folds them into an ``EngineConfig`` and emits a
``DeprecationWarning``) so downstream callers migrate on their own clock —
the repo itself is fully migrated and CI runs with
``-W error::DeprecationWarning``.

``AdmissionPolicy`` replaces the loose ``prefill_chunk`` /
``step_token_budget`` / ``bucket_policy`` trio with one object that decides
how queued prompts enter the slot pool (t2t's ``data_reader.py`` bucketing
schemes are the exemplar):

* ``FifoAdmission`` — one-shot admission in arrival order: a free slot
  absorbs the whole (bucketed) prompt in one prefill program.
* ``LengthBucketedAdmission`` — chunked admission: prompts are bucketed to
  a pad length and absorbed ``chunk`` tokens per engine step, so a long
  prompt never stalls the decode loop for more than one chunk.
* ``TokenBudgetAdmission`` — chunked admission under a per-step token
  budget: decode always runs and eats ``num_active`` tokens of the budget;
  prefill chunks only proceed on leftover budget.
* ``EdfAdmission`` — deadline-aware token-budget admission:
  earliest-deadline-first within the chunk budget, starvation-free via
  aging (``age_limit`` caps every request's effective deadline at
  ``arrival + age_limit``, so deadline-free traffic cannot be starved by a
  stream of tight deadlines). With ``shed=True`` it also REJECTS submits
  whose deadline is provably unattainable at current queue depth (or past
  ``queue_cap``) as typed ``ShedEvent`` results — overload robustness
  instead of silent queue growth.

Policies see the scheduler state as ``RequestSpec`` objects (arrival time,
prompt length, SLO deadline, tenant id, next chunk size) through two
methods: ``select(num_active, reqs)`` picks which due prefill chunks run
this engine step (in run order — deadline policies may reorder), and
``order(reqs)`` is the queue discipline for topping up the prefill pool.
Reordering is placement-only: each request's token stream depends only on
its own slot rows, so any admission order emits byte-identical tokens —
only TTFT/TPOT (the schedule) moves.

The pre-SLO protocol method — ``chunk_budget(num_active, chunks)`` over
bare chunk-size ints — remains as a deprecation shim mirroring
``coerce_config``: third-party policies that only implement it are wrapped
(one ``DeprecationWarning`` per config) into the ``select`` interface, and
the stock policies still answer ``chunk_budget`` calls (same warning) by
delegating to ``select``.

Per-tenant SLO targets are declared on ``EngineConfig.tenants`` as
``TenantSpec`` entries (p95 TTFT / p95 TPOT targets in engine-step units,
rate share of the step token budget, and — for the multi-tenant engine —
the tenant's model/params/pairing), which the engines translate into
per-request deadlines at ``submit`` time.

The legacy trio maps 1:1 onto the three original policies
(``resolve_admission``), so existing behavior is reproduced exactly — the
policy object is the same scheduler, named.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Protocol, Sequence


def make_bucketer(policy) -> Callable[[int], int]:
    """Resolve a prefill bucketing policy to ``fn(prompt_len) -> pad_len``.

    Policies:
      "pow2"     next power of two — few compiled prefill programs (default)
      "exact"    no padding — one compilation per distinct prompt length
      "step:K"   round up to a multiple of K — linear compile count, less pad
      callable   custom ``fn(n) -> >= n``
    """
    if callable(policy):
        return policy
    if policy == "pow2":
        def pow2(n: int) -> int:
            p = 1
            while p < n:
                p *= 2
            return p
        return pow2
    if policy == "exact":
        return lambda n: n
    if isinstance(policy, str) and policy.startswith("step:"):
        k = int(policy.split(":", 1)[1])
        if k <= 0:
            raise ValueError(f"bucket_policy 'step:K' needs a positive K, "
                             f"got {k}")
        return lambda n: -(-n // k) * k
    raise ValueError(f"bucket_policy {policy!r} is unknown "
                     "(expected 'pow2', 'exact', 'step:K', or a callable)")


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """What an admission policy sees about one pending request.

    ``chunk`` is the request's next due prefill chunk size in tokens (the
    whole padded prompt for one-shot admission, the first chunk for queue
    ordering); ``deadline`` is the absolute SLO deadline in engine-step
    time (``math.inf`` = no deadline); ``tenant`` is an opaque tenant id.
    """

    chunk: int
    prompt_len: int = 0
    arrival: float = 0.0
    deadline: float = math.inf
    tenant: object = None

    def __post_init__(self):
        if self.chunk < 0:
            raise ValueError("RequestSpec.chunk must be a non-negative "
                             "token count")
        if math.isnan(self.deadline):
            raise ValueError("RequestSpec.deadline must be a time or "
                             "math.inf, not NaN")


@dataclasses.dataclass(frozen=True)
class ShedEvent:
    """One rejected submit under shed-mode admission.

    Load shedding surfaces as a TYPED RESULT, never a silent stall or an
    exception: ``ContinuousEngine.submit`` returns the event (and appends
    it to ``engine.shed_events``) so callers — and per-tenant accounting —
    see exactly which request was refused and why. ``reason`` is
    human-readable and starts with the policy trigger (``"queue_cap"`` or
    ``"deadline"``)."""

    tenant: object
    arrival: float
    reason: str
    request: object = None


def _fifo_order(reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
    return tuple(range(len(reqs)))


def _deprecated_chunk_budget(policy, num_active: int,
                             chunks: Sequence[int]) -> int:
    warnings.warn(
        f"{type(policy).__name__}.chunk_budget(num_active, chunks) is "
        "deprecated — admission policies now expose select(num_active, "
        "reqs) over RequestSpec objects (repro.serving.RequestSpec)",
        DeprecationWarning, stacklevel=3)
    return len(policy.select(num_active,
                             [RequestSpec(chunk=int(c)) for c in chunks]))


class AdmissionPolicy(Protocol):
    """How queued prompts enter the slot pool.

    ``chunk`` is the per-step prefill granularity (None = one-shot whole
    prompts), ``budget`` the per-step token budget (None = unbudgeted);
    ``pad`` buckets a prompt length to its compiled pad length.

    ``select`` is the scheduler decision: given the decode load and the
    pending prefills' ``RequestSpec``s (arrival order), which of their due
    chunks run this step — returned as indices in run order, so a
    deadline-aware policy may reorder. ``order`` is the queue discipline:
    the priority order in which queued requests should enter the prefill
    pool. Both are placement-only decisions — any ordering emits identical
    token streams; only the schedule (TTFT/TPOT) changes.

    The old ``chunk_budget(num_active, chunks)`` int-based signature is
    deprecated; policies that only implement it are shimmed into ``select``
    with a ``DeprecationWarning`` (see ``coerce_admission``).
    """

    chunk: int | None
    budget: int | None

    def pad(self, prompt_len: int) -> int: ...

    def select(self, num_active: int,
               reqs: Sequence[RequestSpec]) -> tuple[int, ...]: ...

    def order(self, reqs: Sequence[RequestSpec]) -> tuple[int, ...]: ...


@dataclasses.dataclass(frozen=True)
class FifoAdmission:
    """One-shot admission in arrival order (no chunking): each free slot
    absorbs a whole bucketed prompt in one prefill program."""

    bucket_policy: object = "pow2"
    chunk = None
    budget = None

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def select(self, num_active: int,
               reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return _fifo_order(reqs)

    def order(self, reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return _fifo_order(reqs)

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        return _deprecated_chunk_budget(self, num_active, chunks)


@dataclasses.dataclass(frozen=True)
class LengthBucketedAdmission:
    """Chunked admission: prompts bucketed to a pad length and absorbed
    ``chunk`` tokens per engine step, unbudgeted (every in-flight prefill
    may advance one chunk per step)."""

    chunk: int
    bucket_policy: object = "pow2"
    budget = None

    def __post_init__(self):
        if self.chunk <= 0:
            raise ValueError("LengthBucketedAdmission.chunk must be a "
                             "positive token count")

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def select(self, num_active: int,
               reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return _fifo_order(reqs)

    def order(self, reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return _fifo_order(reqs)

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        return _deprecated_chunk_budget(self, num_active, chunks)


@dataclasses.dataclass(frozen=True)
class TokenBudgetAdmission:
    """Chunked admission under a per-step token budget.

    Decode always runs and eats ``num_active`` tokens of the budget; pending
    prefills advance in FIFO order on the leftover — the prefix of chunks
    whose sizes fit ``budget - num_active``. An empty pool bypasses the gate
    entirely (nothing is decoding, so there is nothing to protect), which is
    also the progress guarantee: decode drains slots, ``num_active`` falls,
    and the leftover eventually covers the head chunk.
    """

    chunk: int
    budget: int
    bucket_policy: object = "pow2"

    def __post_init__(self):
        if self.chunk <= 0:
            raise ValueError("TokenBudgetAdmission.chunk must be a "
                             "positive token count")
        if self.budget <= 0:
            raise ValueError("TokenBudgetAdmission.budget must be a "
                             "positive token count")

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def select(self, num_active: int,
               reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        if num_active == 0:
            return _fifo_order(reqs)
        left = self.budget - num_active
        k = 0
        for r in reqs:
            if r.chunk > left:
                break
            left -= r.chunk
            k += 1
        return tuple(range(k))

    def order(self, reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return _fifo_order(reqs)

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        return _deprecated_chunk_budget(self, num_active, chunks)


@dataclasses.dataclass(frozen=True)
class EdfAdmission:
    """Deadline-aware token-budget admission: earliest-deadline-first
    within the chunk budget, starvation-free via aging.

    Pending chunks are ranked by effective deadline
    ``min(deadline, arrival + age_limit)`` (ties broken by arrival, then
    submission order) — so a request with no SLO deadline competes as if
    due ``age_limit`` steps after it arrived, which bounds every request's
    wait behind tighter-deadline traffic (the aging guarantee: no
    starvation, however adversarial the deadline stream).

    Selection is WORK-CONSERVING: chunks are admitted greedily in deadline
    order while they fit ``budget - num_active``, and a chunk that does not
    fit is skipped rather than blocking later chunks that do — the engine
    never idles leftover budget while some due chunk would fit it. With
    ``budget=None`` every due chunk runs, in deadline order. The idle-engine
    bypass (``num_active == 0``) and the progress guarantee match
    ``TokenBudgetAdmission``.

    Reordering is placement-only: a request's tokens depend only on its own
    slot rows, so EDF emits byte-identical streams to FIFO — for a
    single-tenant stream with uniform deadlines even the schedule matches
    (the ranking degenerates to arrival order).

    **Shed mode** (``shed=True``): overloaded submits are REJECTED as typed
    ``ShedEvent`` results instead of queueing hopeless work. Two triggers,
    checked in order by ``shed_reason``: the queue already holds
    ``queue_cap`` requests, or the request's deadline is PROVABLY
    unattainable — even if prefill got the whole step budget every step,
    the prompt tokens queued at-or-ahead of it under EDF ranking could not
    finish before its deadline. The bound deliberately ignores decode's
    budget share and prompt padding, so it never sheds a request the
    engine might still serve in time; requests without a finite deadline
    are only ever capacity-shed. Shedding the provably-late tail is what
    keeps ADMITTED requests' TTFT inside their SLO under overload —
    without it, EDF ordering alone lets doomed work consume budget ahead
    of attainable deadlines.
    """

    chunk: int
    budget: int | None = None
    bucket_policy: object = "pow2"
    age_limit: float = 256.0
    shed: bool = False
    queue_cap: int | None = None

    def __post_init__(self):
        if self.chunk <= 0:
            raise ValueError("EdfAdmission.chunk must be a positive token "
                             "count")
        if self.budget is not None and self.budget <= 0:
            raise ValueError("EdfAdmission.budget must be a positive "
                             "token count")
        if not self.age_limit > 0:
            raise ValueError("EdfAdmission.age_limit must be a positive "
                             "step count (it is the starvation bound)")
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError("EdfAdmission.queue_cap must be >= 1 "
                             f"(got {self.queue_cap}); use None for "
                             "an unbounded queue")

    def pad(self, prompt_len: int) -> int:
        return make_bucketer(self.bucket_policy)(prompt_len)

    def _rank(self, reqs: Sequence[RequestSpec]) -> list[int]:
        key = lambda i: (min(reqs[i].deadline,
                             reqs[i].arrival + self.age_limit),
                         reqs[i].arrival, i)
        return sorted(range(len(reqs)), key=key)

    def select(self, num_active: int,
               reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        ranked = self._rank(reqs)
        if self.budget is None or num_active == 0:
            return tuple(ranked)
        left = self.budget - num_active
        take = []
        for i in ranked:
            if reqs[i].chunk <= left:
                take.append(i)
                left -= reqs[i].chunk
        return tuple(take)

    def order(self, reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return tuple(self._rank(reqs))

    def shed_reason(self, spec: RequestSpec,
                    queued: Sequence[RequestSpec],
                    num_active: int = 0) -> str | None:
        """Shed-mode admission test: the reason to reject ``spec`` given
        the current queue, or None to admit.

        The deadline trigger is a LOWER bound on time-to-first-token:
        prefill needs at least ``ceil(work / budget)`` engine steps, where
        ``work`` counts the new prompt plus every queued prompt ranked
        at-or-ahead of it under the EDF effective deadline. Decode's share
        of the budget, prompt padding, and slot contention are all ignored
        — each only makes reality slower — so a shed here is provable, not
        a heuristic. Unbudgeted policies only enforce ``queue_cap``."""
        if not self.shed:
            return None
        if self.queue_cap is not None and len(queued) >= self.queue_cap:
            return (f"queue_cap: {len(queued)} requests queued >= "
                    f"queue_cap {self.queue_cap}")
        if self.budget is None or not math.isfinite(spec.deadline):
            return None

        def eff(r: RequestSpec):
            return (min(r.deadline, r.arrival + self.age_limit), r.arrival)

        mine = eff(spec)
        work = spec.prompt_len + sum(
            r.prompt_len for r in queued if eff(r) <= mine)
        steps = math.ceil(work / self.budget)
        if spec.arrival + steps > spec.deadline:
            return (f"deadline: first token needs >= {steps} steps of the "
                    f"full prefill budget {self.budget} ({work} prompt "
                    "tokens at or ahead of this deadline), but the "
                    f"deadline is {spec.deadline - spec.arrival:g} steps "
                    "after arrival")
        return None

    def chunk_budget(self, num_active: int, chunks: Sequence[int]) -> int:
        return _deprecated_chunk_budget(self, num_active, chunks)


class _LegacyAdmission:
    """Deprecation shim for pre-``select`` admission policies (the old
    int-based ``chunk_budget`` protocol): adapts them to the ``select`` /
    ``order`` interface by forwarding bare chunk sizes and admitting the
    returned prefix. Created (with one ``DeprecationWarning``) by
    ``coerce_admission`` — mirroring ``coerce_config``'s legacy-kwarg
    shim."""

    def __init__(self, policy):
        self._policy = policy
        self.chunk = getattr(policy, "chunk", None)
        self.budget = getattr(policy, "budget", None)
        self.bucket_policy = getattr(policy, "bucket_policy", "pow2")

    def pad(self, prompt_len: int) -> int:
        return self._policy.pad(prompt_len)

    def select(self, num_active: int,
               reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        k = self._policy.chunk_budget(num_active, [r.chunk for r in reqs])
        return tuple(range(min(int(k), len(reqs))))

    def order(self, reqs: Sequence[RequestSpec]) -> tuple[int, ...]:
        return _fifo_order(reqs)


def coerce_admission(policy, owner: str = "EngineConfig"):
    """Adapt ``policy`` to the ``select``-based ``AdmissionPolicy`` protocol.

    Policies already speaking ``select`` pass through; legacy policies that
    only implement the deprecated int-based ``chunk_budget(num_active,
    chunks)`` are wrapped in ``_LegacyAdmission`` with a single
    ``DeprecationWarning`` (per call — ``EngineConfig.resolve_admission``
    caches the result, so an engine warns once)."""
    if hasattr(policy, "select"):
        return policy
    if hasattr(policy, "chunk_budget"):
        warnings.warn(
            f"{owner}: admission policy {type(policy).__name__} only "
            "implements the deprecated int-based chunk_budget(num_active, "
            "chunks) — implement select(num_active, reqs) over "
            "repro.serving.RequestSpec objects instead",
            DeprecationWarning, stacklevel=3)
        return _LegacyAdmission(policy)
    raise TypeError(
        f"{owner}: {type(policy).__name__} is not an admission policy "
        "(needs select(num_active, reqs) — see "
        "repro.serving.AdmissionPolicy)")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's declaration: SLO targets plus (for the multi-tenant
    engine) its model, params, and expert pairing.

    SLO targets are in ENGINE-STEP time units (the same clock as
    ``Request.arrival``): ``ttft_p95`` is the p95 time-to-first-token
    target — engines turn it into per-request deadlines
    (``arrival + ttft_p95``) at submit time, which is what deadline-aware
    policies like ``EdfAdmission`` schedule against; ``tpot_p95`` is the
    p95 time-per-output-token target (reported by the SLO bench sweep, not
    a scheduling input). ``rate_share`` is the tenant's fraction of the
    step token budget — the multi-tenant engine scales a budgeted
    admission policy's ``budget`` by it, so one tenant's prefill burst
    cannot eat the whole step. Shares across one config must sum to <= 1.

    ``model``/``params``/``pair`` fold the multi-tenant constructor
    plumbing into the spec: ``MultiTenantContinuousEngine(batch_slots,
    cache_cap, config=EngineConfig(tenants=(TenantSpec(model=..,
    params=..), ...)))`` replaces the parallel models/params lists, and
    ``admit_tenant(TenantSpec(...))`` admits with the same validated type.
    ``params`` arrive in the LOGICAL (unpermuted) frame; ``pair`` is the
    slot->expert placement the engine realizes (identity when None).
    """

    name: str | None = None
    ttft_p95: float | None = None
    tpot_p95: float | None = None
    rate_share: float | None = None
    model: object = None
    params: object = None
    pair: tuple[int, ...] | None = None

    def __post_init__(self):
        for field in ("ttft_p95", "tpot_p95"):
            v = getattr(self, field)
            if v is not None and not v > 0:
                raise ValueError(f"{field} must be a positive engine-step "
                                 f"count, got {v!r}")
        if self.rate_share is not None and not 0 < self.rate_share <= 1:
            raise ValueError("rate_share must be in (0, 1] — it is the "
                             "tenant's fraction of the step token budget, "
                             f"got {self.rate_share!r}")
        if self.pair is not None:
            object.__setattr__(self, "pair",
                               tuple(int(x) for x in self.pair))
        if self.params is not None and self.model is None:
            raise ValueError("TenantSpec.params without model — the engine "
                             "needs both to host the tenant")

    def deadline(self, arrival: float) -> float:
        """Absolute SLO deadline for a request arriving at ``arrival``
        (``math.inf`` when the tenant declares no TTFT target)."""
        if self.ttft_p95 is None:
            return math.inf
        return arrival + self.ttft_p95


def scale_admission(policy, rate_share: float | None):
    """Per-tenant view of a budgeted admission policy: the tenant's pool
    gets ``budget * rate_share`` (floored at one chunk so progress is never
    configured away). Unbudgeted policies and ``None`` shares pass through
    unchanged."""
    budget = getattr(policy, "budget", None)
    if (rate_share is None or budget is None
            or not dataclasses.is_dataclass(policy)):
        return policy
    chunk = getattr(policy, "chunk", None) or 1
    return dataclasses.replace(
        policy, budget=max(int(chunk), int(round(budget * rate_share))))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Scheduling/compilation knobs shared by every serving engine.

    ``admission`` is the full-control path (any ``AdmissionPolicy``); the
    ``prefill_chunk``/``step_token_budget``/``bucket_policy`` fields are the
    shorthand that maps onto the three stock policies (and mirrors the old
    keyword API) — set one or the other, not both.

    ``prefill_pool = K`` admits up to K chunked prefills CONCURRENTLY: all
    their due chunks (and the decode step, in the single-model engine) run
    in ONE jitted program per engine step instead of one chunk per step.
    Each prompt is still absorbed as batch-1 sub-calls inside that program,
    so MoE capacity/drop semantics — computed per token group — are
    bit-identical to serialized admission and token streams cannot change;
    only the schedule (and the dispatch count) does. Requires chunked
    admission.

    ``kernels`` unifies kernel-path selection: ``False`` (dense reference),
    ``True`` (default ``KernelConfig``), or an explicit ``KernelConfig`` —
    one code path (``kernelize`` -> ``Model.with_kernels``, which also picks
    ``moe_impl="kernel"`` for non-EP MoE configs).

    ``step_wrapper`` wraps every compiled step (the distributed engines
    compose their mesh-context wrapper under it); ``jit=False`` runs steps
    eagerly (debugging).

    ``telemetry`` attaches a ``repro.serving.Telemetry`` hub: compiled
    steps become spans, shed/replan/fault/adoption events publish to the
    hub's bus, and the metrics registry fills in. ``None`` (default) is
    the zero-overhead path — no wrapper is composed and no per-step work
    happens. The hub is shared by colocated/multi-tenant pools (pool
    configs are ``dataclasses.replace`` copies). ``event_capacity``
    bounds the per-engine event rings (``shed_events``), drop-oldest.
    """

    prefill_len: int | None = None
    prefill_chunk: int | None = None
    step_token_budget: int | None = None
    bucket_policy: object = "pow2"
    prefill_pool: int = 1
    admission: AdmissionPolicy | None = None
    tenants: tuple[TenantSpec, ...] = ()
    kernels: object = False          # bool | KernelConfig
    jit: bool = True
    step_wrapper: Callable | None = None
    telemetry: object = None         # Telemetry | None
    event_capacity: int = 4096

    def __post_init__(self):
        object.__setattr__(self, "tenants", tuple(self.tenants))
        if self.event_capacity < 1:
            raise ValueError("event_capacity must be >= 1")
        for t in self.tenants:
            if not isinstance(t, TenantSpec):
                raise ValueError(f"tenants must be TenantSpec entries, "
                                 f"got {type(t).__name__}")
        shares = [t.rate_share for t in self.tenants
                  if t.rate_share is not None]
        if sum(shares) > 1 + 1e-9:
            raise ValueError(f"tenant rate_shares sum to {sum(shares)} > 1 "
                             "— shares are fractions of ONE step token "
                             "budget")
        if self.admission is not None:
            if (self.prefill_chunk is not None
                    or self.step_token_budget is not None):
                raise ValueError(
                    "admission= replaces the prefill_chunk/step_token_budget "
                    "shorthand — configure chunking inside the policy")
            if self.bucket_policy != "pow2":
                raise ValueError(
                    "with admission= set, pass bucket_policy inside the "
                    "admission policy (the config-level field would be "
                    "silently ignored)")
        if self.prefill_chunk is not None and self.prefill_chunk <= 0:
            raise ValueError("prefill_chunk must be a positive token count")
        if self.step_token_budget is not None and self.prefill_chunk is None:
            raise ValueError(
                "step_token_budget only gates CHUNKED prefill scheduling — "
                "one-shot admission absorbs whole prompts regardless; set "
                "prefill_chunk to give the budget something to schedule")
        if self.prefill_pool < 1:
            raise ValueError("prefill_pool must be >= 1")
        if self.prefill_pool > 1 and self.resolve_admission().chunk is None:
            raise ValueError(
                "prefill_pool > 1 pools CHUNKED prefills — one-shot "
                "admission has nothing to interleave; set prefill_chunk "
                "(or a chunked admission policy)")

    def resolve_admission(self) -> AdmissionPolicy:
        """The admission policy this config realizes (explicit ``admission``
        wins; else the legacy-trio mapping). Legacy ``chunk_budget``-only
        policies are shimmed to the ``select`` protocol here
        (``coerce_admission``), cached so the shim's single
        ``DeprecationWarning`` fires once per config."""
        cached = getattr(self, "_resolved_admission", None)
        if cached is not None:
            return cached
        if self.admission is not None:
            resolved = coerce_admission(self.admission)
        elif self.prefill_chunk is None:
            resolved = FifoAdmission(bucket_policy=self.bucket_policy)
        elif self.step_token_budget is None:
            resolved = LengthBucketedAdmission(
                chunk=self.prefill_chunk, bucket_policy=self.bucket_policy)
        else:
            resolved = TokenBudgetAdmission(
                chunk=self.prefill_chunk, budget=self.step_token_budget,
                bucket_policy=self.bucket_policy)
        object.__setattr__(self, "_resolved_admission", resolved)
        return resolved

    def kernelize(self, model):
        """The ONE kernel-selection code path: route ``model`` through the
        Pallas serving hot path per ``self.kernels`` (no-op when False;
        ``Model.with_kernels`` picks ``moe_impl`` for bool/KernelConfig)."""
        return model.with_kernels(self.kernels) if self.kernels else model


# Old per-engine constructor keywords, foldable 1:1 into EngineConfig.
_LEGACY_KEYS = ("prefill_len", "prefill_chunk", "step_token_budget",
                "bucket_policy", "kernels", "jit", "step_wrapper")


def coerce_config(config: EngineConfig | None, kwargs: dict, owner: str,
                  strict: bool = True) -> EngineConfig:
    """Deprecated-kwarg shim: pop legacy engine keywords out of ``kwargs``,
    fold them into an ``EngineConfig`` (with a ``DeprecationWarning``), and
    return the effective config.

    ``strict=True`` (the engine constructors) rejects any leftover key —
    the catch-all ``**legacy`` must not silently eat typos. The distributed
    engines pre-coerce with ``strict=False`` because their ``kwargs`` still
    carry real pass-through arguments (``monitor``, ``pair``, ...) for the
    parent constructor, which then runs the strict pass on what remains.
    """
    legacy = {k: kwargs.pop(k) for k in _LEGACY_KEYS if k in kwargs}
    if strict and kwargs:
        raise TypeError(f"{owner}: unexpected keyword argument(s) "
                        f"{sorted(kwargs)}")
    if not legacy:
        return config if config is not None else EngineConfig()
    if config is not None:
        raise ValueError(
            f"{owner}: pass either config=EngineConfig(...) or the "
            f"deprecated keyword(s) {sorted(legacy)}, not both")
    warnings.warn(
        f"{owner}({', '.join(sorted(legacy))}=...) is deprecated — pass "
        "config=EngineConfig(...) (repro.serving.EngineConfig)",
        DeprecationWarning, stacklevel=3)
    return EngineConfig(**legacy)
