"""Serving: static + continuous single-model engines, Aurora colocation
(dual-model static + continuous, N-tenant continuous with live tenant
churn), live traffic monitoring + online re-planning/re-grouping, the
EP-sharded distributed engines (mesh decode, round-pipelined dispatch, live
schedule refresh), and fault tolerance (seedable fault injection, health
monitoring, degraded-mode failover), plus unified telemetry (metrics
registry, structured spans, bounded event bus — ``EngineConfig(telemetry=
Telemetry())``). All engines are configured through one frozen
``EngineConfig`` (admission policies, prefill pool, kernels, jit)."""

from repro.core.errors import FaultError, PlanError

from .config import (AdmissionPolicy, EdfAdmission, EngineConfig,
                     FifoAdmission, LengthBucketedAdmission, RequestSpec,
                     ShedEvent, TenantSpec, TokenBudgetAdmission,
                     coerce_admission, make_bucketer, scale_admission)
from .engine import (ContinuousEngine, Request, ServingEngine,
                     poisson_requests, serve_stream)
from .colocated import (ColocatedContinuousEngine, ColocatedEngine,
                        MultiTenantContinuousEngine, apply_pairing,
                        build_lockstep_step, inverse_pair, reseat_pairing)
from .distributed import (DistributedColocatedEngine, DistributedEngine,
                          DistributedMultiTenantEngine, device_traffic,
                          rounds_from_plan, rounds_from_trace,
                          rounds_from_traffic)
from .monitor import OnlineReplanner, ReplanEvent, TrafficMonitor
from .health import FaultEvent, HealthMonitor
from .faults import (ChaosHarness, DeviceLoss, ExpertCorruption,
                     FaultInjector, FaultPlan, Straggler)
from .events import BusEvent, EventBus, RingBuffer
from .telemetry import (MetricsRegistry, SpanRecord, Telemetry,
                        record_adoption)

__all__ = ["Request", "ServingEngine", "ContinuousEngine",
           "ColocatedEngine", "ColocatedContinuousEngine",
           "MultiTenantContinuousEngine", "DistributedEngine",
           "DistributedColocatedEngine", "DistributedMultiTenantEngine",
           "EngineConfig", "AdmissionPolicy", "FifoAdmission",
           "LengthBucketedAdmission", "TokenBudgetAdmission",
           "EdfAdmission", "RequestSpec", "TenantSpec", "coerce_admission",
           "scale_admission", "ShedEvent",
           "apply_pairing", "build_lockstep_step", "device_traffic",
           "inverse_pair", "make_bucketer", "poisson_requests",
           "reseat_pairing", "rounds_from_plan", "rounds_from_trace",
           "rounds_from_traffic", "serve_stream", "TrafficMonitor",
           "OnlineReplanner", "ReplanEvent",
           "FaultEvent", "HealthMonitor", "FaultPlan", "FaultInjector",
           "ChaosHarness", "DeviceLoss", "ExpertCorruption", "Straggler",
           "FaultError", "PlanError",
           "Telemetry", "MetricsRegistry", "SpanRecord", "record_adoption",
           "EventBus", "BusEvent", "RingBuffer"]
