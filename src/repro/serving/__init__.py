"""Serving: static + continuous single-model engines, Aurora dual-model
colocation (static + continuous), live traffic monitoring + online
re-planning."""

from .engine import (ContinuousEngine, Request, ServingEngine,
                     make_bucketer, poisson_requests, serve_stream)
from .colocated import (ColocatedContinuousEngine, ColocatedEngine,
                        apply_pairing, inverse_pair)
from .monitor import OnlineReplanner, ReplanEvent, TrafficMonitor

__all__ = ["Request", "ServingEngine", "ContinuousEngine",
           "ColocatedEngine", "ColocatedContinuousEngine",
           "apply_pairing", "inverse_pair", "make_bucketer",
           "poisson_requests", "serve_stream", "TrafficMonitor",
           "OnlineReplanner", "ReplanEvent"]
