"""Serving: static + continuous single-model engines, Aurora colocation
(dual-model static + continuous, N-tenant continuous), live traffic
monitoring + online re-planning/re-grouping."""

from .engine import (ContinuousEngine, Request, ServingEngine,
                     make_bucketer, poisson_requests, serve_stream)
from .colocated import (ColocatedContinuousEngine, ColocatedEngine,
                        MultiTenantContinuousEngine, apply_pairing,
                        build_lockstep_step, inverse_pair)
from .monitor import OnlineReplanner, ReplanEvent, TrafficMonitor

__all__ = ["Request", "ServingEngine", "ContinuousEngine",
           "ColocatedEngine", "ColocatedContinuousEngine",
           "MultiTenantContinuousEngine", "apply_pairing",
           "build_lockstep_step", "inverse_pair", "make_bucketer",
           "poisson_requests", "serve_stream", "TrafficMonitor",
           "OnlineReplanner", "ReplanEvent"]
