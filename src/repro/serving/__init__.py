"""Serving: static + continuous single-model engines, Aurora dual-model
colocation (static + continuous)."""

from .engine import (ContinuousEngine, Request, ServingEngine,
                     poisson_requests, serve_stream)
from .colocated import (ColocatedContinuousEngine, ColocatedEngine,
                        apply_pairing, inverse_pair)

__all__ = ["Request", "ServingEngine", "ContinuousEngine",
           "ColocatedEngine", "ColocatedContinuousEngine",
           "apply_pairing", "inverse_pair", "poisson_requests",
           "serve_stream"]
