"""Serving: static + continuous single-model engines, Aurora colocation
(dual-model static + continuous, N-tenant continuous with live tenant
churn), live traffic monitoring + online re-planning/re-grouping, and the
EP-sharded distributed engines (mesh decode, round-pipelined dispatch, live
schedule refresh). All engines are configured through one frozen
``EngineConfig`` (admission policies, prefill pool, kernels, jit)."""

from .config import (AdmissionPolicy, EdfAdmission, EngineConfig,
                     FifoAdmission, LengthBucketedAdmission, RequestSpec,
                     TenantSpec, TokenBudgetAdmission, coerce_admission,
                     make_bucketer, scale_admission)
from .engine import (ContinuousEngine, Request, ServingEngine,
                     poisson_requests, serve_stream)
from .colocated import (ColocatedContinuousEngine, ColocatedEngine,
                        MultiTenantContinuousEngine, apply_pairing,
                        build_lockstep_step, inverse_pair, reseat_pairing)
from .distributed import (DistributedColocatedEngine, DistributedEngine,
                          DistributedMultiTenantEngine, device_traffic,
                          rounds_from_plan, rounds_from_trace,
                          rounds_from_traffic)
from .monitor import OnlineReplanner, ReplanEvent, TrafficMonitor

__all__ = ["Request", "ServingEngine", "ContinuousEngine",
           "ColocatedEngine", "ColocatedContinuousEngine",
           "MultiTenantContinuousEngine", "DistributedEngine",
           "DistributedColocatedEngine", "DistributedMultiTenantEngine",
           "EngineConfig", "AdmissionPolicy", "FifoAdmission",
           "LengthBucketedAdmission", "TokenBudgetAdmission",
           "EdfAdmission", "RequestSpec", "TenantSpec", "coerce_admission",
           "scale_admission",
           "apply_pairing", "build_lockstep_step", "device_traffic",
           "inverse_pair", "make_bucketer", "poisson_requests",
           "reseat_pairing", "rounds_from_plan", "rounds_from_trace",
           "rounds_from_traffic", "serve_stream", "TrafficMonitor",
           "OnlineReplanner", "ReplanEvent"]
