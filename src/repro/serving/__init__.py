"""Serving: batched single-model engine + Aurora dual-model colocation."""

from .engine import Request, ServingEngine
from .colocated import ColocatedEngine

__all__ = ["Request", "ServingEngine", "ColocatedEngine"]
