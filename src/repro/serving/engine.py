"""Batched serving engine: fixed-capacity batch, prefill + greedy decode.

The engine owns params and a KV/SSM cache sized ``(batch_slots, cache_cap)``
and runs jitted ``prefill`` / ``decode_step`` functions — the same functions
the dry-run lowers for the decode input shapes. Requests are left-padded to
a common prompt length per batch (fixed-shape serving; continuous batching
is out of scope for the paper, which schedules the MoE all-to-all).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0, jit: bool = True):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        # Cache buffers are donated: the update aliases in place instead of
        # copying the full KV/SSM state every step.
        self._prefill = (jax.jit(model.prefill, donate_argnums=(2,))
                         if jit else model.prefill)
        self._decode = (jax.jit(model.decode_step, donate_argnums=(2,))
                        if jit else model.decode_step)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch_slots, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def serve(self, reqs: list[Request], frames=None) -> list[Request]:
        """Run one batch of requests to completion (greedy decoding)."""
        if len(reqs) > self.batch_slots:
            raise ValueError("too many requests for the batch")
        toks = self._pad_prompts(reqs)
        cache = self.model.init_cache(self.batch_slots, self.cache_cap,
                                      src_len=self.src_len)
        inputs = {"tokens": jnp.asarray(toks)}
        if frames is not None:
            inputs["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, inputs, cache)
        tok = jnp.argmax(logits[:, -1:, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in reqs)
        for _ in range(steps):
            for i, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                             axis=-1).astype(jnp.int32)
        return reqs
