"""Serving engines: static fixed-batch and continuous batching.

``ServingEngine`` (the original) runs one fixed-shape batch to completion:
requests are left-padded to a common prompt length, and the whole batch
decodes for ``max(max_new_tokens)`` steps — throughput stalls on the longest
request, and nothing can start until the batch is done.

``ContinuousEngine`` owns a request queue plus ``batch_slots`` decode slots
over a shared, donated KV/SSM cache with **per-slot lengths**
(``init_cache(per_slot_len=True)``). Each step the scheduler admits queued
requests into free slots — a per-slot prefill writes one request's state into
its slot row (``Model.prefill_slot``) — then decodes every slot in one jitted
step and evicts finished requests, so a short request's slot is immediately
reusable while long requests keep decoding. Same math as the static engine
(per-row attention masking via the per-slot length vector), different
schedule.
"""

from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    arrival: float = 0.0                 # engine-step time of arrival
    out_tokens: list = dataclasses.field(default_factory=list)


def poisson_requests(rng, n: int, rate: float, vocab: int, prompt_len: int,
                     max_new_lo: int, max_new_hi: int) -> list[Request]:
    """n requests with Exp(1/rate) inter-arrival gaps (a Poisson process,
    in decode-step time units) and uniform output lengths in
    [max_new_lo, max_new_hi]."""
    t = 0.0
    reqs = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        reqs.append(Request(
            prompt=list(rng.integers(1, vocab, prompt_len)),
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1)),
            arrival=t))
    return reqs


def serve_stream(step_fn, pools) -> None:
    """Arrival-clock driver shared by the continuous engines.

    ``pools``: (engine, requests) pairs — one for the single-model engine,
    two (lockstep) for the colocated engine. Each tick admits every request
    whose ``arrival`` has passed (same-arrival requests in list order), runs
    one ``step_fn()``, and jumps the clock over idle gaps when nothing is
    active but requests are still due.
    """
    streams = [[eng, sorted(reqs, key=lambda r: r.arrival), 0]
               for eng, reqs in pools]
    t = 0.0
    while any(i < len(p) or e.queue or e.num_active for e, p, i in streams):
        for s in streams:
            eng, pend, i = s
            while i < len(pend) and pend[i].arrival <= t:
                eng.submit(pend[i])
                i += 1
            s[2] = i
        due = [p[i].arrival for _, p, i in streams if i < len(p)]
        if not step_fn() and due:
            t = max(t + 1.0, min(due))               # jump idle gaps
        else:
            t += 1.0


class ServingEngine:
    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0, jit: bool = True):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        # Cache buffers are donated: the update aliases in place instead of
        # copying the full KV/SSM state every step.
        self._prefill = (jax.jit(model.prefill, donate_argnums=(2,))
                         if jit else model.prefill)
        self._decode = (jax.jit(model.decode_step, donate_argnums=(2,))
                        if jit else model.decode_step)
        self.decode_steps = 0            # decode invocations (for benchmarks)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch_slots, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def serve(self, reqs: list[Request], frames=None) -> list[Request]:
        """Run one batch of requests to completion (greedy decoding)."""
        if len(reqs) > self.batch_slots:
            raise ValueError("too many requests for the batch")
        toks = self._pad_prompts(reqs)
        cache = self.model.init_cache(self.batch_slots, self.cache_cap,
                                      src_len=self.src_len)
        inputs = {"tokens": jnp.asarray(toks)}
        if frames is not None:
            inputs["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, inputs, cache)
        tok = jnp.argmax(logits[:, -1:, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in reqs)
        for _ in range(steps):
            for i, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
            logits, cache = self._decode(self.params, tok, cache)
            self.decode_steps += 1
            tok = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                             axis=-1).astype(jnp.int32)
        return reqs


class ContinuousEngine:
    """Continuous-batching scheduler over ``batch_slots`` decode slots.

    ``prefill_len``: fixed left-pad length for per-slot prefills (one compiled
    prefill program). ``None`` buckets each prompt to the next power of two
    (one compilation per bucket). A prompt padded to length P behaves exactly
    like the static engine's batch padded to P, so outputs are
    token-identical when the pad lengths agree.

    The slot state machine lives host-side (``queue`` + ``slots``); device
    state is the shared cache (per-slot lengths) and the (B, 1) current-token
    buffer. Free slots keep decoding garbage rows — attention is batch-row
    independent and the rows are overwritten at the next admission — so the
    decode step is one fixed-shape jitted program regardless of occupancy.
    """

    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0,
                 prefill_len: int | None = None, jit: bool = True):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        self.prefill_len = prefill_len
        self.cache = model.init_cache(batch_slots, cache_cap,
                                      src_len=src_len, per_slot_len=True)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * batch_slots
        fn_p = partial(model.prefill_slot, cap=cache_cap, src_len=src_len)
        self._prefill = (jax.jit(fn_p, donate_argnums=(2,)) if jit else fn_p)
        self._decode = (jax.jit(model.decode_step, donate_argnums=(2,))
                        if jit else model.decode_step)
        self.decode_steps = 0

    # -- scheduler ---------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def submit(self, req: Request) -> None:
        # Final per-slot length is pad(prompt) + max_new_tokens - 1 (the
        # last emitted token is never written back); beyond cache_cap the
        # decode path would silently overwrite slot cap-1 every step.
        need = self._bucket(len(req.prompt)) + max(req.max_new_tokens - 1, 0)
        if need > self.cache_cap:
            raise ValueError(
                f"prompt + generation needs {need} cache slots, "
                f"capacity is {self.cache_cap}")
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        if self.prefill_len is not None:
            if n > self.prefill_len:
                raise ValueError(f"prompt len {n} > prefill_len "
                                 f"{self.prefill_len}")
            return self.prefill_len
        p = 1
        while p < n:
            p *= 2
        return min(p, self.cache_cap)

    def _admit(self) -> None:
        """Drain the queue into free slots (per-slot prefill each)."""
        while self.queue and None in self.slots:
            slot = self.slots.index(None)
            r = self.queue.popleft()
            p = self._bucket(len(r.prompt))
            toks = np.zeros((1, p), np.int32)
            toks[0, p - len(r.prompt):] = r.prompt      # left-pad with 0
            logits, self.cache = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.cache,
                jnp.int32(slot))
            tok0 = int(jnp.argmax(logits[0, -1, : self.model.cfg.vocab]))
            if r.max_new_tokens > 0:
                r.out_tokens.append(tok0)
            if len(r.out_tokens) < r.max_new_tokens:
                self.slots[slot] = r
                self.tokens = self.tokens.at[slot, 0].set(tok0)

    def _postdecode(self, logits) -> None:
        """Emit one token per occupied slot; evict finished requests."""
        nxt = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        self.tokens = nxt
        host = np.asarray(nxt)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.out_tokens.append(int(host[i, 0]))
            if len(r.out_tokens) >= r.max_new_tokens:
                self.slots[i] = None                     # slot free for reuse

    def step(self) -> bool:
        """Admit, then decode all slots once. Returns False when idle."""
        self._admit()
        if self.num_active == 0:
            return False
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache)
        self.decode_steps += 1
        self._postdecode(logits)
        return True

    # -- driver ------------------------------------------------------------
    def serve(self, reqs: list[Request]) -> list[Request]:
        """Run a request stream to completion, honoring ``arrival`` times
        (measured in engine steps; requests arriving at the same step are
        admitted in list order)."""
        serve_stream(self.step, [(self, reqs)])
        return reqs
