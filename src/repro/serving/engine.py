"""Serving engines: static fixed-batch and continuous batching.

``ServingEngine`` (the original) runs one fixed-shape batch to completion:
requests are left-padded to a common prompt length, and the whole batch
decodes for ``max(max_new_tokens)`` steps — throughput stalls on the longest
request, and nothing can start until the batch is done.

``ContinuousEngine`` owns a request queue plus ``batch_slots`` decode slots
over a shared, donated KV/SSM cache with **per-slot lengths**
(``init_cache(per_slot_len=True)``). Each step the scheduler admits queued
requests into free slots — a per-slot prefill writes one request's state into
its slot row (``Model.prefill_slot``) — then decodes every slot in one jitted
step and evicts finished requests, so a short request's slot is immediately
reusable while long requests keep decoding. Same math as the static engine
(per-row attention masking via the per-slot length vector), different
schedule.

All scheduling/compilation knobs arrive through one frozen ``EngineConfig``
(``repro.serving.config``): ``Engine(model, params, batch_slots, cache_cap,
config=EngineConfig(...))``. The old per-engine keywords remain as
deprecated shims.

**Chunked prefill** (``EngineConfig(prefill_chunk=C)``): instead of
absorbing a whole prompt in one admission step — stalling every active
slot's decode behind a long prefill — the prompt is consumed ``C`` tokens
per engine step straight into its slot's row of the shared cache
(``Model.prefill_chunk_slot``: slice, continue, merge in one donated
program). Between chunks the decode step freezes the pending slot's row
(``row_mask``), so the partial state survives interleaved decodes. An
``AdmissionPolicy`` decides which pending chunks run each step via
``select`` over per-request ``RequestSpec``s (arrival, prompt length, SLO
deadline, tenant): decode always runs; under ``TokenBudgetAdmission``
leftover budget feeds the FIFO prefix of due chunks, under
``EdfAdmission`` the earliest effective deadlines go first. Token streams
are identical to one-shot admission regardless of order (prefill
continuation is exact — see ``models.transformer.forward``); only the
schedule changes.

**Prefill pool** (``EngineConfig(prefill_pool=K)``): up to K chunked
prefills live in flight at once, and every engine step runs ALL their due
chunks plus the decode step as ONE jitted program — prefill effectively
overlaps decode by sharing its dispatch instead of serializing admission
one chunk per step. Each prompt still advances as batch-1 sub-calls inside
that program, so MoE capacity/drop semantics (computed per token group)
are bit-identical to serialized admission; completed prompts merge into
their reserved slots as they finish.

**Live routing stats** (``monitor=TrafficMonitor(...)``): decode steps and
prefills report per-layer expert routing counts, feeding the traffic-driven
re-planner (``repro.serving.monitor``).

**Kernel path** (``EngineConfig(kernels=True)`` or a ``KernelConfig``): the
engine's jitted steps run through the Pallas serving hot path — sort-based
ragged MoE dispatch into the fused grouped FFN and flash-decode attention
over the per-slot cache (``EngineConfig.kernelize`` ->
``Model.with_kernels``, the one kernel-selection path). Same
routing/capacity semantics, so token streams match the dense path; routing
counts still flow to the monitor (derived from the routing output by the
shared ``routed_counts`` scatter, no one-hot).
"""

from __future__ import annotations

import collections
import dataclasses
import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import FaultError, PlanError
from repro.models import Model
from repro.serving.config import (EngineConfig, RequestSpec, ShedEvent,
                                  coerce_config, make_bucketer)
from repro.serving.events import RingBuffer
from repro.serving.telemetry import STEP_BOUNDS, record_adoption

__all__ = ["Request", "poisson_requests", "serve_stream", "make_bucketer",
           "ServingEngine", "ContinuousEngine"]


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    arrival: float = 0.0                 # engine-step time of arrival
    # Absolute SLO deadline (engine-step time) fed to deadline-aware
    # admission policies. None = derive from the engine's TenantSpec at
    # submit (math.inf when the tenant declares no TTFT target).
    deadline: float | None = None
    tenant: object = None                # opaque tenant id for the policy
    out_tokens: list = dataclasses.field(default_factory=list)


def poisson_requests(rng, n: int, rate: float, vocab: int, prompt_len: int,
                     max_new_lo: int, max_new_hi: int) -> list[Request]:
    """n requests with Exp(1/rate) inter-arrival gaps (a Poisson process,
    in decode-step time units) and uniform output lengths in
    [max_new_lo, max_new_hi]."""
    t = 0.0
    reqs = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        reqs.append(Request(
            prompt=list(rng.integers(1, vocab, prompt_len)),
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1)),
            arrival=t))
    return reqs


def serve_stream(step_fn, pools) -> None:
    """Arrival-clock driver shared by the continuous engines.

    ``pools``: (engine, requests) pairs — one for the single-model engine,
    two (lockstep) for the colocated engine. Each tick admits every request
    whose ``arrival`` has passed (same-arrival requests in list order), runs
    one ``step_fn()``, and jumps the clock over idle gaps when nothing is
    active but requests are still due.
    """
    streams = [[eng, sorted(reqs, key=lambda r: r.arrival), 0]
               for eng, reqs in pools]
    t = 0.0
    while any(i < len(p) or e.queue or e.num_active or e.num_pending
              for e, p, i in streams):
        for s in streams:
            eng, pend, i = s
            while i < len(pend) and pend[i].arrival <= t:
                eng.submit(pend[i])
                i += 1
            s[2] = i
        due = [p[i].arrival for _, p, i in streams if i < len(p)]
        if not step_fn() and due:
            t = max(t + 1.0, min(due))               # jump idle gaps
        else:
            t += 1.0


class ServingEngine:
    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0, jit: bool = True):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        # Cache buffers are donated: the update aliases in place instead of
        # copying the full KV/SSM state every step.
        self._prefill = (jax.jit(model.prefill, donate_argnums=(2,))
                         if jit else model.prefill)
        self._decode = (jax.jit(model.decode_step, donate_argnums=(2,))
                        if jit else model.decode_step)
        self.decode_steps = 0            # decode invocations (for benchmarks)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch_slots, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def serve(self, reqs: list[Request], frames=None) -> list[Request]:
        """Run one batch of requests to completion (greedy decoding)."""
        if len(reqs) > self.batch_slots:
            raise ValueError("too many requests for the batch")
        toks = self._pad_prompts(reqs)
        cache = self.model.init_cache(self.batch_slots, self.cache_cap,
                                      src_len=self.src_len)
        inputs = {"tokens": jnp.asarray(toks)}
        if frames is not None:
            inputs["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, inputs, cache)
        tok = jnp.argmax(logits[:, -1:, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in reqs)
        for _ in range(steps):
            for i, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
            logits, cache = self._decode(self.params, tok, cache)
            self.decode_steps += 1
            tok = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                             axis=-1).astype(jnp.int32)
        return reqs


class ContinuousEngine:
    """Continuous-batching scheduler over ``batch_slots`` decode slots.

    ``prefill_len``: fixed left-pad length for per-slot prefills (one compiled
    prefill program). ``None`` buckets each prompt to the next power of two
    (one compilation per bucket). A prompt padded to length P behaves exactly
    like the static engine's batch padded to P, so outputs are
    token-identical when the pad lengths agree.

    The slot state machine lives host-side (``queue`` + ``slots``); device
    state is the shared cache (per-slot lengths) and the (B, 1) current-token
    buffer. Free slots keep decoding garbage rows — attention is batch-row
    independent and the rows are overwritten at the next admission — so the
    decode step is one fixed-shape jitted program regardless of occupancy.
    """

    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0,
                 config: EngineConfig | None = None, monitor=None,
                 **legacy):
        config = coerce_config(config, legacy, type(self).__name__)
        self.config = config
        model = config.kernelize(model)
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        self.admission = config.resolve_admission()
        # The single-model engine hosts ONE tenant: its spec (SLO targets)
        # turns into per-request deadlines at submit. The colocated /
        # multi-tenant engines split their config's tenants across pools.
        if len(config.tenants) > 1:
            raise ValueError(
                f"{type(self).__name__} hosts one tenant; "
                f"config.tenants has {len(config.tenants)} — use "
                "MultiTenantContinuousEngine for several")
        self.tenant_spec = config.tenants[0] if config.tenants else None
        # Derived views kept for callers that inspected the old attributes.
        self.prefill_len = config.prefill_len
        self.prefill_chunk = self.admission.chunk
        self.step_token_budget = self.admission.budget
        self._bucketer = make_bucketer(self.admission.bucket_policy)
        self._pool_size = config.prefill_pool
        self.monitor = monitor
        self.cache = model.init_cache(batch_slots, cache_cap,
                                      src_len=src_len, per_slot_len=True)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * batch_slots
        # In-flight chunked prefills, arrival order: [req, slot,
        # padded_toks, done]. The admission policy's select() picks which
        # of their due chunks run each step (deadline policies reorder).
        self._pending: list[list] = []
        # Exclusive-scenario expert->device assignment REALIZED in params
        # (identity unless an exclusive plan was adopted); None = non-MoE.
        self.assignment = (list(range(model.cfg.moe.n_experts))
                          if model.cfg.moe is not None else None)
        self._jit = config.jit
        # Distributed engines wrap every compiled step so it runs under the
        # mesh context (``with_sharding_constraint`` needs an active mesh on
        # legacy jax); identity for the single-device engines.
        self._step_wrapper = config.step_wrapper or (lambda fn: fn)
        # Optional telemetry hub (``config.telemetry``): compiled steps get
        # span-wrapped in ``_build_steps`` and the scheduler publishes
        # shed/adoption events + queue/TTFT/token metrics. None (default)
        # keeps the exact untelemetered code path — no wrapper, no per-step
        # work.
        self._telemetry = config.telemetry
        self._tenant_label = (self.tenant_spec.name
                              if self.tenant_spec is not None else "")
        self._build_steps()
        self.decode_steps = 0
        # Shed-mode admission: every rejected submit is recorded here as a
        # typed ``ShedEvent`` (and returned from ``submit``) — rejections
        # are observable per tenant, never silent stalls. Bounded ring
        # (``config.event_capacity``), drop-oldest; evictions are counted
        # on ``shed_events.dropped``.
        self.shed_events: RingBuffer = RingBuffer(config.event_capacity)

    def _live_rounds(self):
        """The CURRENT BvN round schedule (None off the distributed path).
        Read through ``self.model`` at call time so telemetry follows
        mid-stream rounds swaps (``_rebind``)."""
        return getattr(self.model.pc, "aurora_rounds", None)

    def _wrap_step_fn(self, fn, name: str, rounds: bool = False):
        """Compose the step wrappers for one compiled step: the configured
        ``step_wrapper`` (mesh context / fault injection) innermost, the
        telemetry span wrapper — when a hub is attached — outermost, so
        span timing covers the full wrapped call."""
        fn = self._step_wrapper(fn)
        tel = self._telemetry
        if tel is None:
            return fn
        return tel.wrap_step(fn, name, tenant=self._tenant_label or None,
                             rounds=self._live_rounds if rounds else None)

    def _build_steps(self) -> None:
        """(Re)build the jitted step programs from ``self.model``."""
        model, jit, wrap = self.model, self._jit, self._wrap_step_fn
        stats = self.monitor is not None
        fn_p = partial(model.prefill_slot, cap=self.cache_cap,
                       src_len=self.src_len, collect_moe_stats=stats)
        self._prefill = wrap(jax.jit(fn_p, donate_argnums=(2,))
                             if jit else fn_p, "prefill")
        # Chunked prefill runs straight against the shared per-slot cache:
        # each chunk slices the slot row, continues the prefill, and merges
        # back in ONE donated program (``Model.prefill_chunk_slot``) — no
        # detached batch-1 cache lives on the host between chunks.
        fn_c0 = partial(model.prefill_chunk_slot, first=True,
                        cap=self.cache_cap, src_len=self.src_len,
                        collect_moe_stats=stats)
        self._chunk_first = wrap(jax.jit(fn_c0, donate_argnums=(2,))
                                 if jit else fn_c0, "prefill_chunk")
        fn_c = partial(model.prefill_chunk_slot, first=False,
                       cap=self.cache_cap, src_len=self.src_len,
                       collect_moe_stats=stats)
        self._chunk = wrap(jax.jit(fn_c, donate_argnums=(2,))
                           if jit else fn_c, "prefill_chunk")
        fn_d = model.decode_step_stats if stats else model.decode_step
        self._decode = wrap(jax.jit(fn_d, donate_argnums=(2,))
                            if jit else fn_d, "decode_step", rounds=True)
        if self._pool_size > 1:
            fn_pool = self._make_pool_fn(stats)
            self._pool_step = wrap(
                jax.jit(fn_pool, static_argnums=(0, 1), donate_argnums=(4,))
                if jit else fn_pool, "pool_step", rounds=True)

    def _make_pool_fn(self, stats: bool):
        """The pooled-admission program: K chunked prefills (and, when
        ``decode`` is set, the decode step over all slots) threaded through
        the shared donated cache in ONE jitted function.

        Each prefill stays a batch-1 ``prefill_chunk_slot`` sub-call — MoE
        capacity and dispatch ranks are computed per token group, so
        batching the K chunks into one (K, C) group would route with K*C
        tokens of rank competition and break token identity with serialized
        admission. Composing the sub-calls keeps the math bit-identical
        while XLA fuses/schedules them as one program (one dispatch per
        engine step instead of up to K+1).

        ``firsts`` (per-chunk fresh-slot flags) and ``decode`` are static:
        the program retraces per (pool shape, firsts, decode) combination,
        bounded in practice by the chunk bucketing.
        """
        model = self.model
        chunk = partial(model.prefill_chunk_slot, cap=self.cache_cap,
                        src_len=self.src_len, collect_moe_stats=stats)
        dec = model.decode_step_stats if stats else model.decode_step

        def pool_fn(firsts, decode, params, toks, cache, slots, tokens,
                    mask):
            chunk_out = []
            for inp, slot, first in zip(toks, slots, firsts):
                out = chunk(params, inp, cache, slot, first=first)
                if stats:
                    logits, cache, st = out
                else:
                    (logits, cache), st = out, None
                chunk_out.append((logits, st))
            dec_out = None
            if decode:
                out = dec(params, tokens, cache, mask)
                if stats:
                    logits, cache, st = out
                else:
                    (logits, cache), st = out, None
                dec_out = (logits, st)
            return chunk_out, dec_out, cache

        return pool_fn

    def _rebind(self, model: Model) -> None:
        """Swap the model (e.g. a ``ParallelContext`` with fresh ppermute
        rounds) and rebuild the jitted steps. Serving state — cache, slots,
        queue, in-flight prefill — is untouched: a rebind mid-stream is
        placement-only as long as the new model computes the same function."""
        self.model = model
        self._build_steps()

    def _set_replication(self, spec) -> None:
        """Install a hot-expert ``ReplicationSpec`` (placement-only).

        De-replicates the current expert leaves back to the logical frame,
        widens them under the new spec (pure copies of their home experts),
        and rebinds with ``pc.moe_replication`` updated. Routing, capacity
        and drops all stay in the logical frame (the shard-of-token rule in
        ``models.moe``), so a mid-stream swap cannot change emitted tokens."""
        from repro.models.moe import (dereplicate_moe_params,
                                      replicate_moe_params)
        cur = self.model.pc.moe_replication
        if spec is not None and spec.is_identity:
            spec = None
        if (None if cur is None else cur.counts) == \
                (None if spec is None else spec.counts):
            return
        params = self.params
        if cur is not None:
            params = dereplicate_moe_params(params, cur)
        if spec is not None:
            params = replicate_moe_params(params, spec)
        self.params = params
        pc = dataclasses.replace(self.model.pc, moe_replication=spec)
        self._rebind(dataclasses.replace(self.model, pc=pc))
        record_adoption(self._telemetry, "replication",
                        step=self.decode_steps,
                        counts=None if spec is None else spec.counts)

    def adopt_replication(self, replication) -> None:
        """Adopt a planner host map (``Plan.replication`` — per-expert host
        tuples — or a bare per-expert copy-count sequence). ``None`` or the
        identity map drops back to unreplicated serving."""
        from repro.models.moe import ReplicationSpec
        if replication is None:
            spec = None
        else:
            counts = tuple(
                len(h) if hasattr(h, "__len__") else int(h)
                for h in replication)
            spec = ReplicationSpec.from_counts(counts)
        self._set_replication(spec)

    def adopt_assignment(self, expert_to_device) -> None:
        """Adopt an exclusive-scenario expert->GPU assignment (Thm 5.1)
        placement-only: device slot d's expert leaves are re-seated so
        expert e sits on ``expert_to_device[e]``, and the router columns
        follow (``reseat_pairing``), so the composed function — and every
        emitted token — is unchanged. The monitor's stats frame is updated
        to the new slot->expert map.

        In this engine "device slot" is a position along the expert axis —
        exactly how EP sharding places contiguous expert blocks, so the
        same adoption is a REAL device move under ``DistributedEngine``."""
        from repro.serving.colocated import inverse_pair, reseat_pairing
        if self.assignment is None:
            raise PlanError("adopt_assignment needs an MoE model "
                            "(expert->device assignment is per expert)")
        e2d = [int(x) for x in np.asarray(expert_to_device).tolist()]
        n_e = len(self.assignment)
        if sorted(e2d) != list(range(n_e)):
            raise PlanError(
                f"expert_to_device {e2d} is not a permutation of "
                f"0..{n_e - 1} — exclusive assignment places one expert "
                "per device")
        if e2d == self.assignment:
            return
        if self.model.pc.moe_replication is not None:
            raise PlanError(
                "cannot re-seat an expert assignment while replicas are "
                "live — adopt_replication(None) first (the replicated "
                "leaves are in the widened physical frame)")
        old_pair = inverse_pair(self.assignment)   # device slot -> expert
        new_pair = inverse_pair(e2d)
        self.params = reseat_pairing(self.params, old_pair, new_pair,
                                     self.model.cfg)
        self.assignment = e2d
        if self.monitor is not None:
            self.monitor.slot_to_expert = new_pair
        record_adoption(self._telemetry, "assignment",
                        step=self.decode_steps, expert_to_device=e2d)

    def adopt(self, plan) -> None:
        """Unified adoption surface (one verb across every engine): take
        whatever placement evidence the caller has and re-realize it
        placement-only, mid-stream. For the single-model engine that is a
        full exclusive-scenario ``Plan`` (its ``.expert_to_device``
        assignment and/or ``.replication`` host map), a bare per-expert
        host-map/copy-count sequence, or ``None`` to drop back to
        unreplicated serving. The colocated/multi-tenant engines extend
        this verb to pairing/grouping, the distributed engines to Aurora
        round refresh."""
        if not hasattr(plan, "schedules"):
            self.adopt_replication(plan)
            return
        if (plan.pair is None and plan.groups is None
                and plan.replication is None and self.assignment is not None
                and len(plan.expert_to_device) == len(self.assignment)):
            self.adopt_assignment(plan.expert_to_device)
        self.adopt_replication(plan.replication)

    # -- scheduler ---------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def num_pending(self) -> int:
        """In-flight chunked prefills (up to ``config.prefill_pool``)."""
        return len(self._pending)

    def submit(self, req: Request) -> ShedEvent | None:
        # Final per-slot length is pad(prompt) + max_new_tokens - 1 (the
        # last emitted token is never written back); beyond cache_cap the
        # decode path would silently overwrite slot cap-1 every step.
        p = self._bucket(len(req.prompt))
        need = p + max(req.max_new_tokens - 1, 0)
        if need > self.cache_cap:
            raise ValueError(
                f"prompt + generation needs {need} cache slots, "
                f"capacity is {self.cache_cap}")
        if (self.prefill_chunk is not None
                and not self.model.supports_chunked_prefill(
                    p, self.cache_cap)):
            raise ValueError(
                f"{self.model.cfg.arch_id}: a {p}-token prefill cannot be "
                "chunked (MLA / encoder-decoder, or a prompt that WRAPS "
                "the sliding-window ring — prompts inside the ring chunk "
                "fine) — use prefill_chunk=None for this engine")
        if req.deadline is None:
            # Per-request deadlines default from the tenant's SLO target
            # (TenantSpec.ttft_p95); no tenant or no target = no deadline.
            req.deadline = (self.tenant_spec.deadline(req.arrival)
                            if self.tenant_spec is not None else math.inf)
        if req.tenant is None and self.tenant_spec is not None:
            req.tenant = self.tenant_spec.name
        # Shed-mode admission (``EdfAdmission(shed=True)``): reject — as a
        # typed result, not an exception — when the queue is capped out or
        # the deadline is provably unattainable at current queue depth.
        shed_reason = getattr(self.admission, "shed_reason", None)
        if shed_reason is not None:
            def spec_of(r):
                b = self._bucket(len(r.prompt))
                return self._spec(r, min(self.prefill_chunk or b, b))
            reason = shed_reason(spec_of(req),
                                 [spec_of(r) for r in self.queue],
                                 self.num_active + self.num_pending)
            if reason is not None:
                ev = ShedEvent(tenant=req.tenant, arrival=req.arrival,
                               reason=reason, request=req)
                self.shed_events.append(ev)
                tel = self._telemetry
                if tel is not None and tel.enabled:
                    tel.count("serving_sheds_total",
                              help="submits rejected by shed-mode admission",
                              tenant=str(req.tenant), reason=reason)
                    tel.publish("shed", ev, step=self.decode_steps)
                return ev
        self.queue.append(req)
        return None

    def _bucket(self, n: int) -> int:
        if self.prefill_len is not None:
            if n > self.prefill_len:
                raise ValueError(f"prompt len {n} > prefill_len "
                                 f"{self.prefill_len}")
            return self.prefill_len
        p = self._bucketer(n)
        if p < n:
            raise ValueError(f"bucket policy shrank {n} to {p}")
        p = min(p, self.cache_cap)
        if self.prefill_chunk is not None:
            # A pow2/step pad can push a prompt that FITS a sliding-window
            # ring past it (e.g. 10 tokens padded to 16 over a 12-ring) and
            # trigger the wrapped-ring refusal; clamp the pad to the ring so
            # only genuinely wrapping prompts are refused. Applied in
            # _bucket so submit and admission agree on the padded length.
            lim = self.model.chunkable_len(self.cache_cap)
            if lim is not None and n <= lim:
                p = min(p, lim)
        return p

    def _free_slot(self) -> int | None:
        """First free slot not reserved by an in-flight prefill."""
        reserved = {p[1] for p in self._pending}
        for i, r in enumerate(self.slots):
            if r is None and i not in reserved:
                return i
        return None

    def _spec(self, r: Request, chunk: int) -> RequestSpec:
        """The admission policy's view of one pending request."""
        return RequestSpec(
            chunk=int(chunk), prompt_len=len(r.prompt), arrival=r.arrival,
            deadline=math.inf if r.deadline is None else r.deadline,
            tenant=r.tenant)

    @staticmethod
    def _check_selection(order, n: int) -> list[int]:
        """Sanitize a policy's select()/order() result: indices must be
        unique and in range (a buggy policy would otherwise run the same
        chunk twice against the donated cache)."""
        idx = [int(i) for i in order]
        if len(set(idx)) != len(idx) or any(not 0 <= i < n for i in idx):
            raise ValueError(
                f"admission policy returned invalid indices {idx} for "
                f"{n} pending requests (need unique ints in range)")
        return idx

    def _pop_queue(self) -> Request:
        """Next queued request per the policy's queue discipline
        (``order`` — FIFO for the stock policies, earliest effective
        deadline for ``EdfAdmission``)."""
        if len(self.queue) > 1:
            specs = [self._spec(r, min(self.prefill_chunk
                                       or self._bucket(len(r.prompt)),
                                       self._bucket(len(r.prompt))))
                     for r in self.queue]
            order = self._check_selection(self.admission.order(specs),
                                          len(specs))
            if order:
                r = self.queue[order[0]]
                del self.queue[order[0]]
                return r
        return self.queue.popleft()

    def _finish_admission(self, r: Request, slot: int, logits) -> None:
        """Shared tail of one-shot and chunked admission: emit the first
        token and occupy the slot (unless the request is already done)."""
        tok0 = int(jnp.argmax(logits[0, -1, : self.model.cfg.vocab]))
        if r.max_new_tokens > 0:
            r.out_tokens.append(tok0)
        if len(r.out_tokens) < r.max_new_tokens:
            self.slots[slot] = r
            self.tokens = self.tokens.at[slot, 0].set(tok0)
        tel = self._telemetry
        if tel is not None and tel.enabled and r.max_new_tokens > 0:
            tel.count("serving_tokens_total",
                      help="tokens emitted", tenant=self._tenant_label)
            tel.observe("serving_ttft_steps",
                        max(0.0, self.decode_steps - r.arrival),
                        help="engine steps from arrival to first token "
                             "(step clock)",
                        bounds=STEP_BOUNDS, tenant=self._tenant_label)

    def _admit(self) -> None:
        """Drain the queue into free slots (one-shot per-slot prefill each,
        in the policy's queue order)."""
        while self.queue and None in self.slots:
            slot = self.slots.index(None)
            r = self._pop_queue()
            p = self._bucket(len(r.prompt))
            toks = np.zeros((1, p), np.int32)
            toks[0, p - len(r.prompt):] = r.prompt      # left-pad with 0
            out = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.cache,
                jnp.int32(slot))
            if self.monitor is not None:
                logits, self.cache, stats = out
                self._observe_prefill(stats, pad=p - len(r.prompt))
            else:
                logits, self.cache = out
            self._finish_admission(r, slot, logits)

    def _admit_tick(self) -> bool:
        """One scheduler tick of admission work. Returns True iff chunked
        prefill progressed (one-shot admissions surface via num_active)."""
        if self.prefill_chunk is None:
            self._admit()
            return False
        if self._pool_size > 1:
            return self._pool_tick(fuse_decode=False)
        return self._prefill_tick()

    def _start_pending(self, slot: int) -> None:
        """Pop the policy's next queued request into a reserved slot as an
        in-flight prefill."""
        r = self._pop_queue()
        p = self._bucket(len(r.prompt))
        toks = np.zeros((1, p), np.int32)
        toks[0, p - len(r.prompt):] = r.prompt          # left-pad with 0
        self._pending.append([r, slot, toks, 0])

    def _prefill_tick(self) -> bool:
        """Serialized chunked admission (``prefill_pool=1``): start or
        advance the single in-flight prefill by at most one
        ``prefill_chunk``-token chunk, as the admission policy allows. Every
        chunk lands directly in the slot's row of the shared cache; between
        chunks the decode step freezes that row (``row_mask``), so the
        partial state survives interleaved decode ticks untouched."""
        if not self._pending:
            slot = self._free_slot()
            if not self.queue or slot is None:
                return False
            self._start_pending(slot)
        r, slot, toks, done = self._pending[0]
        c = min(self.prefill_chunk, toks.shape[1] - done)
        # Decode always runs and eats num_active tokens of any budget; the
        # chunk only proceeds when the policy admits it. Progress is
        # guaranteed: decode drains slots, so num_active falls and the
        # leftover eventually covers a chunk (or the pool empties and the
        # budget gate is bypassed entirely).
        if not self.admission.select(self.num_active, [self._spec(r, c)]):
            return False
        chunk_toks = {"tokens": jnp.asarray(toks[:, done:done + c])}
        # The first chunk starts the slot from a fresh zero state (no
        # leakage from the previous occupant); later chunks resume from the
        # slot's own recorded fill level.
        fn = self._chunk_first if done == 0 else self._chunk
        out = fn(self.params, chunk_toks, self.cache, jnp.int32(slot))
        if self.monitor is not None:
            logits, self.cache, stats = out
            # The chunk covers padded positions [done, done+c); left-pad
            # spans [0, total - len(prompt)) of the padded prompt.
            self._observe_prefill(
                stats, pad=(toks.shape[1] - len(r.prompt)) - done)
        else:
            logits, self.cache = out
        done += c
        if done < toks.shape[1]:
            self._pending[0][3] = done
            return True
        self._pending.pop(0)
        self._finish_admission(r, slot, logits)
        return True

    def _pool_tick(self, fuse_decode: bool) -> bool:
        """Pooled chunked admission (``prefill_pool=K``): top the pool up
        from the queue, then run every policy-admitted due chunk — and, when
        ``fuse_decode`` is set and slots are occupied, the decode step — as
        ONE jitted program against the shared cache.

        The pool tops up in the policy's queue order and the policy's
        ``select`` picks which due chunks run (the stock policies admit a
        FIFO prefix; deadline policies reorder) — either way emitted token
        streams are identical to serialized admission, since each request's
        tokens depend only on its own slot rows; only the schedule changes.
        Bookkeeping order matters: ``_postdecode`` replaces ``self.tokens``
        wholesale with this step's argmax, so it must land BEFORE
        ``_finish_admission`` writes a freshly admitted slot's first token.
        """
        while len(self._pending) < self._pool_size and self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            self._start_pending(slot)
        chunks = [min(self.prefill_chunk, p[2].shape[1] - p[3])
                  for p in self._pending]
        specs = [self._spec(p[0], c)
                 for p, c in zip(self._pending, chunks)]
        picked = self._check_selection(
            self.admission.select(self.num_active, specs), len(specs))
        decode = fuse_decode and self.num_active > 0
        if not picked and not decode:
            return False
        sel = [self._pending[i] for i in picked]
        sel_chunks = [chunks[i] for i in picked]
        toks = tuple({"tokens": jnp.asarray(p[2][:, p[3]:p[3] + c])}
                     for p, c in zip(sel, sel_chunks))
        slot_ids = tuple(jnp.int32(p[1]) for p in sel)
        firsts = tuple(p[3] == 0 for p in sel)
        mask = np.array([r is not None for r in self.slots], bool)
        chunk_out, dec_out, self.cache = self._pool_step(
            firsts, bool(decode), self.params, toks, self.cache, slot_ids,
            self.tokens, jnp.asarray(mask))
        if decode:
            dlogits, dstats = dec_out
            if self.monitor is not None:
                self._observe_decode_routing(dstats, mask)
            self.decode_steps += 1
            self._postdecode(dlogits)
        finished = []
        for p, c, (logits, pstats) in zip(sel, sel_chunks, chunk_out):
            r, slot, tk, done = p
            if self.monitor is not None:
                self._observe_prefill(
                    pstats, pad=(tk.shape[1] - len(r.prompt)) - done)
            p[3] = done + c
            if p[3] >= tk.shape[1]:
                finished.append((p, logits))
        for p, logits in finished:
            self._pending.remove(p)
            self._finish_admission(p[0], p[1], logits)
        return True

    def _observe_decode_routing(self, stats, mask) -> None:
        """Fold decode routing counts into the monitor and — when a
        telemetry hub is attached — the per-layer load gauges."""
        self.monitor.observe(stats, mask)
        tel = self._telemetry
        if tel is None or not tel.enabled:
            return
        arr = np.asarray(stats, np.float64)          # (L, B, E)
        if mask is not None:
            arr = arr * np.asarray(mask, np.float64)[None, :, None]
        totals = arr.sum(axis=1)                     # (L, E)
        moe = self.model.cfg.moe
        cf = moe.capacity_factor if moe is not None else None
        for l, row in enumerate(totals):
            tot = float(row.sum())
            if tot <= 0:
                continue
            tel.gauge("moe_expert_load_imbalance",
                      float(row.max()) * row.size / tot,
                      help="max/mean expert load this decode step "
                           "(1.0 = perfectly balanced)", layer=l)
            if cf:
                cap = cf * tot / row.size
                tel.gauge("moe_expert_drop_rate",
                          float(np.maximum(row - cap, 0.0).sum()) / tot,
                          help="estimated fraction of routed tokens over "
                               "per-expert capacity (capacity_factor rule "
                               "applied to this step's counts)", layer=l)

    def _observe_prefill(self, stats, pad: int) -> None:
        """Fold prefill routing counts into the monitor, dropping the first
        ``pad`` positions (left-padding routes token id 0 every time and
        would skew the popularity estimate toward phantom traffic)."""
        arr = np.asarray(stats)                      # (L, 1, S, E)
        real = arr[:, :, max(pad, 0):, :]
        if real.shape[2]:
            self.monitor.observe(real.sum(axis=2))

    def _postdecode(self, logits) -> None:
        """Emit one token per occupied slot; evict finished requests."""
        nxt = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        self.tokens = nxt
        host = np.asarray(nxt)
        emitted = 0
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.out_tokens.append(int(host[i, 0]))
            emitted += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                self.slots[i] = None                     # slot free for reuse
        tel = self._telemetry
        if tel is not None and tel.enabled and emitted:
            tel.count("serving_tokens_total", emitted,
                      help="tokens emitted", tenant=self._tenant_label)

    def _decode_all(self):
        """One fixed-shape decode over every slot (stats-aware).

        Vacant rows are masked out of cache updates (``row_mask``): their
        state and fill level freeze, which keeps a partially chunk-prefilled
        slot's row byte-stable between chunks. Occupied rows are unaffected
        — attention is batch-row independent — so masking never changes
        emitted tokens."""
        mask = np.array([r is not None for r in self.slots], bool)
        if self.monitor is not None:
            logits, self.cache, stats = self._decode(self.params, self.tokens,
                                                     self.cache,
                                                     jnp.asarray(mask))
            self._observe_decode_routing(stats, mask)
        else:
            logits, self.cache = self._decode(self.params, self.tokens,
                                              self.cache, jnp.asarray(mask))
        return logits

    def step(self) -> bool:
        """Admit (whole prefills, or policy-admitted chunks), then decode
        all slots once. Returns False when idle.

        With a prefill pool (``prefill_pool > 1``) the whole step — every
        due prefill chunk AND the decode — is one fused program: a finishing
        request's first decode shifts one engine step later than in the
        serialized schedule, but per-request token streams are unchanged
        (its first token comes from the prefill logits either way)."""
        tel = self._telemetry
        if tel is None or not tel.enabled:
            return self._step_impl()
        with tel.span("engine_step", step=self.decode_steps,
                      tenant=self._tenant_label or None):
            tel.gauge("serving_queue_depth", len(self.queue),
                      help="requests waiting for admission",
                      tenant=self._tenant_label)
            return self._step_impl()

    def _step_impl(self) -> bool:
        if self._pool_size > 1:
            return self._pool_tick(fuse_decode=True)
        worked = self._admit_tick()
        if self.num_active == 0:
            return worked
        logits = self._decode_all()
        self.decode_steps += 1
        self._postdecode(logits)
        return True

    # -- fault tolerance ---------------------------------------------------
    def checkpoint(self) -> dict:
        """Host-side snapshot of the serving state — cache, token buffer,
        slot map, queue, in-flight prefills, emitted-token lengths — for
        step-level rollback after a detected-corrupt step (NaN weights
        caught by the ``HealthMonitor`` mid-step). Request objects are
        shared with the live engine; ``restore`` rewinds their
        ``out_tokens`` to the recorded lengths."""
        reqs = {id(r): r for r in self.slots if r is not None}
        for r in self.queue:
            reqs[id(r)] = r
        for p in self._pending:
            reqs[id(p[0])] = p[0]
        return {
            "cache": jax.tree_util.tree_map(np.asarray, self.cache),
            "tokens": np.asarray(self.tokens),
            "slots": list(self.slots),
            "queue": list(self.queue),
            "pending": [[p[0], p[1], p[2].copy(), p[3]]
                        for p in self._pending],
            "out_lens": [(r, len(r.out_tokens)) for r in reqs.values()],
            "decode_steps": self.decode_steps,
        }

    def restore(self, snap: dict) -> None:
        """Roll the engine back to a ``checkpoint`` snapshot. The recovery
        loop restores, repairs the weights (``repair_moe_params`` from a
        healthy replica), and re-runs the step — deterministic greedy
        decoding makes the re-run byte-identical to a never-faulted run."""
        self.cache = jax.tree_util.tree_map(jnp.asarray, snap["cache"])
        self.tokens = jnp.asarray(snap["tokens"])
        self.slots = list(snap["slots"])
        self.queue = collections.deque(snap["queue"])
        self._pending = [[p[0], p[1], p[2].copy(), p[3]]
                         for p in snap["pending"]]
        for r, ln in snap["out_lens"]:
            del r.out_tokens[ln:]
        self.decode_steps = snap["decode_steps"]

    def requeue(self, slots) -> list[Request]:
        """Fail-stop eviction: push the requests occupying ``slots`` (and
        any in-flight prefill reserving them) back onto the FRONT of the
        queue with their generation reset. The slots' cache rows are
        treated as lost — re-admission re-prefills from the prompt, and
        deterministic greedy decoding re-emits the exact same stream, so a
        re-queued request that completes is byte-identical to its un-failed
        run. Returns the evicted requests (re-queue order)."""
        lost = sorted({int(s) for s in slots})
        for s in lost:
            if not 0 <= s < self.batch_slots:
                raise FaultError(
                    f"cannot requeue slot {s}: out of "
                    f"range({self.batch_slots})")
        lost_set = set(lost)
        victims: list[Request] = []
        for p in list(self._pending):
            if p[1] in lost_set:
                self._pending.remove(p)
                victims.append(p[0])
        for s in lost:
            r = self.slots[s]
            if r is not None:
                self.slots[s] = None
                victims.append(r)
        for r in victims:
            r.out_tokens.clear()
        for r in reversed(victims):
            self.queue.appendleft(r)
        return victims

    # -- driver ------------------------------------------------------------
    def serve(self, reqs: list[Request]) -> list[Request]:
        """Run a request stream to completion, honoring ``arrival`` times
        (measured in engine steps; requests arriving at the same step are
        admitted in list order)."""
        serve_stream(self.step, [(self, reqs)])
        return reqs
