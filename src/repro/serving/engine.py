"""Serving engines: static fixed-batch and continuous batching.

``ServingEngine`` (the original) runs one fixed-shape batch to completion:
requests are left-padded to a common prompt length, and the whole batch
decodes for ``max(max_new_tokens)`` steps — throughput stalls on the longest
request, and nothing can start until the batch is done.

``ContinuousEngine`` owns a request queue plus ``batch_slots`` decode slots
over a shared, donated KV/SSM cache with **per-slot lengths**
(``init_cache(per_slot_len=True)``). Each step the scheduler admits queued
requests into free slots — a per-slot prefill writes one request's state into
its slot row (``Model.prefill_slot``) — then decodes every slot in one jitted
step and evicts finished requests, so a short request's slot is immediately
reusable while long requests keep decoding. Same math as the static engine
(per-row attention masking via the per-slot length vector), different
schedule.

**Chunked prefill** (``prefill_chunk=C``): instead of absorbing a whole
prompt in one admission step — stalling every active slot's decode behind a
long prefill — the prompt is consumed ``C`` tokens per engine step straight
into its slot's row of the shared cache (``Model.prefill_chunk_slot``:
slice, continue, merge in one donated program). Between chunks the decode
step freezes the pending slot's row (``row_mask``), so the partial state
survives interleaved decodes. Each step runs under a token budget: decode
always runs; leftover budget feeds at most ONE prefill chunk
(``step_token_budget``). Token streams are identical to one-shot admission
(prefill continuation is exact — see ``models.transformer.forward``); only
the schedule changes.

**Live routing stats** (``monitor=TrafficMonitor(...)``): decode steps and
prefills report per-layer expert routing counts, feeding the traffic-driven
re-planner (``repro.serving.monitor``).

**Kernel path** (``kernels=True`` or a ``KernelConfig``): the engine's jitted
steps run through the Pallas serving hot path — sort-based ragged MoE
dispatch into the fused grouped FFN and flash-decode attention over the
per-slot cache (``Model.with_kernels``). Same routing/capacity semantics,
so token streams match the dense path; routing counts still flow to the
monitor (derived from the routing output by the shared ``routed_counts``
scatter, no one-hot).
"""

from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


def make_bucketer(policy) -> Callable[[int], int]:
    """Resolve a prefill bucketing policy to ``fn(prompt_len) -> pad_len``.

    Policies (ROADMAP follow-up: beyond hardcoded powers of two):
      "pow2"     next power of two — few compiled prefill programs (default)
      "exact"    no padding — one compilation per distinct prompt length
      "step:K"   round up to a multiple of K — linear compile count, less pad
      callable   custom ``fn(n) -> >= n``
    """
    if callable(policy):
        return policy
    if policy == "pow2":
        def pow2(n: int) -> int:
            p = 1
            while p < n:
                p *= 2
            return p
        return pow2
    if policy == "exact":
        return lambda n: n
    if isinstance(policy, str) and policy.startswith("step:"):
        k = int(policy.split(":", 1)[1])
        if k <= 0:
            raise ValueError(f"bucket step must be positive, got {k}")
        return lambda n: -(-n // k) * k
    raise ValueError(f"unknown bucket policy {policy!r} "
                     "(expected 'pow2', 'exact', 'step:K', or a callable)")


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    arrival: float = 0.0                 # engine-step time of arrival
    out_tokens: list = dataclasses.field(default_factory=list)


def poisson_requests(rng, n: int, rate: float, vocab: int, prompt_len: int,
                     max_new_lo: int, max_new_hi: int) -> list[Request]:
    """n requests with Exp(1/rate) inter-arrival gaps (a Poisson process,
    in decode-step time units) and uniform output lengths in
    [max_new_lo, max_new_hi]."""
    t = 0.0
    reqs = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        reqs.append(Request(
            prompt=list(rng.integers(1, vocab, prompt_len)),
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1)),
            arrival=t))
    return reqs


def serve_stream(step_fn, pools) -> None:
    """Arrival-clock driver shared by the continuous engines.

    ``pools``: (engine, requests) pairs — one for the single-model engine,
    two (lockstep) for the colocated engine. Each tick admits every request
    whose ``arrival`` has passed (same-arrival requests in list order), runs
    one ``step_fn()``, and jumps the clock over idle gaps when nothing is
    active but requests are still due.
    """
    streams = [[eng, sorted(reqs, key=lambda r: r.arrival), 0]
               for eng, reqs in pools]
    t = 0.0
    while any(i < len(p) or e.queue or e.num_active or e.num_pending
              for e, p, i in streams):
        for s in streams:
            eng, pend, i = s
            while i < len(pend) and pend[i].arrival <= t:
                eng.submit(pend[i])
                i += 1
            s[2] = i
        due = [p[i].arrival for _, p, i in streams if i < len(p)]
        if not step_fn() and due:
            t = max(t + 1.0, min(due))               # jump idle gaps
        else:
            t += 1.0


class ServingEngine:
    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0, jit: bool = True):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        # Cache buffers are donated: the update aliases in place instead of
        # copying the full KV/SSM state every step.
        self._prefill = (jax.jit(model.prefill, donate_argnums=(2,))
                         if jit else model.prefill)
        self._decode = (jax.jit(model.decode_step, donate_argnums=(2,))
                        if jit else model.decode_step)
        self.decode_steps = 0            # decode invocations (for benchmarks)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch_slots, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def serve(self, reqs: list[Request], frames=None) -> list[Request]:
        """Run one batch of requests to completion (greedy decoding)."""
        if len(reqs) > self.batch_slots:
            raise ValueError("too many requests for the batch")
        toks = self._pad_prompts(reqs)
        cache = self.model.init_cache(self.batch_slots, self.cache_cap,
                                      src_len=self.src_len)
        inputs = {"tokens": jnp.asarray(toks)}
        if frames is not None:
            inputs["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, inputs, cache)
        tok = jnp.argmax(logits[:, -1:, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in reqs)
        for _ in range(steps):
            for i, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
            logits, cache = self._decode(self.params, tok, cache)
            self.decode_steps += 1
            tok = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                             axis=-1).astype(jnp.int32)
        return reqs


class ContinuousEngine:
    """Continuous-batching scheduler over ``batch_slots`` decode slots.

    ``prefill_len``: fixed left-pad length for per-slot prefills (one compiled
    prefill program). ``None`` buckets each prompt to the next power of two
    (one compilation per bucket). A prompt padded to length P behaves exactly
    like the static engine's batch padded to P, so outputs are
    token-identical when the pad lengths agree.

    The slot state machine lives host-side (``queue`` + ``slots``); device
    state is the shared cache (per-slot lengths) and the (B, 1) current-token
    buffer. Free slots keep decoding garbage rows — attention is batch-row
    independent and the rows are overwritten at the next admission — so the
    decode step is one fixed-shape jitted program regardless of occupancy.
    """

    def __init__(self, model: Model, params, batch_slots: int,
                 cache_cap: int, src_len: int = 0,
                 prefill_len: int | None = None, jit: bool = True,
                 prefill_chunk: int | None = None,
                 step_token_budget: int | None = None,
                 bucket_policy="pow2", monitor=None, kernels=False,
                 step_wrapper: Callable | None = None):
        if kernels:
            model = model.with_kernels(kernels)
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.cache_cap = cache_cap
        self.src_len = src_len
        self.prefill_len = prefill_len
        if prefill_chunk is not None and prefill_chunk <= 0:
            raise ValueError("prefill_chunk must be a positive token count")
        if step_token_budget is not None and prefill_chunk is None:
            raise ValueError(
                "step_token_budget only gates CHUNKED prefill scheduling — "
                "one-shot admission absorbs whole prompts regardless; set "
                "prefill_chunk to give the budget something to schedule")
        self.prefill_chunk = prefill_chunk
        self.step_token_budget = step_token_budget
        self._bucketer = make_bucketer(bucket_policy)
        self.monitor = monitor
        self.cache = model.init_cache(batch_slots, cache_cap,
                                      src_len=src_len, per_slot_len=True)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * batch_slots
        self._pending = None        # in-flight chunked prefill (at most one)
        self._jit = jit
        # Distributed engines wrap every compiled step so it runs under the
        # mesh context (``with_sharding_constraint`` needs an active mesh on
        # legacy jax); identity for the single-device engines.
        self._step_wrapper = step_wrapper or (lambda fn: fn)
        self._build_steps()
        self.decode_steps = 0

    def _build_steps(self) -> None:
        """(Re)build the jitted step programs from ``self.model``."""
        model, jit, wrap = self.model, self._jit, self._step_wrapper
        stats = self.monitor is not None
        fn_p = partial(model.prefill_slot, cap=self.cache_cap,
                       src_len=self.src_len, collect_moe_stats=stats)
        self._prefill = wrap(jax.jit(fn_p, donate_argnums=(2,))
                             if jit else fn_p)
        # Chunked prefill runs straight against the shared per-slot cache:
        # each chunk slices the slot row, continues the prefill, and merges
        # back in ONE donated program (``Model.prefill_chunk_slot``) — no
        # detached batch-1 cache lives on the host between chunks.
        fn_c0 = partial(model.prefill_chunk_slot, first=True,
                        cap=self.cache_cap, src_len=self.src_len,
                        collect_moe_stats=stats)
        self._chunk_first = wrap(jax.jit(fn_c0, donate_argnums=(2,))
                                 if jit else fn_c0)
        fn_c = partial(model.prefill_chunk_slot, first=False,
                       cap=self.cache_cap, src_len=self.src_len,
                       collect_moe_stats=stats)
        self._chunk = wrap(jax.jit(fn_c, donate_argnums=(2,))
                           if jit else fn_c)
        fn_d = model.decode_step_stats if stats else model.decode_step
        self._decode = wrap(jax.jit(fn_d, donate_argnums=(2,))
                            if jit else fn_d)

    def _rebind(self, model: Model) -> None:
        """Swap the model (e.g. a ``ParallelContext`` with fresh ppermute
        rounds) and rebuild the jitted steps. Serving state — cache, slots,
        queue, in-flight prefill — is untouched: a rebind mid-stream is
        placement-only as long as the new model computes the same function."""
        self.model = model
        self._build_steps()

    def _set_replication(self, spec) -> None:
        """Install a hot-expert ``ReplicationSpec`` (placement-only).

        De-replicates the current expert leaves back to the logical frame,
        widens them under the new spec (pure copies of their home experts),
        and rebinds with ``pc.moe_replication`` updated. Routing, capacity
        and drops all stay in the logical frame (the shard-of-token rule in
        ``models.moe``), so a mid-stream swap cannot change emitted tokens."""
        from repro.models.moe import (dereplicate_moe_params,
                                      replicate_moe_params)
        cur = self.model.pc.moe_replication
        if spec is not None and spec.is_identity:
            spec = None
        if (None if cur is None else cur.counts) == \
                (None if spec is None else spec.counts):
            return
        params = self.params
        if cur is not None:
            params = dereplicate_moe_params(params, cur)
        if spec is not None:
            params = replicate_moe_params(params, spec)
        self.params = params
        pc = dataclasses.replace(self.model.pc, moe_replication=spec)
        self._rebind(dataclasses.replace(self.model, pc=pc))

    def adopt_replication(self, replication) -> None:
        """Adopt a planner host map (``Plan.replication`` — per-expert host
        tuples — or a bare per-expert copy-count sequence). ``None`` or the
        identity map drops back to unreplicated serving."""
        from repro.models.moe import ReplicationSpec
        if replication is None:
            spec = None
        else:
            counts = tuple(
                len(h) if hasattr(h, "__len__") else int(h)
                for h in replication)
            spec = ReplicationSpec.from_counts(counts)
        self._set_replication(spec)

    # -- scheduler ---------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def num_pending(self) -> int:
        """In-flight chunked prefills (0 or 1)."""
        return int(self._pending is not None)

    def submit(self, req: Request) -> None:
        # Final per-slot length is pad(prompt) + max_new_tokens - 1 (the
        # last emitted token is never written back); beyond cache_cap the
        # decode path would silently overwrite slot cap-1 every step.
        p = self._bucket(len(req.prompt))
        need = p + max(req.max_new_tokens - 1, 0)
        if need > self.cache_cap:
            raise ValueError(
                f"prompt + generation needs {need} cache slots, "
                f"capacity is {self.cache_cap}")
        if (self.prefill_chunk is not None
                and not self.model.supports_chunked_prefill(
                    p, self.cache_cap)):
            raise ValueError(
                f"{self.model.cfg.arch_id}: a {p}-token prefill cannot be "
                "chunked (MLA / encoder-decoder, or a prompt that WRAPS "
                "the sliding-window ring — prompts inside the ring chunk "
                "fine) — use prefill_chunk=None for this engine")
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        if self.prefill_len is not None:
            if n > self.prefill_len:
                raise ValueError(f"prompt len {n} > prefill_len "
                                 f"{self.prefill_len}")
            return self.prefill_len
        p = self._bucketer(n)
        if p < n:
            raise ValueError(f"bucket policy shrank {n} to {p}")
        p = min(p, self.cache_cap)
        if self.prefill_chunk is not None:
            # A pow2/step pad can push a prompt that FITS a sliding-window
            # ring past it (e.g. 10 tokens padded to 16 over a 12-ring) and
            # trigger the wrapped-ring refusal; clamp the pad to the ring so
            # only genuinely wrapping prompts are refused. Applied in
            # _bucket so submit and admission agree on the padded length.
            lim = self.model.chunkable_len(self.cache_cap)
            if lim is not None and n <= lim:
                p = min(p, lim)
        return p

    def _free_slot(self) -> int | None:
        """First free slot not reserved by the in-flight prefill."""
        reserved = self._pending[1] if self._pending is not None else -1
        for i, r in enumerate(self.slots):
            if r is None and i != reserved:
                return i
        return None

    def _finish_admission(self, r: Request, slot: int, logits) -> None:
        """Shared tail of one-shot and chunked admission: emit the first
        token and occupy the slot (unless the request is already done)."""
        tok0 = int(jnp.argmax(logits[0, -1, : self.model.cfg.vocab]))
        if r.max_new_tokens > 0:
            r.out_tokens.append(tok0)
        if len(r.out_tokens) < r.max_new_tokens:
            self.slots[slot] = r
            self.tokens = self.tokens.at[slot, 0].set(tok0)

    def _admit(self) -> None:
        """Drain the queue into free slots (one-shot per-slot prefill each)."""
        while self.queue and None in self.slots:
            slot = self.slots.index(None)
            r = self.queue.popleft()
            p = self._bucket(len(r.prompt))
            toks = np.zeros((1, p), np.int32)
            toks[0, p - len(r.prompt):] = r.prompt      # left-pad with 0
            out = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.cache,
                jnp.int32(slot))
            if self.monitor is not None:
                logits, self.cache, stats = out
                self._observe_prefill(stats, pad=p - len(r.prompt))
            else:
                logits, self.cache = out
            self._finish_admission(r, slot, logits)

    def _admit_tick(self) -> bool:
        """One scheduler tick of admission work. Returns True iff chunked
        prefill progressed (one-shot admissions surface via num_active)."""
        if self.prefill_chunk is None:
            self._admit()
            return False
        return self._prefill_tick()

    def _prefill_tick(self) -> bool:
        """Budgeted chunked admission: start or advance the single in-flight
        prefill by at most one ``prefill_chunk``-token chunk. Every chunk
        lands directly in the slot's row of the shared cache; between chunks
        the decode step freezes that row (``row_mask``), so the partial
        state survives interleaved decode ticks untouched."""
        if self._pending is None:
            slot = self._free_slot()
            if not self.queue or slot is None:
                return False
            r = self.queue.popleft()
            p = self._bucket(len(r.prompt))
            toks = np.zeros((1, p), np.int32)
            toks[0, p - len(r.prompt):] = r.prompt      # left-pad with 0
            self._pending = [r, slot, toks, 0]
        r, slot, toks, done = self._pending
        c = min(self.prefill_chunk, toks.shape[1] - done)
        if self.step_token_budget is not None and self.num_active > 0:
            # Decode always runs and eats num_active tokens of the budget;
            # the chunk only proceeds on leftover budget. Progress is
            # guaranteed: decode drains slots, so num_active falls and the
            # leftover eventually covers a chunk (or the pool empties and
            # the budget gate is bypassed entirely).
            if self.step_token_budget - self.num_active < c:
                return False
        chunk_toks = {"tokens": jnp.asarray(toks[:, done:done + c])}
        # The first chunk starts the slot from a fresh zero state (no
        # leakage from the previous occupant); later chunks resume from the
        # slot's own recorded fill level.
        fn = self._chunk_first if done == 0 else self._chunk
        out = fn(self.params, chunk_toks, self.cache, jnp.int32(slot))
        if self.monitor is not None:
            logits, self.cache, stats = out
            # The chunk covers padded positions [done, done+c); left-pad
            # spans [0, total - len(prompt)) of the padded prompt.
            self._observe_prefill(
                stats, pad=(toks.shape[1] - len(r.prompt)) - done)
        else:
            logits, self.cache = out
        done += c
        if done < toks.shape[1]:
            self._pending = [r, slot, toks, done]
            return True
        self._pending = None
        self._finish_admission(r, slot, logits)
        return True

    def _observe_prefill(self, stats, pad: int) -> None:
        """Fold prefill routing counts into the monitor, dropping the first
        ``pad`` positions (left-padding routes token id 0 every time and
        would skew the popularity estimate toward phantom traffic)."""
        arr = np.asarray(stats)                      # (L, 1, S, E)
        real = arr[:, :, max(pad, 0):, :]
        if real.shape[2]:
            self.monitor.observe(real.sum(axis=2))

    def _postdecode(self, logits) -> None:
        """Emit one token per occupied slot; evict finished requests."""
        nxt = jnp.argmax(logits[:, :, : self.model.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        self.tokens = nxt
        host = np.asarray(nxt)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.out_tokens.append(int(host[i, 0]))
            if len(r.out_tokens) >= r.max_new_tokens:
                self.slots[i] = None                     # slot free for reuse

    def _decode_all(self):
        """One fixed-shape decode over every slot (stats-aware).

        Vacant rows are masked out of cache updates (``row_mask``): their
        state and fill level freeze, which keeps a partially chunk-prefilled
        slot's row byte-stable between chunks. Occupied rows are unaffected
        — attention is batch-row independent — so masking never changes
        emitted tokens."""
        mask = np.array([r is not None for r in self.slots], bool)
        if self.monitor is not None:
            logits, self.cache, stats = self._decode(self.params, self.tokens,
                                                     self.cache,
                                                     jnp.asarray(mask))
            self.monitor.observe(stats, mask)
        else:
            logits, self.cache = self._decode(self.params, self.tokens,
                                              self.cache, jnp.asarray(mask))
        return logits

    def step(self) -> bool:
        """Admit (whole prefills, or one budgeted chunk), then decode all
        slots once. Returns False when idle."""
        worked = self._admit_tick()
        if self.num_active == 0:
            return worked
        logits = self._decode_all()
        self.decode_steps += 1
        self._postdecode(logits)
        return True

    # -- driver ------------------------------------------------------------
    def serve(self, reqs: list[Request]) -> list[Request]:
        """Run a request stream to completion, honoring ``arrival`` times
        (measured in engine steps; requests arriving at the same step are
        admitted in list order)."""
        serve_stream(self.step, [(self, reqs)])
        return reqs
