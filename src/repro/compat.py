"""Shims over jax API drift, so one codebase spans the installed versions.

- ``set_mesh(mesh)``: context manager. ``jax.set_mesh`` arrived with the
  sharding-in-types work; on older jax a ``Mesh`` is itself a context
  manager that installs the legacy global mesh environment.
- ``shard_map(...)``: top-level ``jax.shard_map`` vs
  ``jax.experimental.shard_map.shard_map``, and the ``check_vma`` →
  ``check_rep`` keyword rename.
- ``pallas_compiler_params(...)``: pallas TPU ``TPUCompilerParams`` →
  ``CompilerParams`` rename.
"""

from __future__ import annotations

import jax

try:
    from jax import shard_map as _shard_map        # jax >= 0.6
except ImportError:                                # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def set_mesh(mesh):
    """``with set_mesh(mesh):`` on any jax version."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh                                    # legacy: Mesh is a CM


def axis_size(name) -> int:
    """Static mesh-axis size from inside ``shard_map`` on any jax version."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    from jax._src.core import axis_frame              # 0.4.x: returns size
    sz = axis_frame(name)
    return sz if isinstance(sz, int) else sz.size


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    except TypeError:                              # pre-rename keyword
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def pallas_compiler_params(**kwargs):
    """Construct pallas TPU compiler params across the
    ``TPUCompilerParams`` → ``CompilerParams`` rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = (getattr(pltpu, "CompilerParams", None)
           or getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:                                # pragma: no cover
        raise ImportError("this jax exposes neither pallas-TPU "
                          "CompilerParams nor TPUCompilerParams")
    return cls(**kwargs)
