"""Expert-parallel dispatch/combine collectives.

The MoE all-to-all is realized two ways:

1. **Baseline** — one monolithic ``jax.lax.all_to_all`` per phase. This is
   what existing systems (GShard / DeepSpeed-MoE / Tutel) lower to and what
   the paper's baselines model: the runtime picks an arbitrary transmission
   order, so receivers can suffer bandwidth contention.

2. **Aurora** — the paper's Thm 4.2 schedule: a static sequence of
   ``lax.ppermute`` **permutation rounds**. Each round is a (partial)
   permutation of the devices, so every device sends to at most one peer and
   receives from at most one peer — exactly the paper's contention-free
   invariant, and also the contention-free traffic pattern for the TPU ICI
   torus. The round order is computed host-side by ``repro.core.schedule``
   from historical traffic statistics (the paper's §2.4 prerequisite) and
   baked into the compiled program ("a buffer layer … calls communication
   collective libraries in the desired order", §3).

Both variants move identical bytes; on real hardware the Aurora variant
avoids receiver contention for skewed traffic. On the dry-run we verify both
lower/compile and that the HLO shows the expected collective structure.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map


# ---------------------------------------------------------------------------
# Round construction (host side)
# ---------------------------------------------------------------------------

def round_robin_rounds(n: int) -> tuple[tuple[int, ...], ...]:
    """Default contention-free cover: n-1 cyclic-shift permutations.

    Round r sends i → (i + r) mod n. Every ordered pair appears exactly once
    and every round is a full permutation — the unscheduled (traffic-blind)
    member of the family Aurora optimizes over.
    """
    return tuple(
        tuple((i + r) % n for i in range(n)) for r in range(1, n)
    )


def aurora_rounds_from_schedule(schedule, n: int) -> tuple[tuple[int, ...], ...]:
    """Collapse a ``CommSchedule`` into one exchange round per (src, dst) pair.

    The BvN schedule may split a pair across slots (durations differ); the
    static lowering moves each pair's whole capacity bucket in the slot where
    the pair FIRST appears — preserving Aurora's *ordering* decision (heavy
    pairs early, contention-free rounds). Pairs absent from the schedule
    (zero historical traffic) are appended as round-robin cleanup rounds so
    the exchange stays correct under traffic drift (§8 Q4).

    Degenerate inputs are handled explicitly: a single device needs no
    rounds (self-traffic never crosses the network), and malformed slots
    (duplicate receivers, self-sends, out-of-range destinations) raise
    instead of silently misrouting buckets in the ppermute lowering.
    """
    from repro.core.schedule import validate_permutation_slots

    validate_permutation_slots(schedule.slots, n)
    if n == 1:
        return ()
    seen = np.zeros((n, n), dtype=bool)
    rounds: list[tuple[int, ...]] = []
    for slot in schedule.slots:
        dst = []
        any_new = False
        for i, j in enumerate(slot.dst):
            if j >= 0 and not seen[i, j]:
                seen[i, j] = True
                dst.append(j)
                any_new = True
            else:
                dst.append(-1)
        if any_new:
            rounds.append(tuple(dst))
    # Cleanup: cover never-seen off-diagonal pairs with round-robin shifts.
    for r in range(1, n):
        dst = []
        any_new = False
        for i in range(n):
            j = (i + r) % n
            if not seen[i, j]:
                seen[i, j] = True
                dst.append(j)
                any_new = True
            else:
                dst.append(-1)
        if any_new:
            rounds.append(tuple(dst))
    return tuple(rounds)


def validate_rounds_cover(rounds, n: int) -> tuple[tuple[int, ...], ...]:
    """Demand a full contention-free cover from a literal round sequence.

    The exchange bodies trust ``rounds`` blindly: a missing (src, dst) pair
    leaves that capacity bucket's row as zeros (tokens silently vanish), a
    duplicate delivers one bucket twice. Everything derived through
    ``aurora_rounds_from_schedule`` satisfies this by construction; rounds
    installed verbatim (``swap_rounds`` / engine ``rounds=``) go through
    here so misuse fails loudly instead. Returns the normalized tuple.
    """
    from repro.core.schedule import check_partial_permutation

    rounds = tuple(check_partial_permutation(r, n, f"round {r_i}")
                   for r_i, r in enumerate(rounds))
    seen = np.zeros((n, n), dtype=int)
    for dst in rounds:
        for i, j in enumerate(dst):
            if j >= 0:
                seen[i, j] += 1
    off = ~np.eye(n, dtype=bool)
    if n > 1 and not (seen[off] == 1).all():
        missing = int((seen[off] == 0).sum())
        dup = int((seen[off] > 1).sum())
        raise ValueError(
            f"rounds are not an exact cover of the {n}-device exchange: "
            f"{missing} ordered pair(s) never exchanged (their token "
            f"buckets would silently vanish), {dup} exchanged more than "
            "once")
    return rounds


# ---------------------------------------------------------------------------
# In-shard_map exchange primitives
# ---------------------------------------------------------------------------

def flat_axis_index(axis_names):
    """Row-major flattened device index over ``axis_names`` (traced)."""
    me = jnp.zeros((), jnp.int32)
    for ax in axis_names:
        me = me * axis_size(ax) + jax.lax.axis_index(ax)
    return me


def _exchange_rounds(buf, axis_names, rounds) -> jnp.ndarray:
    """Scheduled exchange: buf (n, ...) slices; out[s] = buf_of_device_s[me].

    Equivalent to ``lax.all_to_all(buf, axes, 0, 0)`` but expressed as the
    static ppermute round sequence (each round a partial permutation).
    Multi-axis EP (e.g. deepseek's flat ('data','model') = 256) uses the
    row-major flattened device index, matching all_to_all's ordering.
    """
    n = buf.shape[0]
    me = flat_axis_index(axis_names)
    axis_name = tuple(axis_names) if len(axis_names) > 1 else axis_names[0]
    # Row n is a scratch slot for rounds in which this device receives nothing.
    out = jnp.zeros((n + 1,) + buf.shape[1:], buf.dtype)
    for dst_vec in rounds:
        dst = np.asarray(dst_vec)
        src = np.full(n, n, dtype=np.int64)          # n = scratch
        for i, j in enumerate(dst):
            if j >= 0:
                src[j] = i
        perm = [(i, int(j)) for i, j in enumerate(dst) if j >= 0]
        send_idx = jnp.asarray(np.where(dst < 0, 0, dst))[me]
        send = jax.lax.dynamic_index_in_dim(buf, send_idx, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        write_idx = jnp.asarray(src)[me]
        out = jax.lax.dynamic_update_index_in_dim(out, recv, write_idx, 0)
    # Self-traffic never crosses the network (paper §4.2 footnote 1).
    out = jax.lax.dynamic_update_index_in_dim(
        out, jax.lax.dynamic_index_in_dim(buf, me, 0, keepdims=False), me, 0)
    return out[:n]


def ep_all_to_all(buf, axis_names, rounds=None) -> jnp.ndarray:
    """Dispatch exchange over the flat EP axis. buf: (n_ep, ...) per device.

    Result[s] = what device s sent to me. ``rounds=None`` → monolithic
    all_to_all; otherwise the Aurora ppermute schedule (works for single-
    and multi-axis flat EP).
    """
    if rounds is not None:
        return _exchange_rounds(buf, tuple(axis_names), rounds)
    return jax.lax.all_to_all(buf, axis_names, split_axis=0, concat_axis=0,
                              tiled=False)


# ---------------------------------------------------------------------------
# Full dispatch → expert FFN → combine (runs inside shard_map)
# ---------------------------------------------------------------------------

def _scatter_buckets(xt, valid, router_w, moe, token_axes, spec=None):
    """Shared dispatch prologue of the sync and pipelined bodies.

    Routes the local token slice and scatters it into per-expert capacity
    buckets. Returns ``(buf (E', C, d), combine, aux, idx)`` where ``combine``
    maps the returned (E', C, d) expert-output buckets back onto the local
    token slice (gate-weighted scatter-add).

    ``spec`` (a ``moe.ReplicationSpec``) widens the bucket frame to the
    physical expert count: routing/capacity/drops stay in the LOGICAL frame
    (bit-identical to no replication), then kept rank r of expert e lands on
    replica ``r % r_e`` at position ``r // r_e`` — the same shard-of-token
    rule as the local paths, so replicas are placement-only."""
    from repro.models.moe import capacity, dispatch_indices, replica_arrays, \
        route

    t_loc, d = xt.shape
    e = moe.n_experts
    gates, idx, aux = route(router_w, xt, moe)
    aux = jax.lax.pmean(aux, token_axes)
    cap = capacity(t_loc, moe.top_k, e, moe.capacity_factor)
    slot, keep = dispatch_indices(idx, e, cap)
    keep = keep & valid[:, None]

    # Scatter local tokens into per-(expert) capacity buckets: (E', C, d).
    tok_ids = jnp.broadcast_to(jnp.arange(t_loc)[:, None], idx.shape)
    e_f, s_f, t_f = idx.reshape(-1), slot.reshape(-1), tok_ids.reshape(-1)
    k_f = keep.reshape(-1)
    if spec is not None:
        base, reps = replica_arrays(spec)
        r_f = reps[e_f]
        e_f = base[e_f] + s_f % r_f
        s_f = s_f // r_f
        n_phys = spec.n_phys
    else:
        n_phys = e
    safe_s = jnp.where(k_f, s_f, cap - 1)
    buf = jnp.zeros((n_phys, cap, d), xt.dtype)
    buf = buf.at[e_f, safe_s].add(jnp.where(k_f[:, None], xt[t_f], 0.0))

    def combine(back):
        picked = back[e_f, safe_s]
        picked = jnp.where(k_f[:, None], picked, 0.0)
        return jnp.zeros_like(xt).at[t_f].add(
            picked * gates.reshape(-1)[:, None])

    return buf, combine, aux, idx


def _replicated_counts(idx, valid, n_experts: int, token_axes):
    """In-collective ``return_counts``: per-token routed-choice histogram.

    Routing runs inside the shard_map collective, so per-token assignments
    never materialize outside the per-device program — each device scatters
    its local (T_loc, E) ``routed_counts`` slice into the global padded token
    range and a ``psum`` over the token axes replicates the full (T_pad, E)
    histogram, exactly matching the local paths' output frame."""
    from repro.models.moe import routed_counts

    cnt = routed_counts(idx, n_experts) * valid[:, None].astype(jnp.float32)
    t_loc = cnt.shape[0]
    n_shards = 1
    for ax in token_axes:
        n_shards *= axis_size(ax)
    shard = flat_axis_index(token_axes)
    full = jnp.zeros((n_shards * t_loc, n_experts), jnp.float32)
    full = jax.lax.dynamic_update_slice(full, cnt, (shard * t_loc, 0))
    return jax.lax.psum(full, tuple(token_axes))


def _local_dispatch_combine(xt, valid, router_w, experts, moe, act,
                            ep_axes, token_axes, rounds,
                            return_counts: bool = False, spec=None):
    """Per-device body (synchronous). xt: (T_loc, d) local token slice."""
    t_loc, d = xt.shape
    n_ep = 1
    for ax in ep_axes:
        n_ep *= axis_size(ax)
    e = moe.n_experts

    buf, combine, aux, idx = _scatter_buckets(xt, valid, router_w, moe,
                                              token_axes, spec=spec)
    n_phys, cap = buf.shape[0], buf.shape[1]
    epd = n_phys // n_ep                             # experts per device

    # First all-to-all (token dispatch, D_N).
    buf = buf.reshape(n_ep, epd, cap, d)
    recv = ep_all_to_all(buf, ep_axes, rounds)       # (n_src, epd, C, d)
    recv = recv.transpose(1, 0, 2, 3).reshape(epd, n_ep * cap, d)

    # Expert FFN on this device's experts.
    from repro.models.layers import ffn_apply
    out = jax.vmap(lambda p, xb: ffn_apply(p, xb, act))(experts, recv)

    # Second all-to-all (expert-output return, D_C = D_N^T): same rounds —
    # the two phases are exact reverses (§2.2), so the contention-free
    # property carries over by symmetry.
    out = out.reshape(epd, n_ep, cap, d).transpose(1, 0, 2, 3)
    back = ep_all_to_all(out, ep_axes, rounds)       # (E_dev_of_pair …)
    back = back.reshape(n_phys, cap, d)

    y = combine(back)
    if return_counts:
        return y, aux, _replicated_counts(idx, valid, e, token_axes)
    return y, aux


def ep_dispatch_combine(xt, router_w, experts, moe, act, pc,
                        return_counts: bool = False):
    """shard_map wrapper. xt: (T, d) global.

    The flat token axis shards over ``pc.token_axes`` (all mesh axes —
    including ``pod``); the all-to-all collectives run over ``pc.ep_axes``
    only, so each pod performs its own expert exchange and **no all-to-all
    crosses the DCN boundary** (DESIGN.md §6). Pads T to a multiple of the
    token-shard count (decode steps can have fewer tokens than devices);
    padded tokens are masked out of dispatch.

    ``pc.ep_overlap=True`` switches the body to the round-pipelined software
    pipeline (``repro.distributed.overlap``): expert FFN chunks run while the
    next ppermute round is in flight. ``return_counts=True`` appends the
    (T, E) routed-choice histogram, psum'd inside the collective.
    """
    ep_axes = tuple(pc.ep_axes)
    token_axes = tuple(pc.token_axes) or ep_axes
    mesh = pc.mesh
    n_tok_shards = 1
    for ax in token_axes:
        n_tok_shards *= mesh.shape[ax]
    t = xt.shape[0]
    t_pad = -(-t // n_tok_shards) * n_tok_shards
    valid = jnp.arange(t_pad) < t
    if t_pad != t:
        xt = jnp.pad(xt, ((0, t_pad - t), (0, 0)))

    n_ep = 1
    for ax in ep_axes:
        n_ep *= mesh.shape[ax]
    spec = pc.moe_replication
    if spec is not None and spec.n_phys % n_ep != 0:
        raise ValueError(
            f"replicated physical expert count {spec.n_phys} does not "
            f"divide over the {n_ep}-device EP axis — pad the replication "
            f"(planner: total_multiple={n_ep}) so every device hosts the "
            "same number of physical experts")
    rounds = pc.aurora_rounds if pc.moe_impl == "aurora" else None
    if rounds is None and (pc.moe_impl == "aurora" or pc.ep_overlap):
        # The pipeline needs explicit rounds; traffic-blind round robin is
        # the unscheduled member of the contention-free family.
        rounds = round_robin_rounds(n_ep)

    if pc.ep_overlap:
        from repro.distributed.overlap import pipelined_local_dispatch_combine
        body = pipelined_local_dispatch_combine
    else:
        body = _local_dispatch_combine

    out_specs = (P(token_axes, None), P())
    if return_counts:
        out_specs = out_specs + (P(),)
    fn = shard_map(
        lambda xs, vs, rw, ex: body(
            xs, vs, rw, ex, moe, act, ep_axes, token_axes, rounds,
            return_counts=return_counts, spec=spec),
        mesh=mesh,
        in_specs=(P(token_axes, None), P(token_axes), P(), P(ep_axes)),
        out_specs=out_specs,
        check_vma=False,
    )
    if return_counts:
        y, aux, counts = fn(xt, valid, router_w, experts)
        return y[:t], aux, counts[:t]
    y, aux = fn(xt, valid, router_w, experts)
    return y[:t], aux
