"""Round-pipelined (overlapped) Aurora dispatch — the paper's Fig 3(b) at
intra-step granularity.

The synchronous EP path (``alltoall._local_dispatch_combine``) is a strict
barrier pipeline: *all* ppermute rounds of the dispatch all-to-all complete,
then the expert FFN runs over every arrival, then *all* return rounds fire.
Lina and FasterMoE (PAPERS.md) show the win comes from breaking that barrier:
expert compute on tokens that already arrived can hide the latency of rounds
still in flight.

``pipelined_local_dispatch_combine`` realizes this as a **software pipeline**
over the BvN rounds:

  round r+1's ppermute is issued          ─┐  data-independent, so XLA's
  FFN runs on the chunk from round r       ├─ latency-hiding scheduler
  round r's output returns (ppermuteᵀ)    ─┘  overlaps all three

Each round delivers at most one (experts_per_device, C, d) capacity chunk
per device; the grouped expert FFN is applied per chunk (FFN is row-wise, so
per-chunk compute equals the batched compute on the concatenation), and the
finished chunk returns through the **transposed** permutation of its delivery
round — still a (partial) permutation, so the return phase keeps the paper's
contention-free invariant.

Token-identity with the synchronous path is proven in
``tests/test_distributed_serving.py``: same routing, same capacity buckets,
same gate-weighted combine — only the schedule of byte movement changes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from .alltoall import _replicated_counts, _scatter_buckets, flat_axis_index


def pipelined_local_dispatch_combine(xt, valid, router_w, experts, moe, act,
                                     ep_axes, token_axes, rounds,
                                     return_counts: bool = False, spec=None):
    """Per-device body of the round-pipelined dispatch/FFN/combine.

    Same contract as ``alltoall._local_dispatch_combine`` (and proven
    token-identical to it): xt (T_loc, d) local token slice in, combined
    expert outputs out. ``rounds`` must be an explicit ppermute schedule —
    the pipeline has no monolithic-all_to_all fallback.
    """
    from repro.models.layers import ffn_apply

    if rounds is None:
        raise ValueError("the pipelined dispatch needs explicit ppermute "
                         "rounds (aurora_rounds or round_robin_rounds)")
    t_loc, d = xt.shape
    n_ep = 1
    for ax in ep_axes:
        n_ep *= axis_size(ax)
    e = moe.n_experts
    axis_name = tuple(ep_axes) if len(ep_axes) > 1 else ep_axes[0]
    me = flat_axis_index(ep_axes)

    buf, combine, aux, idx = _scatter_buckets(xt, valid, router_w, moe,
                                              token_axes, spec=spec)
    n_phys, cap = buf.shape[0], buf.shape[1]
    epd = n_phys // n_ep                             # experts per device
    buf = buf.reshape(n_ep, epd, cap, d)             # buf[s] → device s

    def experts_ffn(chunk):                          # (epd, C, d)
        return jax.vmap(lambda p, xb: ffn_apply(p, xb, act))(experts, chunk)

    # out[s] = FFN outputs of MY tokens processed on device s's experts;
    # row n_ep is a scratch slot for rounds where this device is idle.
    out = jnp.zeros((n_ep + 1, epd, cap, d), xt.dtype)

    def flush(out, chunk, back_perm, write_tbl):
        """Drain one arrived chunk: grouped FFN, then return it through the
        transposed permutation of its delivery round (local for the self
        chunk). Issued AFTER the next round's forward ppermute, so both the
        FFN and the return transfer sit in that round's latency window."""
        y = experts_ffn(chunk)
        if back_perm is None:                        # self chunk: no network
            return jax.lax.dynamic_update_index_in_dim(out, y, me, 0)
        back = jax.lax.ppermute(y, axis_name, back_perm)
        w = jnp.asarray(write_tbl)[me]
        return jax.lax.dynamic_update_index_in_dim(out, back, w, 0)

    # Prologue: the self chunk "arrived" before any round; its FFN fills the
    # first round's latency window (self-traffic never crosses the network).
    pending = (jax.lax.dynamic_index_in_dim(buf, me, 0, keepdims=False),
               None, None)
    for dst_vec in rounds:
        dst = np.asarray(dst_vec)
        perm = [(i, int(j)) for i, j in enumerate(dst) if j >= 0]
        send_idx = jnp.asarray(np.where(dst < 0, 0, dst))[me]
        send = jax.lax.dynamic_index_in_dim(buf, send_idx, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)   # round in flight…
        out = flush(out, *pending)                       # …compute ≤ r
        # The chunk just received returns through the transposed permutation
        # and lands in my out row for the device I sent to this round.
        pending = (recv, [(j, i) for (i, j) in perm],
                   np.where(dst < 0, n_ep, dst))
    out = flush(out, *pending)                           # pipeline epilogue

    back = out[:n_ep].reshape(n_phys, cap, d)
    y = combine(back)
    if return_counts:
        return y, aux, _replicated_counts(idx, valid, e, token_axes)
    return y, aux


def pipelined_dispatch_combine(xt, router_w, experts, moe, act, pc,
                               return_counts: bool = False):
    """``ep_dispatch_combine`` with the software pipeline forced on,
    regardless of ``pc.ep_overlap`` / ``pc.moe_impl``.

    Exists so callers (tests, benchmarks) can compare the two paths on one
    ``ParallelContext``; the serving engines flip ``pc.ep_overlap`` instead.
    Delegates to the one shard_map wrapper (token padding, specs, and the
    round-robin fallback live in exactly one place).
    """
    from .alltoall import ep_dispatch_combine

    pc = dataclasses.replace(pc, moe_impl="aurora", ep_overlap=True)
    return ep_dispatch_combine(xt, router_w, experts, moe, act, pc,
                               return_counts=return_counts)
