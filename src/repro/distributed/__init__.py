"""Distributed runtime: shard_map collectives for expert parallelism."""

from .alltoall import (aurora_rounds_from_schedule, ep_all_to_all,
                       ep_dispatch_combine, round_robin_rounds)
from .overlap import pipelined_dispatch_combine

__all__ = ["aurora_rounds_from_schedule", "ep_all_to_all",
           "ep_dispatch_combine", "pipelined_dispatch_combine",
           "round_robin_rounds"]
