import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles the appropriate step for every supported
(architecture × input-shape) pair on the production meshes:

  16×16      (data, model)        — 256 chips, one pod
  2×16×16    (pod, data, model)   — 512 chips, two pods

and records ``memory_analysis()`` (fits-in-HBM evidence),
``cost_analysis()`` (FLOPs/bytes for §Roofline), and the collective-byte
histogram parsed from the compiled HLO. Failures here (sharding mismatch,
unsupported collective) are bugs in the system.

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count at first init. Do not import this module from test/bench
processes (they must see one device); invoke it as
``PYTHONPATH=src python -m repro.launch.dryrun --arch ... --shape ...``.

Usage:
  python -m repro.launch.dryrun --arch deepseek-v3-671b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import time
import traceback


def run_one(arch: str, shape_name: str, multi_pod: bool,
            moe_impl: str = "ep", out_dir: str | None = None,
            calibrate: bool = True) -> dict:
    import jax
    from repro.compat import set_mesh
    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as S
    from repro.roofline.analysis import (collective_bytes_from_hlo,
                                         roofline_report)
    from repro.roofline.calibrate import calibrated_cost

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "moe_impl": moe_impl}
    if not S.supported(cfg, shape):
        rec["status"] = "skipped (shape-skip matrix, see DESIGN.md)"
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with set_mesh(mesh):
        step_fn, args = S.lowering_args(cfg, shape, mesh, moe_impl=moe_impl)
        # Donation: train aliases params+opt in place, serving aliases the
        # KV/SSM cache — no full-state copy per step (§Perf iteration 1).
        donate = (0, 1) if shape.kind == "train" else (2,)
        lowered = jax.jit(step_fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_dev = mesh.devices.size
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        n_devices=int(n_dev),
    )
    if mem is not None:
        # memory_analysis reports PER-DEVICE sizes for the SPMD program.
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
        args_b = rec["memory"].get("argument_size_in_bytes", 0)
        temp_b = rec["memory"].get("temp_size_in_bytes", 0)
        rec["memory"]["per_device_total_gib"] = round(
            (args_b + temp_b) / 2**30, 3)
    raw_cost = {k: float(v) for k, v in (cost or {}).items()
                if k in ("flops", "bytes accessed")}
    rec["cost_raw"] = dict(raw_cost,
                           note="per-device; scan bodies counted ONCE")
    coll_raw = collective_bytes_from_hlo(compiled.as_text())
    rec["collectives_raw"] = coll_raw

    if calibrate:
        # Scan-corrected per-device cost (see roofline/calibrate.py).
        cal = calibrated_cost(cfg, shape, mesh, moe_impl=moe_impl)
        rec["cost"] = {"flops": cal["flops"], "bytes": cal["bytes"],
                       "collective_bytes": cal["collective_bytes"]}
        rec["calibration"] = cal["detail"]
        flops, hbm, coll_b = (cal["flops"], cal["bytes"],
                              cal["collective_bytes"])
    else:
        flops = raw_cost.get("flops", 0.0)
        hbm = raw_cost.get("bytes accessed", 0.0)
        coll_b = coll_raw["link_bytes"]
    rec["roofline"] = roofline_report(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll_b,
        n_devices=int(n_dev), cfg=cfg, shape=shape,
        arg_bytes=rec.get("memory", {}).get("argument_size_in_bytes"),
        out_bytes=rec.get("memory", {}).get("output_size_in_bytes"))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    from repro.configs import ARCH_IDS, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-impl", default="ep", choices=["ep", "aurora"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the scan-correction calibration lowerings")
    args = ap.parse_args()

    combos = []
    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = 0
    for arch, shape, mp in combos:
        tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
        try:
            # The roofline table (§Roofline) is single-pod only; multi-pod
            # runs prove sharding coherence + memory, skipping calibration.
            rec = run_one(arch, shape, mp, moe_impl=args.moe_impl,
                          out_dir=args.out,
                          calibrate=not args.no_calibrate and not mp)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f"  lower {rec['lower_s']}s compile "
                         f"{rec['compile_s']}s "
                         f"mem/dev {rec.get('memory', {}).get('per_device_total_gib', '?')} GiB")
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
        except Exception:
            failures += 1
            print(f"[dryrun] {tag}: FAILED", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
