"""Serving driver: single-model or Aurora-colocated dual-model, static batch
or continuous batching with a streaming (Poisson) arrival process.

  python -m repro.launch.serve --arch qwen3-32b --reduced
  python -m repro.launch.serve --arch qwen3-32b --reduced \
      --arrival-rate 0.5 --num-requests 12          # continuous batching
  python -m repro.launch.serve --arch phi3.5-moe-42b-a6.6b \
      --colocate-with phi4-mini-3.8b --reduced --arrival-rate 0.5
  python -m repro.launch.serve --arch phi3.5-moe-42b-a6.6b --reduced \
      --experts 8 --arrival-rate 0.5 --mesh 8 --overlap   # distributed EP

``--arrival-rate λ`` switches to the continuous engine and draws request
inter-arrival gaps from Exp(λ) (a Poisson process), measured in decode-step
time units — the serving-loop clock. The colocated mode plans the expert
pairing with AuroraPlanner from a synthetic routing trace, permutes model B's
experts accordingly, and serves both streams through one interleaved XLA
program (see serving/colocated.py).

``--ttft-slo`` / ``--tpot-slo`` declare per-tenant SLO targets (p95, in
engine-step units): each served model gets a ``TenantSpec``, every request's
deadline is stamped from it at submit, and admission switches to
deadline-aware EDF (``EdfAdmission`` — earliest effective deadline first,
starvation-free via aging) over the same chunk and budget:

  python -m repro.launch.serve --arch qwen3-32b --reduced \
      --arrival-rate 0.5 --prefill-chunk 4 --ttft-slo 12 --tpot-slo 2

``--mesh N`` serves EP-sharded over an N-device mesh (on a CPU host the
platform is split into N virtual devices — the flag must land before jax
initializes, which is why it is handled first). ``--moe-impl aurora``
(default) dispatches through the scheduled ppermute rounds, planned from a
synthetic historical trace; ``--overlap`` pipelines expert FFN chunks with
in-flight rounds (repro.distributed.overlap). The expert count must divide
N — use ``--experts`` to widen the reduced configs.

``--trace-out BASE`` / ``--metrics-out PATH`` attach the unified telemetry
hub (serving/telemetry.py) to whichever engine is built: structured spans
(engine_step > prefill_chunk / decode_step > dispatch_round) and the typed
event bus (replan / shed / fault / adoption) land in ``BASE.jsonl`` and
``BASE.trace.json`` (Chrome trace-event JSON — open in Perfetto), and the
final metrics snapshot (tok/s, TTFT, expert-load imbalance, …) is written
as JSON on exit — including on Ctrl-C.
"""

from __future__ import annotations

import argparse

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--colocate-with", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--cache-cap", type=int, default=64)
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="requests per decode step (Poisson); enables "
                         "continuous batching")
    ap.add_argument("--num-requests", type=int, default=12,
                    help="stream length for --arrival-rate mode")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: absorb at most N prompt tokens "
                         "per engine step (continuous engines)")
    ap.add_argument("--step-budget", type=int, default=None,
                    help="per-step token budget: decode always runs, "
                         "leftover feeds the FIFO prefix of due prefill "
                         "chunks")
    ap.add_argument("--prefill-pool", type=int, default=1,
                    help="admit up to K chunked prefills concurrently; "
                         "their chunks (and decode) fuse into one jitted "
                         "step (requires --prefill-chunk)")
    ap.add_argument("--bucket-policy", default="pow2",
                    help="prefill pad-length policy: pow2 | exact | step:K")
    ap.add_argument("--replan-interval", type=int, default=None,
                    help="colocated continuous mode: re-plan the expert "
                         "pairing from live routing stats every N decode "
                         "steps")
    ap.add_argument("--replan-threshold", type=float, default=0.02,
                    help="min relative predicted-time improvement before a "
                         "re-plan is applied")
    ap.add_argument("--ttft-slo", type=float, default=None,
                    help="p95 TTFT target in engine steps: declares a "
                         "TenantSpec SLO (stamps per-request deadlines) and "
                         "switches admission to deadline-aware EDF")
    ap.add_argument("--tpot-slo", type=float, default=None,
                    help="p95 TPOT target in engine steps (declared on the "
                         "TenantSpec next to --ttft-slo)")
    ap.add_argument("--kernels", action="store_true",
                    help="continuous engines: serve through the Pallas "
                         "kernel path (sort-based ragged MoE dispatch + "
                         "flash-decode attention; pure-jnp twin on CPU)")
    ap.add_argument("--mesh", type=int, default=None,
                    help="serve EP-sharded over an N-device mesh (forces N "
                         "host-platform devices on CPU; the expert count "
                         "must divide N)")
    ap.add_argument("--moe-impl", default=None,
                    choices=["ep", "aurora"],
                    help="--mesh dispatch path: monolithic all_to_all (ep) "
                         "or scheduled ppermute rounds (aurora)")
    ap.add_argument("--overlap", action="store_true",
                    help="--mesh: round-pipelined dispatch — expert FFN "
                         "chunks overlap in-flight ppermute rounds")
    ap.add_argument("--experts", type=int, default=None,
                    help="override the MoE expert count (reduced configs "
                         "clamp to 4, which rarely divides a mesh)")
    ap.add_argument("--trace-out", default=None, metavar="BASE",
                    help="record telemetry and write BASE.jsonl (structured "
                         "spans + events) and BASE.trace.json (Chrome "
                         "trace-event JSON — open in Perfetto) on exit")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metrics snapshot as JSON on exit "
                         "(also on Ctrl-C)")
    args = ap.parse_args()

    if args.mesh is None and (args.overlap or args.moe_impl is not None):
        # Fail loudly: without a mesh these flags would silently serve the
        # single-device dense path while the user believes they measured
        # distributed dispatch.
        raise SystemExit("--overlap/--moe-impl configure the distributed "
                         "EP dispatch; add --mesh N (or drop them)")
    if args.mesh is not None:
        # Before jax initializes: split the host platform into the mesh's
        # device count (no-op when real devices exist and the flag is set).
        from repro.launch.mesh import force_host_device_count
        force_host_device_count(args.mesh)

    telemetry = None
    if args.trace_out or args.metrics_out:
        from repro.serving.telemetry import Telemetry
        telemetry = Telemetry()

    # The flush runs on every exit path — clean return, SystemExit, and
    # Ctrl-C — so a long serving run killed mid-stream still leaves its
    # trace and metrics on disk.
    try:
        return _serve(args, telemetry)
    except KeyboardInterrupt:
        print("\ninterrupted")
        return 130
    finally:
        _flush_telemetry(telemetry, args)


def _flush_telemetry(telemetry, args) -> None:
    if telemetry is None:
        return
    if args.trace_out:
        telemetry.write_jsonl(args.trace_out + ".jsonl")
        telemetry.write_chrome_trace(args.trace_out + ".trace.json")
        print(f"trace: {args.trace_out}.jsonl + {args.trace_out}.trace.json"
              f" (open the .trace.json in Perfetto / chrome://tracing)")
    if args.metrics_out:
        import json
        with open(args.metrics_out, "w") as f:
            json.dump(telemetry.snapshot(), f, indent=2, sort_keys=True)
        print(f"metrics snapshot: {args.metrics_out}")


def _serve(args, telemetry) -> int:
    import jax
    from repro.configs import get_config
    from repro.models import Model
    from repro.serving import (ColocatedContinuousEngine, ColocatedEngine,
                               ContinuousEngine, EdfAdmission, EngineConfig,
                               Request, ServingEngine, TenantSpec,
                               poisson_requests)

    # One config for every continuous engine this driver can build. SLO
    # flags declare TenantSpecs (one per served model — they stamp each
    # request's deadline) and replace the chunk/budget shorthand with
    # deadline-aware EDF admission over the same chunk and budget.
    slo = args.ttft_slo is not None or args.tpot_slo is not None
    if slo:
        names = [args.arch] + ([args.colocate_with] if args.colocate_with
                               else [])
        tenants = tuple(TenantSpec(name=name, ttft_p95=args.ttft_slo,
                                   tpot_p95=args.tpot_slo)
                        for name in names)
        config = EngineConfig(
            prefill_len=args.prompt_len,
            admission=EdfAdmission(
                chunk=args.prefill_chunk or args.prompt_len,
                budget=args.step_budget,
                bucket_policy=args.bucket_policy),
            prefill_pool=args.prefill_pool, kernels=args.kernels,
            tenants=tenants, telemetry=telemetry)
        print(f"SLO targets (engine steps): ttft_p95<="
              f"{args.ttft_slo if args.ttft_slo is not None else 'none'} "
              f"tpot_p95<="
              f"{args.tpot_slo if args.tpot_slo is not None else 'none'} "
              f"-> EDF admission, {len(tenants)} tenant spec(s)")
    else:
        config = EngineConfig(prefill_len=args.prompt_len,
                              prefill_chunk=args.prefill_chunk,
                              step_token_budget=args.step_budget,
                              bucket_policy=args.bucket_policy,
                              prefill_pool=args.prefill_pool,
                              kernels=args.kernels, telemetry=telemetry)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.experts is not None:
        import dataclasses
        if cfg.moe is None:
            raise SystemExit(f"{args.arch} has no MoE layers to widen")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_experts=args.experts))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    moe_impl = args.moe_impl or "aurora"
    mesh = None
    if args.mesh is not None:
        if args.arrival_rate is None:
            raise SystemExit("--mesh serves through the continuous engines; "
                             "add --arrival-rate")
        from repro.launch.mesh import make_ep_mesh
        mesh = make_ep_mesh(args.mesh)

    if args.colocate_with is None:
        if args.arrival_rate is not None:
            kw = dict(batch_slots=args.batch, cache_cap=args.cache_cap,
                      config=config)
            if mesh is not None:
                from repro.core import synthetic_trace
                from repro.serving import (DistributedEngine,
                                           rounds_from_trace)
                if cfg.moe is None:
                    raise SystemExit(
                        f"{args.arch} has no MoE layers — --mesh serves "
                        "expert-parallel (nothing to shard); drop --mesh or "
                        "pick an MoE arch")
                n = cfg.moe.n_experts
                hist = synthetic_trace("hist", n_experts=n, n_layers=2,
                                       seed=0)
                rounds = (rounds_from_trace(hist, args.mesh)
                          if moe_impl == "aurora" else None)
                eng = DistributedEngine(model, params, mesh=mesh,
                                        moe_impl=moe_impl,
                                        rounds=rounds, overlap=args.overlap,
                                        **kw)
                print(f"distributed EP serving: {args.mesh}-device mesh, "
                      f"impl={moe_impl}, overlap={args.overlap}, "
                      f"{len(rounds or ())} scheduled rounds")
            else:
                eng = ContinuousEngine(model, params, **kw)
            reqs = poisson_requests(
                rng, args.num_requests, args.arrival_rate, cfg.vocab,
                args.prompt_len, max(1, args.max_new_tokens // 2),
                args.max_new_tokens)
            for i, r in enumerate(eng.serve(reqs)):
                print(f"req {i} (t={r.arrival:.1f}): {r.out_tokens}")
            total = sum(len(r.out_tokens) for r in reqs)
            print(f"{total} tokens in {eng.decode_steps} decode steps "
                  f"({total / max(eng.decode_steps, 1):.2f} tok/step, "
                  f"{args.batch} slots)")
            return 0
        eng = ServingEngine(model, params, batch_slots=args.batch,
                            cache_cap=args.cache_cap)
        reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                                 args.prompt_len)),
                        max_new_tokens=args.max_new_tokens)
                for _ in range(args.batch)]
        frames = None
        if cfg.is_encoder_decoder:
            frames = rng.standard_normal(
                (args.batch, args.prompt_len, cfg.frontend_dim),
                dtype=np.float32)
        for i, r in enumerate(eng.serve(reqs, frames=frames)):
            print(f"req {i}: {r.out_tokens}")
        return 0

    cfg_b = get_config(args.colocate_with)
    if args.reduced:
        cfg_b = cfg_b.reduced()
    if args.experts is not None and cfg_b.moe is not None:
        import dataclasses
        cfg_b = dataclasses.replace(
            cfg_b, moe=dataclasses.replace(cfg_b.moe,
                                           n_experts=args.experts))
    model_b = Model(cfg_b)
    params_b = model_b.init(jax.random.PRNGKey(1))

    # Plan the expert pairing from synthetic routing statistics (§2.4:
    # historical traces drive the optimization).
    plan = planner = None
    if cfg.moe is not None and cfg_b.moe is not None and \
            cfg.moe.n_experts == cfg_b.moe.n_experts:
        from repro.core import AuroraPlanner, homogeneous_cluster, \
            synthetic_trace
        from repro.serving.colocated import apply_pairing
        n = cfg.moe.n_experts
        tr_a = synthetic_trace("a", n_experts=n, n_layers=2, seed=0)
        tr_b = synthetic_trace("b", n_experts=n, n_layers=2, seed=1)
        planner = AuroraPlanner(homogeneous_cluster(n))
        plan = planner.plan_colocated(tr_a, tr_b)
        params_b = apply_pairing(params_b, plan.pair, cfg_b)
        print(f"aurora colocation pairing: {plan.pair}")

    if args.arrival_rate is not None:
        replan = None
        if args.replan_interval is not None:
            if plan is None:
                raise SystemExit("--replan-interval needs two MoE models "
                                 "with equal expert counts")
            from repro.serving import OnlineReplanner
            replan = OnlineReplanner(planner, interval=args.replan_interval,
                                     threshold=args.replan_threshold,
                                     telemetry=telemetry)
        kw = dict(batch_slots=args.batch, cache_cap=args.cache_cap,
                  config=config, pair=(list(plan.pair) if plan else None),
                  replan=replan)
        if mesh is not None:
            from repro.serving import DistributedColocatedEngine
            eng = DistributedColocatedEngine(
                model, model_b, params, params_b, mesh=mesh,
                moe_impl=moe_impl, plan=plan, overlap=args.overlap,
                **kw)
            print(f"distributed EP colocation: {args.mesh}-device mesh, "
                  f"impl={moe_impl}, overlap={args.overlap}, "
                  f"{len(eng.rounds or ())} scheduled rounds")
        else:
            eng = ColocatedContinuousEngine(model, model_b, params, params_b,
                                            **kw)
        lo = max(1, args.max_new_tokens // 2)
        reqs_a = poisson_requests(rng, args.num_requests, args.arrival_rate,
                                  cfg.vocab, args.prompt_len, lo,
                                  args.max_new_tokens)
        reqs_b = poisson_requests(rng, args.num_requests, args.arrival_rate,
                                  cfg_b.vocab, args.prompt_len, lo,
                                  args.max_new_tokens)
        eng.serve(reqs_a, reqs_b)
        for tag, reqs in (("A", reqs_a), ("B", reqs_b)):
            total = sum(len(r.out_tokens) for r in reqs)
            print(f"model {tag}: {total} tokens over {len(reqs)} requests")
        print(f"{eng.decode_steps} lockstep decode steps")
        for e in eng.replan_events:
            tag = "APPLIED" if e.applied else "kept"
            print(f"replan @ step {e.step}: current {e.stale_time:.3f} vs "
                  f"candidate {e.candidate_time:.3f} -> {tag}")
        if eng.replan_events:
            print(f"final pairing: {eng.pair}")
        return 0

    eng = ColocatedEngine(model, model_b, params, params_b)
    pa = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len))
    pb = rng.integers(1, cfg_b.vocab, (args.batch, args.prompt_len))
    out_a, out_b = eng.serve(pa, pb, max_new_tokens=args.max_new_tokens,
                             cache_cap=args.cache_cap)
    print("model A:", np.asarray(out_a).tolist())
    print("model B:", np.asarray(out_b).tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
