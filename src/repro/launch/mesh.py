"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires host-device flag)."""
    return jax.make_mesh(shape, axes)


def make_ep_mesh(n_devices: int):
    """Flat EP mesh for the distributed serving engines: all devices on the
    ``model`` axis (so any expert count divisible by the device count
    shards), a singleton ``data`` axis to satisfy the sharding rule table."""
    return jax.make_mesh((1, n_devices), ("data", "model"))


def force_host_device_count(n: int) -> None:
    """Split the host platform into ``n`` XLA devices (CI / laptop meshes).

    Must run BEFORE the jax backend initializes (first device query) — this
    is why ``repro.launch.serve`` handles ``--mesh`` before importing jax
    for real work, and why the mesh test tier sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the
    environment instead. A no-op when the flag is already present.
    """
    import os
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = (
            f"{cur} --xla_force_host_platform_device_count={n}".strip())
