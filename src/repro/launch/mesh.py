"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires host-device flag)."""
    return jax.make_mesh(shape, axes)
