"""Abstract input/state specs for the dry-run: ShapeDtypeStructs with
NamedShardings — weak-type-correct, shardable, zero allocation.

Per input shape (configs/shapes.py):
  train_4k     → train_step(params, opt, batch)
  prefill_32k  → prefill_step(params, inputs, cache)
  decode_*     → serve_step(params, token, cache)   (ONE token, full cache)

Family conventions (DESIGN.md §5): VLM prefill takes patch embeddings;
audio (enc-dec) prefill takes source frames + a target prefix of
``seq_len // 4``; enc-dec decode carries a ``SRC_LEN``-frame cross-attention
context. ``long_500k`` only lowers for sub-quadratic configs (shape-skip
matrix in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model, transformer as tf
from repro.sharding import cache_specs, input_sharding, make_pc, param_specs
from repro.training.optim import AdamWConfig, adamw_init

SRC_LEN = 4_096          # enc-dec cross-attention context at decode
AUDIO_TGT_FRac = 4       # enc-dec: target prefix = seq_len // 4

# >100B-param configs keep AdamW moments in bf16 so optimizer state fits
# HBM on 256 chips (recorded in EXPERIMENTS.md §Dry-run).
BIG_MODEL_PARAMS = 100e9


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), shapes_tree, specs_tree)


def supported(cfg, shape) -> bool:
    """Shape-skip matrix (DESIGN.md §5)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def opt_config_for(cfg) -> AdamWConfig:
    big = cfg.param_count() > BIG_MODEL_PARAMS
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


def abstract_params(cfg, mesh):
    shapes = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, mesh)
    return _tree_sds(shapes, specs, mesh)


def abstract_opt(cfg, mesh, params_abs):
    opt_cfg = opt_config_for(cfg)
    shapes = jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), params_abs),
        opt_cfg))
    pspecs = param_specs(cfg, mesh)
    specs = {"m": pspecs, "v": pspecs, "step": P()}
    return _tree_sds(shapes, specs, mesh), opt_cfg


def abstract_cache(cfg, mesh, batch, cap, src_len=0):
    shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, batch, cap, src_len=src_len))
    specs = cache_specs(cfg, mesh, batch, cap, src_len=src_len)
    return _tree_sds(shapes, specs, mesh)


def input_specs(cfg, shape, mesh) -> dict:
    """Abstract step inputs for one (arch × input-shape × mesh)."""
    b, s = shape.global_batch, shape.seq_len
    bspec = input_sharding(cfg, mesh, b)
    batch_ax = bspec[0] if len(bspec) else None

    def tok(shape_):
        return _sds(shape_, jnp.int32, mesh, P(batch_ax) if len(shape_) == 2
                    else P(batch_ax, None, None))

    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            return {"frames": _sds((b, s, cfg.frontend_dim), jnp.bfloat16,
                                   mesh, P(batch_ax, None, None)),
                    "tokens": tok((b, s // AUDIO_TGT_FRac))}
        return {"tokens": tok((b, s))}
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            return {"frames": _sds((b, s, cfg.frontend_dim), jnp.bfloat16,
                                   mesh, P(batch_ax, None, None)),
                    "tokens": tok((b, s // AUDIO_TGT_FRac))}
        if cfg.input_mode == "patches":
            return {"embeds": _sds((b, s, cfg.frontend_dim), jnp.bfloat16,
                                   mesh, P(batch_ax, None, None))}
        return {"tokens": tok((b, s))}
    # decode
    return {"tokens": tok((b, 1))}


def make_step_fns(cfg, mesh, moe_impl: str = "ep", aurora_rounds=None,
                  unroll: bool = False):
    """(train_step, prefill_step, serve_step) closed over a Model+mesh."""
    import dataclasses as _dc
    pc = make_pc(cfg, mesh, moe_impl=moe_impl, aurora_rounds=aurora_rounds)
    if unroll:
        pc = _dc.replace(pc, unroll_segments=True)
    model = Model(cfg, pc)
    from repro.training.loop import make_train_step

    opt_cfg = opt_config_for(cfg)
    train_step = make_train_step(model, opt_cfg)

    def prefill_step(params, inputs, cache):
        return model.prefill(params, inputs, cache)

    def serve_step(params, token, cache):
        return model.decode_step(params, token, cache)

    return model, train_step, prefill_step, serve_step


def lowering_args(cfg, shape, mesh, moe_impl: str = "ep",
                  aurora_rounds=None, unroll: bool = False):
    """(step_fn, abstract_args) ready for jit(...).lower(*args)."""
    model, train_step, prefill_step, serve_step = make_step_fns(
        cfg, mesh, moe_impl, aurora_rounds, unroll=unroll)
    params = abstract_params(cfg, mesh)
    inputs = input_specs(cfg, shape, mesh)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        opt, _ = abstract_opt(cfg, mesh, params)
        return train_step, (params, opt, inputs)
    if shape.kind == "prefill":
        tgt = (s // AUDIO_TGT_FRac) if cfg.is_encoder_decoder else s
        cache = abstract_cache(cfg, mesh, b, tgt,
                               src_len=s if cfg.is_encoder_decoder else 0)
        return prefill_step, (params, inputs, cache)
    cache = abstract_cache(cfg, mesh, b, s,
                           src_len=SRC_LEN if cfg.is_encoder_decoder else 0)
    return serve_step, (params, inputs["tokens"], cache)
