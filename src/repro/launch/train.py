"""Training driver.

Single-host (CPU/example) mode runs a real loop on a reduced config:

  python -m repro.launch.train --arch phi3.5-moe-42b-a6.6b --reduced \
      --steps 100 --batch 8 --seq 128

On the production mesh the same script is pointed at the full config with
``--mesh pod16x16`` (the step function is identical to the one the dry-run
lowers for ``train_4k``).
"""

from __future__ import annotations

import argparse
import json


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import Model
    from repro.training import (AdamWConfig, SyntheticLMData,
                                save_checkpoint, train_loop)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    data = SyntheticLMData(
        cfg.vocab, seq_len=args.seq, batch=args.batch,
        frames_dim=cfg.frontend_dim if cfg.is_encoder_decoder else 0,
        frames_len=args.seq if cfg.is_encoder_decoder else 0)
    state, hist = train_loop(
        model, data, steps=args.steps,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1)),
        log_every=args.log_every)
    for h in hist:
        print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                          for k, v in h.items()}))
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state.params, step=state.step)
        print(f"saved checkpoint to {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
