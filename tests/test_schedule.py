"""Thm 4.2 / Thm 5.2 schedule properties + fluid network model."""

import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis if installed

from repro.core.schedule import (aurora_schedule, augment_to_bmax, b_max_of,
                                 comm_time, fluid_comm_time, rcs_order,
                                 sjf_order, time_matrix)
from repro.core.traffic import strip_diagonal


def random_traffic(rng, n, density=1.0, scale=10.0):
    d = rng.random((n, n)) * scale
    mask = rng.random((n, n)) < density
    d = d * mask
    np.fill_diagonal(d, 0.0)
    return d


# ---------------------------------------------------------------------------
# Fig 4: the paper's worked example
# ---------------------------------------------------------------------------

def test_fig4_contention_example():
    """GPU1→{2,3}, GPU2→{1,3}: naive order takes 3 units, optimal takes 2."""
    bad = [[(1, 1.0), (2, 1.0)], [(0, 1.0), (2, 1.0)], []]
    good = [[(1, 1.0), (2, 1.0)], [(2, 1.0), (0, 1.0)], []]
    assert fluid_comm_time(bad, 1.0, 3) == pytest.approx(3.0)
    assert fluid_comm_time(good, 1.0, 3) == pytest.approx(2.0)
    d = np.array([[0, 1, 1], [1, 0, 1], [0, 0, 0]], float)
    sched = aurora_schedule(d)
    assert sched.b_max == pytest.approx(2.0)
    assert sched.total_time == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Property tests: schedule validity (homogeneous)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(2, 10), st.integers(0, 10_000), st.floats(0.2, 1.0))
def test_schedule_achieves_bmax_and_is_contention_free(n, seed, density):
    rng = np.random.default_rng(seed)
    d = random_traffic(rng, n, density)
    sched = aurora_schedule(d)
    bm = max(d.sum(1).max(), d.sum(0).max())
    assert sched.b_max == pytest.approx(bm, abs=1e-8)
    # Thm 4.2: total schedule length is exactly b_max.
    assert sched.total_time == pytest.approx(bm, abs=1e-6)
    sent = np.zeros_like(d)
    for slot in sched.slots:
        real = [j for j in slot.dst if j >= 0]
        # contention-free: every receiver hears from at most one sender
        assert len(real) == len(set(real))
        for i, j in enumerate(slot.dst):
            if j >= 0:
                assert i != j
                sent[i, j] += slot.duration
    # conservation: the schedule moves at least the real traffic (slots may
    # carry a little artificial padding when a real edge shares a slot).
    assert (sent + 1e-6 >= d).all()
    # and it never invents traffic on pairs that had none
    assert (sent[d <= 1e-12] <= 1e-8).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_schedule_heterogeneous_bmax(n, seed):
    rng = np.random.default_rng(seed)
    d = random_traffic(rng, n)
    bw = rng.choice([40.0, 50.0, 80.0, 100.0], size=n)
    sched = aurora_schedule(d, bw)
    t = time_matrix(d, bw)
    bm = max(t.sum(1).max(), t.sum(0).max())
    assert sched.b_max == pytest.approx(bm, abs=1e-8)
    assert sched.total_time == pytest.approx(bm, abs=1e-6)


def test_augment_to_bmax_properties():
    rng = np.random.default_rng(0)
    d = random_traffic(rng, 6)
    d_prime, bm = augment_to_bmax(d)
    assert (d_prime + 1e-12 >= d).all()  # X is non-negative (Farkas)
    np.testing.assert_allclose(d_prime.sum(1), bm, rtol=1e-9)
    np.testing.assert_allclose(d_prime.sum(0), bm, rtol=1e-9)


# ---------------------------------------------------------------------------
# Baselines can never beat the bound; Aurora always matches it
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 7), st.integers(0, 10_000))
def test_bmax_is_a_lower_bound_for_any_order(n, seed):
    rng = np.random.default_rng(seed)
    d = random_traffic(rng, n)
    bm = b_max_of(d)
    for order in (sjf_order(d), rcs_order(d, seed)):
        assert fluid_comm_time(order, 1.0, n) >= bm - 1e-6


def test_comm_time_policies():
    rng = np.random.default_rng(42)
    d = random_traffic(rng, 6)
    t_aurora = comm_time(d, "aurora")
    t_sjf = comm_time(d, "sjf")
    t_rcs = comm_time(d, "rcs", seed=1)
    assert t_aurora <= t_sjf + 1e-9
    assert t_aurora <= t_rcs + 1e-9
    with pytest.raises(ValueError):
        comm_time(d, "nope")


def test_empty_traffic():
    sched = aurora_schedule(np.zeros((4, 4)))
    assert sched.total_time == 0.0
    assert sched.n_slots == 0


def test_transpose_symmetry():
    """The two all-to-alls are reverses (§2.2): same optimal time."""
    rng = np.random.default_rng(3)
    d = random_traffic(rng, 5)
    assert aurora_schedule(d).b_max == pytest.approx(aurora_schedule(d.T).b_max)


def test_sender_orders_cover_traffic():
    rng = np.random.default_rng(9)
    d = random_traffic(rng, 5)
    orders = aurora_schedule(d).sender_orders()
    got = np.zeros_like(d)
    for i, seq in enumerate(orders):
        for j, dur in seq:
            got[i, j] += dur
    assert (got + 1e-6 >= strip_diagonal(d)).all()
