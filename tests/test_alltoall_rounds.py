"""Round semantics of the ppermute lowering (host-side + 1-device mesh).

The exchange in ``repro.distributed.alltoall`` is only correct when the
round sequence is a *cover*: every ordered off-diagonal (src, dst) pair
appears in exactly one round, and every round is a partial permutation.
These properties are cheap to check host-side for both round constructors;
the mesh-collective equivalence runs in ``tests/test_distributed.py`` /
``tests/test_distributed_serving.py`` (8 host devices, subprocess).
"""

import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis if installed

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import aurora_schedule, synthetic_trace
from repro.core.schedule import CommSchedule, Slot, validate_permutation_slots
from repro.distributed import (aurora_rounds_from_schedule, ep_all_to_all,
                               round_robin_rounds)


def _coverage(rounds, n):
    """Assert every round is a partial permutation; return the (n, n) count
    of how often each ordered pair is exchanged."""
    seen = np.zeros((n, n), int)
    for dst in rounds:
        assert len(dst) == n
        real = [j for j in dst if j >= 0]
        assert len(real) == len(set(real)), "two senders hit one receiver"
        for i, j in enumerate(dst):
            if j >= 0:
                assert i != j, "self-send crossed the network"
                seen[i, j] += 1
    return seen


@pytest.mark.parametrize("n", [1, 2, 3, 5, 8])
def test_round_robin_rounds_cover_each_pair_once(n):
    seen = _coverage(round_robin_rounds(n), n)
    off = ~np.eye(n, dtype=bool)
    assert (seen[off] == 1).all()
    assert (np.diag(seen) == 0).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 10), st.integers(0, 10_000), st.floats(0.0, 1.0))
def test_bvn_rounds_cover_each_pair_once(n, seed, density):
    """Round-trip property: schedule → rounds covers every ordered pair
    exactly once, whatever the traffic looked like (sparse rows, zero rows,
    pairs absent from the schedule get cleanup rounds)."""
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)) * (rng.random((n, n)) < density)
    np.fill_diagonal(d, 0.0)
    rounds = aurora_rounds_from_schedule(aurora_schedule(d), n)
    seen = _coverage(rounds, n)
    off = ~np.eye(n, dtype=bool)
    assert (seen[off] == 1).all(), seen


def test_degenerate_schedules():
    """Single device and zero-traffic rows are explicit, not accidental."""
    # n == 1: self-traffic never crosses the network — no rounds at all.
    assert aurora_rounds_from_schedule(aurora_schedule(np.zeros((1, 1))), 1) \
        == ()
    assert round_robin_rounds(1) == ()
    # All-zero traffic: empty schedule, but the lowering still needs a full
    # cover (traffic drift §8 Q4) — cleanup rounds provide it.
    rounds = aurora_rounds_from_schedule(aurora_schedule(np.zeros((4, 4))), 4)
    assert (_coverage(rounds, 4)[~np.eye(4, dtype=bool)] == 1).all()
    # One silent device (zero row AND column) still gets cleanup coverage.
    d = np.zeros((4, 4))
    d[0, 1] = d[1, 0] = 3.0
    rounds = aurora_rounds_from_schedule(aurora_schedule(d), 4)
    assert (_coverage(rounds, 4)[~np.eye(4, dtype=bool)] == 1).all()


def test_non_permutation_slots_raise():
    """Malformed slots fail loudly instead of silently misrouting buckets."""
    def sched(dst):
        return CommSchedule(slots=(Slot(dst=tuple(dst), duration=1.0),),
                            b_max=1.0)

    with pytest.raises(ValueError, match="two senders"):
        aurora_rounds_from_schedule(sched([1, -1, 1]), 3)
    with pytest.raises(ValueError, match="self-send"):
        aurora_rounds_from_schedule(sched([0, 2, 1]), 3)
    with pytest.raises(ValueError, match="out of range"):
        aurora_rounds_from_schedule(sched([3, -1, -1]), 3)
    with pytest.raises(ValueError, match="entries for"):
        aurora_rounds_from_schedule(sched([1, 0]), 3)
    with pytest.raises(ValueError, match="positive device count"):
        validate_permutation_slots((), 0)
    # A valid schedule passes through the validator untouched.
    validate_permutation_slots(sched([1, 0, -1]).slots, 3)


def test_literal_rounds_demand_a_full_cover():
    """Rounds installed verbatim on an engine (``swap_rounds`` / ctor
    ``rounds=``) must cover every ordered pair exactly once — a truncated
    cover would silently drop token buckets in flight."""
    from repro.distributed.alltoall import validate_rounds_cover

    good = round_robin_rounds(4)
    assert validate_rounds_cover(good, 4) == good
    assert validate_rounds_cover((), 1) == ()
    with pytest.raises(ValueError, match="never exchanged"):
        validate_rounds_cover(good[:-1], 4)            # truncated cover
    with pytest.raises(ValueError, match="more than once"):
        validate_rounds_cover(good + good[-1:], 4)     # duplicate round
    with pytest.raises(ValueError, match="two senders"):
        validate_rounds_cover(((1, -1, 1),), 3)
    with pytest.raises(ValueError, match="self-send"):
        validate_rounds_cover(((0, -1, -1),), 3)
    with pytest.raises(ValueError, match="out of range"):
        validate_rounds_cover(((9, -1, -1),), 3)
    with pytest.raises(ValueError, match="entries for"):
        validate_rounds_cover(((1, 0),), 3)


def test_schedule_traffic_roundtrip():
    """``CommSchedule.traffic`` recovers what the slots move (the inverse
    view the distributed round refresh consumes)."""
    rng = np.random.default_rng(3)
    d = rng.random((5, 5)) * 10
    np.fill_diagonal(d, 0.0)
    sent = aurora_schedule(d).traffic()
    assert sent.shape == (5, 5)
    # Conservation (same property the schedule tests assert): everything
    # real moves, nothing is invented on empty pairs.
    assert (sent + 1e-6 >= d).all()
    assert (sent[d <= 1e-12] <= 1e-8).all()
    assert CommSchedule(slots=(), b_max=0.0).traffic().shape == (0, 0)
    assert CommSchedule(slots=(), b_max=0.0).traffic(3).shape == (3, 3)


def test_ep_all_to_all_identity_on_one_device_mesh():
    """A 1-device mesh's exchange is the identity for every lowering: the
    monolithic all_to_all, an empty round schedule, and the BvN-derived
    rounds of a 1-device schedule (== empty)."""
    mesh = jax.make_mesh((1,), ("ep",))
    x = jnp.arange(24, dtype=jnp.float32).reshape(1, 6, 4)
    rounds_1 = aurora_rounds_from_schedule(
        aurora_schedule(np.zeros((1, 1))), 1)

    for rounds in (None, (), rounds_1):
        y = jax.jit(shard_map(
            lambda b, rounds=rounds: ep_all_to_all(b, ("ep",), rounds),
            mesh=mesh, in_specs=P("ep"), out_specs=P("ep"),
            check_vma=False))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_trace_rounds_roundtrip_through_device_aggregation():
    """Expert-granularity traces aggregate onto fewer devices and still
    yield a full contention-free cover (the serving engines' path)."""
    from repro.serving import device_traffic, rounds_from_trace

    trace = synthetic_trace("t", n_experts=16, n_layers=3, seed=11)
    for n_dev in (2, 4, 8, 16):
        rounds = rounds_from_trace(trace, n_dev)
        seen = _coverage(rounds, n_dev)
        off = ~np.eye(n_dev, dtype=bool)
        assert (seen[off] == 1).all()
    agg = device_traffic(trace.layer(0), 4)
    assert agg.shape == (4, 4)
    assert np.trace(agg) == 0.0
    with pytest.raises(ValueError, match="do not shard"):
        device_traffic(trace.layer(0), 5)
