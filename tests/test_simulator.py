"""Table 2 / Eqn 1–4 simulator invariants and paper-claim bands."""

import numpy as np
import pytest

from repro.core import (AuroraPlanner, add_noise, colocated_inference_time,
                        exclusive_inference_time, heterogeneous_cluster,
                        homogeneous_cluster, lina_inference_time,
                        paper_eval_traces, random_pairing,
                        synthetic_trace)


@pytest.fixture(scope="module")
def traces():
    return paper_eval_traces(seed=0)


@pytest.fixture(scope="module")
def hom():
    return homogeneous_cluster(8)


@pytest.fixture(scope="module")
def het():
    return heterogeneous_cluster(8)


def test_exclusive_decomposition(traces, hom):
    b16, _ = traces
    r = exclusive_inference_time(b16, 0, hom)
    d = r.detail
    assert r.inference_time == pytest.approx(
        d["gate"] + d["N"] + d["ffn"] + d["C"] + d["agg"])
    assert 0.0 < r.utilization < 1.0


def test_colocated_not_faster_than_exclusive_model_a(traces, hom):
    """Adding a second model can only extend model a's completion."""
    b16, b32 = traces
    pair = AuroraPlanner(hom).plan_colocated(b16, b32).pair
    t_co = colocated_inference_time(b16, b32, 0, hom, pair).inference_time
    t_ex = exclusive_inference_time(b16, 0, hom).inference_time
    assert t_co >= t_ex - 1e-9


def test_colocated_chain_is_monotone_in_policy(traces, hom):
    b16, b32 = traces
    pair = random_pairing(8, 0)
    t_a = colocated_inference_time(b16, b32, 0, hom, pair, policy="aurora")
    t_r = colocated_inference_time(b16, b32, 0, hom, pair, policy="rcs")
    assert t_a.inference_time <= t_r.inference_time + 1e-9


def test_heterogeneous_slows_down_uniform_deployment(traces, hom, het):
    b16, _ = traces
    t_hom = exclusive_inference_time(b16, 0, hom).inference_time
    t_het = exclusive_inference_time(b16, 0, het).inference_time
    assert t_het > t_hom  # slower tiers must hurt


def test_utilization_bounds(traces, hom, het):
    b16, b32 = traces
    for cl in (hom, het):
        plan = AuroraPlanner(cl).plan_colocated(b16, b32)
        r = colocated_inference_time(b16, b32, 0, cl, plan.pair,
                                     plan.expert_to_device)
        assert 0.0 < r.utilization < 1.0


# ---------------------------------------------------------------------------
# Paper-claim bands (§8.2) on the synthetic production-like traces
# ---------------------------------------------------------------------------

def test_q1_scheduling_beats_sjf_and_rcs(traces, hom):
    b16, _ = traces
    for layer in range(4):
        t_a = exclusive_inference_time(b16, layer, hom, policy="aurora")
        t_s = exclusive_inference_time(b16, layer, hom, policy="sjf")
        t_r = exclusive_inference_time(b16, layer, hom, policy="rcs")
        assert t_a.inference_time <= t_s.inference_time + 1e-9
        assert t_a.inference_time <= t_r.inference_time + 1e-9


def test_q1_colocation_beats_lina(traces, hom):
    b16, b32 = traces
    plan = AuroraPlanner(hom).plan_colocated(b16, b32)
    ratios = []
    for layer in range(4):
        t_co = colocated_inference_time(b16, b32, layer, hom, plan.pair)
        t_li = lina_inference_time(b16, layer, hom, policy="rcs")
        ratios.append(t_li.inference_time / t_co.inference_time)
    # Fig 11c band: 1.25x – 2.38x
    assert min(ratios) > 1.0
    assert 1.25 <= float(np.mean(ratios)) <= 2.6


def test_q2_utilization_gain(traces, hom):
    b16, b32 = traces
    plan = AuroraPlanner(hom).plan_colocated(b16, b32)
    gains = []
    for layer in range(4):
        r_co = colocated_inference_time(b16, b32, layer, hom, plan.pair)
        r_ex = exclusive_inference_time(b16, layer, hom)
        gains.append(r_co.utilization / r_ex.utilization)
    # Fig 12a band: colocation lifts utilization 1.57x – 1.72x over exclusive
    assert 1.3 <= float(np.mean(gains)) <= 2.0


def test_q4_noise_robustness(traces, het):
    """Fig 14: with 75% traffic noise the plan degrades bounded (~16%)."""
    b16, _ = traces
    plan = AuroraPlanner(het).plan_exclusive(b16)
    base, noisy = [], []
    for layer in range(4):
        base.append(exclusive_inference_time(
            b16, layer, het, plan.expert_to_device).inference_time)
    b16_noisy = add_noise(b16, 0.75, seed=1)
    for layer in range(4):
        noisy.append(exclusive_inference_time(
            b16_noisy, layer, het, plan.expert_to_device).inference_time)
    degradation = float(np.mean(noisy)) / float(np.mean(base))
    assert degradation < 1.35  # bounded degradation under heavy noise


def test_plan_exclusive_schedules_match_layers(traces, hom):
    b16, _ = traces
    plan = AuroraPlanner(hom).plan_exclusive(b16)
    assert plan.n_layers == 4
    for sched in plan.schedules:
        assert sched.total_time == pytest.approx(sched.b_max, abs=1e-6)


def test_unequal_expert_counts_rejected(hom):
    a = synthetic_trace("a", n_experts=8, n_layers=1, seed=0)
    b = synthetic_trace("b", n_experts=4, n_layers=1, seed=1)
    with pytest.raises(ValueError):
        colocated_inference_time(a, b, 0, hom, list(range(8)))
