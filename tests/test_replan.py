"""Traffic monitor, trace-from-counts, plan diffing, and the online
re-planning loop's placement-only invariant."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AuroraPlanner, diff_plans, heterogeneous_cluster,
                        homogeneous_cluster, synthetic_trace,
                        trace_from_counts)
from repro.models import Model
from repro.serving import (ColocatedContinuousEngine, ContinuousEngine,
                           EngineConfig, OnlineReplanner, Request,
                           TrafficMonitor, inverse_pair)


def _model(arch, seed=0):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(n=5, seed=0, max_new=5):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, 500, 6)),
                    max_new_tokens=max_new, arrival=float(i))
            for i in range(n)]


# -- trace_from_counts ------------------------------------------------------

def test_trace_from_counts_shape_and_popularity():
    counts = np.array([[10.0, 30.0, 40.0, 20.0],
                       [0.0, 0.0, 0.0, 0.0]])       # layer 1: unobserved
    tr = trace_from_counts("t", counts, tokens_per_device=100.0)
    assert tr.n == 4 and len(tr.layers) == 2
    d0 = tr.layer(0)
    assert np.all(np.diag(d0) == 0.0)               # self-traffic stripped
    # receive-side popularity proportional to counts (off-diagonal sums)
    recv = d0.sum(axis=0)
    assert recv[2] > recv[1] > recv[3] > recv[0]
    # unobserved layer falls back to uniform popularity
    d1 = tr.layer(1)
    off = d1[~np.eye(4, dtype=bool)]
    np.testing.assert_allclose(off, off[0])


def test_trace_from_counts_validates():
    with pytest.raises(ValueError):
        trace_from_counts("t", np.ones((2, 3, 4)))
    with pytest.raises(ValueError):
        trace_from_counts("t", -np.ones((2, 3)))


# -- TrafficMonitor ---------------------------------------------------------

def test_monitor_ewma_and_mask():
    mon = TrafficMonitor(n_experts=4, n_layers=2, halflife=8.0)
    stats = np.zeros((2, 3, 4))
    stats[:, 0, 1] = 2.0                            # slot 0 -> expert 1
    stats[:, 2, 3] = 2.0                            # slot 2 -> expert 3
    mon.observe(stats, mask=np.array([True, False, False]))
    assert mon.observations == 1
    np.testing.assert_allclose(mon.rates[:, 1], 2.0)
    np.testing.assert_allclose(mon.rates[:, 3], 0.0)   # masked out
    mon.observe(stats)                               # unmasked this time
    assert mon.rates[0, 3] > 0.0
    tr = mon.trace()
    assert tr.n == 4 and len(tr.layers) == 2
    with pytest.raises(ValueError):
        mon.observe(np.zeros((3, 1, 4)))            # wrong layer count
    with pytest.raises(ValueError):
        TrafficMonitor(n_experts=4, n_layers=0)


def test_monitor_harvests_engine_routing():
    """A monitored engine's counts must reflect real routed volume:
    top_k choices per active row per MoE layer per observation."""
    cfg, model, params = _model("phi3.5-moe-42b-a6.6b")
    mon = TrafficMonitor(cfg.moe.n_experts, model.n_moe_layers)
    eng = ContinuousEngine(model, params, 2, 48,
                           config=EngineConfig(prefill_chunk=2),
                           monitor=mon)
    eng.serve(_requests())
    assert mon.observations > 0
    # Every observation routes <= batch_slots * top_k per layer (decode) and
    # exactly chunk * top_k for prefill chunks; rates land in that envelope.
    assert np.all(mon.rates.sum(axis=1) > 0.0)
    assert np.all(mon.rates.sum(axis=1) <= 2 * 2 * cfg.moe.top_k + 1e-9)


# -- planner additions ------------------------------------------------------

def test_evaluate_colocated_matches_plan_prediction():
    tr_a = synthetic_trace("a", n_experts=4, n_layers=2, seed=0)
    tr_b = synthetic_trace("b", n_experts=4, n_layers=2, seed=1)
    planner = AuroraPlanner(homogeneous_cluster(4))
    plan = planner.plan_colocated(tr_a, tr_b)
    ev = planner.evaluate_colocated(tr_a, tr_b, plan.pair)
    assert ev.inference_time == pytest.approx(plan.predicted.inference_time)


def test_diff_plans():
    tr_a = synthetic_trace("a", n_experts=4, n_layers=2, seed=0)
    tr_b = synthetic_trace("b", n_experts=4, n_layers=2, seed=1)
    planner = AuroraPlanner(homogeneous_cluster(4))
    p1 = planner.plan_colocated(tr_a, tr_b)
    d_same = diff_plans(p1, p1)
    assert not d_same.placement_changed
    assert d_same.rel_improvement == pytest.approx(0.0)
    p2 = planner.plan_colocated(tr_b, tr_a)          # different traffic
    d = diff_plans(p1, p2, old_time=10.0)
    assert d.old_time == 10.0
    assert d.rel_improvement == pytest.approx(
        (10.0 - p2.predicted.inference_time) / 10.0)


# -- online re-planning -----------------------------------------------------

def test_replan_never_changes_tokens():
    """The placement-only invariant end to end: a colocated stream served
    with aggressive re-planning emits exactly the tokens of a run that
    never re-plans — across BOTH pools, including chunked admissions."""
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b", seed=0)
    cfg_b, mb, pb = _model("phi3.5-moe-42b-a6.6b", seed=1)
    planner = AuroraPlanner(homogeneous_cluster(cfg_a.moe.n_experts))

    mk_a = lambda: _requests(5, seed=3)
    mk_b = lambda: _requests(4, seed=4)
    ref = ColocatedContinuousEngine(ma, mb, pa, pb, 2, 48,
                                    config=EngineConfig(prefill_chunk=2))
    ra0, rb0 = ref.serve(mk_a(), mk_b())

    # threshold < 0 applies EVERY candidate whose pairing differs — the
    # most churn the loop can produce, the strongest invariant check.
    rp = OnlineReplanner(planner, interval=3, threshold=-1.0, warmup=1)
    eng = ColocatedContinuousEngine(ma, mb, pa, pb, 2, 48,
                                    config=EngineConfig(prefill_chunk=2),
                                    replan=rp)
    ra1, rb1 = eng.serve(mk_a(), mk_b())
    assert [r.out_tokens for r in ra0] == [r.out_tokens for r in ra1]
    assert [r.out_tokens for r in rb0] == [r.out_tokens for r in rb1]
    applied = [e for e in eng.replan_events if e.applied]
    assert applied, "forced re-planning never fired"
    assert eng.pair == applied[-1].pair


def test_reassign_never_changes_tokens():
    """Scenario 2 (exclusive + heterogeneous) re-assignment end to end: a
    monitored stream with forced ``maybe_reassign`` adoptions emits exactly
    the tokens of a run that never re-seats — the Thm 5.1 expert<->GPU move
    is placement-only — and the monitor's stats frame follows the seats."""
    cfg, model, params = _model("phi3.5-moe-42b-a6.6b")
    n = cfg.moe.n_experts
    mk = lambda: _requests(6, seed=7)
    ref = ContinuousEngine(model, params, 2, 48,
                           config=EngineConfig(prefill_chunk=2)).serve(mk())

    mon = TrafficMonitor(n, model.n_moe_layers)
    # threshold < 0 adopts EVERY candidate whose assignment differs — the
    # most re-seating the loop can produce, the strongest invariant check.
    rp = OnlineReplanner(AuroraPlanner(heterogeneous_cluster(n)),
                         interval=2, threshold=-1.0, warmup=1)
    eng = ContinuousEngine(model, params, 2, 48,
                           config=EngineConfig(prefill_chunk=2),
                           monitor=mon)
    reqs = mk()
    for r in reqs:
        eng.submit(r)
    step = 0
    while eng.step():
        step += 1
        plan = rp.maybe_reassign(step, mon, eng.assignment)
        if plan is not None:
            eng.adopt(plan)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]
    applied = [e for e in rp.events if e.applied]
    assert applied, "forced re-assignment never fired"
    assert tuple(eng.assignment) == applied[-1].assignment
    assert mon.slot_to_expert == inverse_pair(eng.assignment)


def test_replan_hysteresis_keeps_plan():
    """An unreachable improvement threshold must never swap the pairing."""
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b", seed=0)
    cfg_b, mb, pb = _model("phi3.5-moe-42b-a6.6b", seed=1)
    planner = AuroraPlanner(homogeneous_cluster(cfg_a.moe.n_experts))
    rp = OnlineReplanner(planner, interval=3, threshold=10.0, warmup=1)
    eng = ColocatedContinuousEngine(ma, mb, pa, pb, 2, 48, replan=rp)
    pair0 = list(eng.pair)
    eng.serve(_requests(4, seed=5), _requests(4, seed=6))
    assert eng.pair == pair0
    assert eng.replan_events and not any(e.applied for e in eng.replan_events)


def test_monitor_slot_to_expert_translation():
    """Observations from a permuted model translate back to original-expert
    space: slot k's counts are credited to expert slot_to_expert[k]."""
    mon = TrafficMonitor(n_experts=4, n_layers=1, halflife=8.0)
    mon.slot_to_expert = [2, 0, 3, 1]
    stats = np.zeros((1, 1, 4))
    stats[0, 0] = [5.0, 0.0, 1.0, 0.0]     # slots 0 and 2 routed
    mon.observe(stats)
    np.testing.assert_allclose(mon.rates[0], [0.0, 0.0, 5.0, 1.0])


def test_paired_pool_traffic_lands_in_original_expert_frame():
    """End to end: model B served PAIRED must report the same original-
    expert traffic as the identical stream through the unpaired model —
    otherwise the re-planner would optimize a permuted phantom trace."""
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b", seed=0)
    cfg_b, mb, pb = _model("phi3.5-moe-42b-a6.6b", seed=1)
    from repro.serving import apply_pairing

    planner = AuroraPlanner(homogeneous_cluster(cfg_a.moe.n_experts))
    pair0 = [2, 0, 3, 1]
    rp = OnlineReplanner(planner, interval=10_000)   # monitors only
    mk = lambda s: _requests(4, seed=s)

    paired = ColocatedContinuousEngine(
        ma, mb, pa, apply_pairing(pb, pair0, cfg_b), 2, 48,
        pair=pair0, replan=rp)
    paired.serve(mk(1), mk(2))

    rp2 = OnlineReplanner(planner, interval=10_000)
    ident = ColocatedContinuousEngine(ma, mb, pa, pb, 2, 48, replan=rp2)
    ident.serve(mk(1), mk(2))

    np.testing.assert_allclose(paired.monitor_b.rates,
                               ident.monitor_b.rates, atol=1e-9)


def test_replan_requires_matching_moe():
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b", seed=0)
    cfg_d, md, pd = _model("qwen3-32b", seed=1)       # dense model
    planner = AuroraPlanner(homogeneous_cluster(cfg_a.moe.n_experts))
    rp = OnlineReplanner(planner, interval=4)
    with pytest.raises(ValueError, match="MoE"):
        ColocatedContinuousEngine(ma, md, pa, pd, 2, 32, replan=rp)
