"""Distributed serving subsystem tests (8 host devices, subprocess).

Like ``test_distributed.py``, everything needing a mesh runs via
``python -c`` with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in the child environment only, so the main pytest process keeps ONE device.

Covers the three layers of the subsystem:
  1. collective — the round-pipelined dispatch is token-identical to the
     synchronous exchange, and ``return_counts`` works in-collective;
  2. engine — ``DistributedEngine`` serves EP-sharded and ``adopt()`` swaps
     ppermute rounds mid-stream placement-only;
  3. colocated — online re-planning refreshes the rounds, and the refresh
     itself never changes a token.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pipelined_dispatch_matches_sync_and_counts_match_dense():
    """The software pipeline (FFN chunks overlapping in-flight ppermute
    rounds) emits byte-identical outputs to the synchronous exchange, at
    experts_per_device 1 AND > 1, and the in-collective psum'd routing
    counts equal the dense reference's exactly."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import set_mesh
    from repro.configs.base import MoEConfig
    from repro.core import aurora_schedule, synthetic_trace
    from repro.distributed import (aurora_rounds_from_schedule,
                                   pipelined_dispatch_combine)
    from repro.models.layers import ParallelContext
    from repro.models.moe import init_moe, moe_apply_dense, moe_apply_ep
    from repro.serving import rounds_from_trace

    mesh = jax.make_mesh((8,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    for e in (8, 16):                       # experts_per_device 1 and 2
        moe = MoEConfig(n_experts=e, top_k=2, d_ff=64, capacity_factor=8.0)
        p = init_moe(jax.random.PRNGKey(e), 32, moe, jnp.float32)
        rounds = rounds_from_trace(
            synthetic_trace("h", n_experts=e, n_layers=1, seed=7), 8)
        pc = ParallelContext(mesh=mesh, data_axes=(), model_axis=None,
                             ep_axes=("model",), token_axes=("model",),
                             moe_impl="aurora", aurora_rounds=rounds)
        pc_pipe = dataclasses.replace(pc, ep_overlap=True)
        y_ref, _, c_ref = jax.jit(lambda x, p=p, moe=moe: moe_apply_dense(
            p, x, moe, "swiglu", return_counts=True))(x)
        with set_mesh(mesh):
            y_sync, _, c_sync = jax.jit(
                lambda x, p=p, moe=moe, pc=pc: moe_apply_ep(
                    p, x, moe, "swiglu", pc, return_counts=True))(x)
            y_pipe, _, c_pipe = jax.jit(
                lambda x, p=p, moe=moe, pc=pc_pipe: moe_apply_ep(
                    p, x, moe, "swiglu", pc, return_counts=True))(x)
        # Token-identity of the pipeline: BYTE-identical to the sync path
        # (same routing, same buckets, same per-row FFN, same combine).
        np.testing.assert_array_equal(np.asarray(y_pipe), np.asarray(y_sync))
        np.testing.assert_allclose(np.asarray(y_sync), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        # Counts are integer-valued and frame-identical across all paths.
        np.testing.assert_array_equal(np.asarray(c_sync), np.asarray(c_ref))
        np.testing.assert_array_equal(np.asarray(c_pipe), np.asarray(c_ref))
        # The standalone wrapper (forced pipeline) agrees too.
        with set_mesh(mesh):
            xt = x.reshape(-1, 32)
            y_w, _ = jax.jit(lambda xt, p=p, moe=moe, pc=pc:
                             pipelined_dispatch_combine(
                                 xt, p["router"], p["experts"], moe,
                                 "swiglu", pc))(xt)
        np.testing.assert_array_equal(np.asarray(y_w),
                                      np.asarray(y_pipe.reshape(-1, 32)))
    print("PIPELINE OK")
    """)


def test_distributed_engine_adopt_swaps_rounds_placement_only():
    """``DistributedEngine`` serves a stream EP-sharded (pipelined rounds)
    and a mid-stream ``adopt()`` — fresh BvN rounds from drifted traffic —
    changes the ppermute schedule but not one emitted token."""
    _run("""
    import dataclasses
    import numpy as np
    import jax
    from repro.configs import get_config
    from repro.core import synthetic_trace
    from repro.launch.mesh import make_ep_mesh
    from repro.models import Model
    from repro.serving import (DistributedEngine, EngineConfig, Request,
                               TrafficMonitor)

    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8,
                                     capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_ep_mesh(8)
    hist = synthetic_trace("hist", n_experts=8, n_layers=2, seed=0)
    drift = synthetic_trace("drift", n_experts=8, n_layers=2, seed=9)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab, 8)) for _ in range(3)]

    def serve(adopt_at, monitor=None):
        eng = DistributedEngine(model, params, batch_slots=2, cache_cap=32,
                                mesh=mesh, rounds=None, plan=hist,
                                overlap=True, monitor=monitor,
                                config=EngineConfig(prefill_len=8))
        r0 = eng.rounds
        for pr in prompts:
            eng.submit(Request(prompt=list(pr), max_new_tokens=6))
        reqs, steps = list(eng.queue), 0
        while eng.step():
            steps += 1
            if steps == adopt_at:
                eng.adopt(drift)
        return eng, r0, [r.out_tokens for r in reqs]

    eng_a, r0, toks_a = serve(adopt_at=None)
    mon = TrafficMonitor(8, eng_a.model.n_moe_layers)
    eng_b, _, toks_b = serve(adopt_at=3, monitor=mon)
    assert eng_b.rounds != r0, "adopt() did not change the round schedule"
    assert all(t for t in toks_a), toks_a
    assert toks_a == toks_b, "rounds swap changed emitted tokens"
    # The monitor harvested in-collective counts from the EP decode path.
    assert mon.observations > 0 and mon.counts.sum() > 0
    print("ADOPT OK", len(r0), "->", len(eng_b.rounds))
    """)


def test_distributed_engine_adopts_replicated_plan_placement_only():
    """A ``plan_replicated(..., total_multiple=n_ep)`` adopted mid-stream
    widens the EP-sharded expert leaves AND swaps the rounds, byte-identical
    token streams; a plan whose physical expert count does not shard over
    the EP axis is refused loudly."""
    _run("""
    import dataclasses
    import numpy as np
    import jax
    from repro.configs import get_config
    from repro.core import AuroraPlanner, homogeneous_cluster, \\
        trace_from_counts
    from repro.launch.mesh import make_ep_mesh
    from repro.models import Model
    from repro.serving import DistributedEngine, EngineConfig, Request

    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8,
                                     capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_ep_mesh(8)
    planner = AuroraPlanner(homogeneous_cluster(8))
    counts = np.ones((2, 8)); counts[:, 0] = 25.0    # expert 0 runs hot
    skew = trace_from_counts("skew", counts)
    rep_plan = planner.plan_replicated(skew, tolerance=0.05,
                                       total_multiple=8)
    n_phys = sum(len(h) for h in rep_plan.replication)
    assert n_phys % 8 == 0 and n_phys > 8, rep_plan.replication
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab, 8)) for _ in range(3)]

    def serve(adopt_at):
        eng = DistributedEngine(model, params, batch_slots=2, cache_cap=32,
                                mesh=mesh, rounds=None, plan=skew,
                                overlap=True,
                                config=EngineConfig(prefill_len=8))
        for pr in prompts:
            eng.submit(Request(prompt=list(pr), max_new_tokens=6))
        reqs, steps = list(eng.queue), 0
        while eng.step():
            steps += 1
            if steps == adopt_at:
                eng.adopt(rep_plan)
        return eng, [r.out_tokens for r in reqs]

    eng_a, toks_a = serve(adopt_at=None)
    eng_b, toks_b = serve(adopt_at=3)
    assert all(t for t in toks_a), toks_a
    assert toks_a == toks_b, "replication adoption changed emitted tokens"
    spec = eng_b.model.pc.moe_replication
    assert spec is not None and spec.n_phys == n_phys

    # A placement that does not shard over the EP axis is refused.
    bad = planner.plan_replicated(skew, tolerance=0.0, max_total_replicas=1)
    assert sum(len(h) for h in bad.replication) % 8, bad.replication
    try:
        eng_b.adopt(bad)
    except ValueError as e:
        assert "total_multiple=8" in str(e)
    else:
        raise AssertionError("non-divisible replication was adopted")
    print("REPLICATED ADOPT OK", n_phys)
    """)


def test_distributed_colocated_replan_refreshes_rounds_placement_only():
    """The distributed colocated engine closes the full loop on a mesh:
    in-collective counts feed the monitors, the replanner re-pairs from
    live traces, an ADOPTED plan refreshes the ppermute rounds — and the
    refresh is placement-only (identical streams with refresh disabled)."""
    _run("""
    import dataclasses
    import numpy as np
    import jax
    from repro.configs import get_config
    from repro.core import AuroraPlanner, homogeneous_cluster, synthetic_trace
    from repro.launch.mesh import make_ep_mesh
    from repro.models import Model
    from repro.serving import (DistributedColocatedEngine, EngineConfig,
                               OnlineReplanner, Request, apply_pairing)

    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8,
                                     capacity_factor=8.0))
    model_a, model_b = Model(cfg), Model(cfg)
    params_a = model_a.init(jax.random.PRNGKey(0))
    params_b = model_b.init(jax.random.PRNGKey(1))
    planner = AuroraPlanner(homogeneous_cluster(8))
    hist_a = synthetic_trace("ha", n_experts=8, n_layers=2, seed=0)
    hist_b = synthetic_trace("hb", n_experts=8, n_layers=2, seed=1)
    plan0 = planner.plan_colocated(hist_a, hist_b)
    pb = apply_pairing(params_b, list(plan0.pair), cfg)

    rng = np.random.default_rng(0)
    v = cfg.vocab
    streams = [[Request(prompt=list(rng.integers(lo, lo + v // 16, 6)),
                        max_new_tokens=4, arrival=float(i))
                for i in range(4)]
               for lo in (1, v // 2)]

    def serve(refresh):
        rp = OnlineReplanner(planner, interval=3, threshold=-1e9, warmup=1)
        eng = DistributedColocatedEngine(
            model_a, model_b, params_a, pb, batch_slots=2, cache_cap=16,
            mesh=mesh, plan=plan0, overlap=True, refresh_rounds=refresh,
            config=EngineConfig(prefill_len=8), replan=rp,
            monitor_halflife=8.0)
        r0 = eng.rounds
        reqs_a = [Request(prompt=list(r.prompt), max_new_tokens=4,
                          arrival=r.arrival) for r in streams[0]]
        reqs_b = [Request(prompt=list(r.prompt), max_new_tokens=4,
                          arrival=r.arrival) for r in streams[1]]
        eng.serve(reqs_a, reqs_b)
        applied = [e for e in eng.replan_events if e.applied]
        return (eng, r0, applied,
                [r.out_tokens for r in reqs_a],
                [r.out_tokens for r in reqs_b])

    mesh = make_ep_mesh(8)
    eng_r, r0, applied_r, ta_r, tb_r = serve(refresh=True)
    eng_s, _, applied_s, ta_s, tb_s = serve(refresh=False)
    assert len(applied_r) >= 1, "no re-plan applied (threshold=-inf!)"
    assert eng_r.rounds != r0, "adopted re-plan did not refresh the rounds"
    assert eng_s.rounds == r0, "refresh_rounds=False still swapped rounds"
    assert ta_r == ta_s and tb_r == tb_s, \
        "rounds refresh changed emitted tokens (placement-only violated)"
    assert [e.pair for e in applied_r] == [e.pair for e in applied_s], \
        "legs diverged before the refresh could be compared"
    print("COLOCATED REFRESH OK", len(applied_r), "replan(s)")
    """)
