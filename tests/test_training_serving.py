"""Integration tests: training loop (loss decreases), checkpoint round-trip,
serving engine, and the dual-model colocated engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving import ColocatedEngine, Request, ServingEngine
from repro.serving.colocated import apply_pairing
from repro.training import (AdamWConfig, SyntheticLMData, restore_checkpoint,
                            save_checkpoint, train_loop)


def test_train_loss_decreases():
    cfg = get_config("phi4-mini-3.8b").reduced()
    model = Model(cfg)
    data = SyntheticLMData(cfg.vocab, seq_len=64, batch=8, seed=0)
    state, hist = train_loop(model, data, steps=60,
                             opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20),
                             log_every=5)
    first = np.mean([h["ce"] for h in hist[:3]])
    last = np.mean([h["ce"] for h in hist[-3:]])
    assert last < first * 0.9, f"loss did not decrease: {first} -> {last}"


def test_moe_train_loss_decreases_with_aux():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    model = Model(cfg)
    data = SyntheticLMData(cfg.vocab, seq_len=32, batch=8, seed=1)
    state, hist = train_loop(model, data, steps=40,
                             opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=10),
                             log_every=5)
    assert hist[-1]["ce"] < hist[0]["ce"], hist
    assert all(np.isfinite(h["aux"]) for h in hist)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("gemma-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path / "ck"), params, step=7)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = restore_checkpoint(str(tmp_path / "ck"), zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_engine_generates():
    cfg = get_config("qwen3-32b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=4, cache_cap=64)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5),
            Request(prompt=[4, 5], max_new_tokens=5),
            Request(prompt=[7], max_new_tokens=3),
            Request(prompt=[8, 9, 10, 11], max_new_tokens=5)]
    out = eng.serve(reqs)
    assert len(out[0].out_tokens) == 5
    assert len(out[2].out_tokens) == 3
    for r in out:
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_serving_decode_matches_forward():
    """Greedy decode through the cache must equal teacher-forced forward."""
    cfg = get_config("gemma3-27b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.array([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    cache = model.init_cache(1, 32)
    logits_p, cache = model.prefill(params, {"tokens": prompt}, cache)

    from repro.models.transformer import forward
    logits_f, _, _, _ = forward(params, cfg, tokens=prompt, mode="train")
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_f),
                               rtol=2e-3, atol=2e-3)

    # Decode one token and check against a re-run of the extended sequence.
    tok = jnp.argmax(logits_p[:, -1:, : cfg.vocab], -1).astype(jnp.int32)
    logits_d, cache = model.decode_step(params, tok, cache)
    ext = jnp.concatenate([prompt, tok], axis=1)
    logits_e, _, _, _ = forward(params, cfg, tokens=ext, mode="train")
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_e[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_colocated_engine_matches_separate():
    """The dual-model engine must produce exactly the tokens each model
    would produce alone (colocation changes scheduling, not math)."""
    cfg_a = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg_b = get_config("phi4-mini-3.8b").reduced()
    ma, mb = Model(cfg_a), Model(cfg_b)
    pa = ma.init(jax.random.PRNGKey(0))
    pb = mb.init(jax.random.PRNGKey(1))
    prompts_a = jnp.array([[1, 2, 3, 4]], jnp.int32)
    prompts_b = jnp.array([[5, 6, 7, 8]], jnp.int32)

    eng = ColocatedEngine(ma, mb, pa, pb)
    out_a, out_b = eng.serve(prompts_a, prompts_b, max_new_tokens=4,
                             cache_cap=16)

    # Solo reference for model a.
    ca = ma.init_cache(1, 16)
    la, ca = ma.prefill(pa, {"tokens": prompts_a}, ca)
    toks = [jnp.argmax(la[:, -1:, : cfg_a.vocab], -1).astype(jnp.int32)]
    for _ in range(3):
        la, ca = ma.decode_step(pa, toks[-1], ca)
        toks.append(jnp.argmax(la[:, :, : cfg_a.vocab], -1).astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(out_a),
                                  np.asarray(jnp.concatenate(toks, 1)))


def test_apply_pairing_permutes_experts():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    e = cfg.moe.n_experts
    pair = list(reversed(range(e)))
    permuted = apply_pairing(params, pair, cfg)

    def experts_leaf(p):
        for si, seg in enumerate(p["segments"]):
            for pos in seg:
                if "moe" in pos:
                    return pos["moe"]["experts"]["w_gate"]
        raise AssertionError("no moe layer found")

    w0 = np.asarray(experts_leaf(params))
    w1 = np.asarray(experts_leaf(permuted))
    np.testing.assert_array_equal(w1[:, 0], w0[:, e - 1])
