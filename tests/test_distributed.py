"""Distributed-correctness tests, run in subprocesses with 8 host devices.

The main pytest process must keep seeing ONE device (smoke tests/benches),
so anything needing a mesh runs via ``python -c`` with XLA_FLAGS set in the
child environment only.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_ep_dispatch_matches_dense():
    """EP shard_map all_to_all dispatch ≡ the dense reference dispatch."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.compat import set_mesh, shard_map
    from repro.models.moe import moe_apply_dense, moe_apply_ep, init_moe
    from repro.models.layers import ParallelContext
    from repro.configs.base import MoEConfig

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    moe = MoEConfig(n_experts=8, top_k=2, d_ff=64, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 32, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    pc = ParallelContext(mesh=mesh, data_axes=("data",), model_axis="model",
                         ep_axes=("data", "model"),
                         token_axes=("data", "model"), moe_impl="ep")
    y_dense, aux_d = moe_apply_dense(p, x, moe, "swiglu")
    with set_mesh(mesh):
        y_ep, aux_e = moe_apply_ep(p, x, moe, "swiglu", pc)
    # capacity_factor is large enough that no tokens drop in either path;
    # EP capacity is per-source-device so bucket POSITIONS differ, but the
    # combined output must match.
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    # Aux loss: EP computes the GShard per-group (per-device) estimator
    # E_group[f·P], dense the global one — bilinear, so they differ by
    # sampling noise. Both must be finite and of the same magnitude.
    assert np.isfinite(float(aux_e)) and np.isfinite(float(aux_d))
    assert 0.5 < float(aux_e) / float(aux_d) < 2.0, (aux_e, aux_d)
    print("EP OK")
    """)


def test_aurora_rounds_match_all_to_all():
    """The scheduled ppermute exchange ≡ monolithic lax.all_to_all."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import set_mesh, shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.alltoall import ep_all_to_all, round_robin_rounds

    mesh = jax.make_mesh((8,), ("ep",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8 * 8, 4, 16))

    def f(rounds):
        return shard_map(
            lambda b: ep_all_to_all(b, ("ep",), rounds),
            mesh=mesh, in_specs=P("ep"), out_specs=P("ep"),
            check_vma=False)(x)

    base = f(None)
    sched = f(round_robin_rounds(8))
    np.testing.assert_allclose(np.asarray(sched), np.asarray(base))
    print("ROUNDS OK")
    """)


def test_aurora_schedule_rounds_cover_all_pairs():
    """BvN-derived rounds (from a real schedule) also reproduce all_to_all."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import set_mesh, shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import synthetic_trace, aurora_schedule
    from repro.distributed.alltoall import (ep_all_to_all,
                                            aurora_rounds_from_schedule)

    n = 8
    trace = synthetic_trace("t", n_experts=n, n_layers=1, seed=3)
    sched = aurora_schedule(trace.layer(0))
    rounds = aurora_rounds_from_schedule(sched, n)
    # Coverage: every ordered off-diagonal pair appears exactly once.
    seen = np.zeros((n, n), int)
    for dst in rounds:
        for i, j in enumerate(dst):
            if j >= 0:
                seen[i, j] += 1
    off = ~np.eye(n, dtype=bool)
    assert (seen[off] == 1).all(), seen

    mesh = jax.make_mesh((8,), ("ep",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8 * 8, 4, 16))
    def f(rounds):
        return shard_map(
            lambda b: ep_all_to_all(b, ("ep",), rounds),
            mesh=mesh, in_specs=P("ep"), out_specs=P("ep"),
            check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(f(rounds)), np.asarray(f(None)))
    print("BVN ROUNDS OK")
    """)


def test_full_moe_layer_aurora_schedule_matches_dense():
    """End-to-end: a full EP MoE layer running the PLANNED Aurora ppermute
    schedule (BvN rounds from historical traffic) equals the dense
    reference — the schedule changes when bytes move, never what arrives."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import set_mesh, shard_map
    from repro.configs.base import MoEConfig
    from repro.core import aurora_schedule, synthetic_trace
    from repro.distributed import aurora_rounds_from_schedule
    from repro.models.layers import ParallelContext
    from repro.models.moe import init_moe, moe_apply_dense, moe_apply_ep

    n = 8
    mesh = jax.make_mesh((n,), ("model",))
    moe = MoEConfig(n_experts=n, top_k=2, d_ff=64, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), 32, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    sched = aurora_schedule(synthetic_trace("h", n_experts=n, n_layers=1,
                                            seed=7).layer(0))
    rounds = aurora_rounds_from_schedule(sched, n)
    pc = ParallelContext(mesh=mesh, data_axes=(), model_axis="model",
                         ep_axes=("model",), token_axes=("model",),
                         moe_impl="aurora", aurora_rounds=rounds)
    y_ref, _ = moe_apply_dense(p, x, moe, "swiglu")
    with set_mesh(mesh):
        y_aur, _ = moe_apply_ep(p, x, moe, "swiglu", pc)
    np.testing.assert_allclose(np.asarray(y_aur), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    print("AURORA LAYER OK")
    """)


def test_moe_smoke_on_mesh_multipod_axes():
    """phi3.5-style reduced MoE model trains a step on a (pod,data,model)
    mesh with EP over model only."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import set_mesh, shard_map
    from repro.configs import get_config
    from repro.models import Model, cross_entropy
    from repro.sharding import make_pc
    import dataclasses

    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    # 4 experts over a model axis of 2 → EP=2, experts_per_device=2.
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    pc = make_pc(cfg, mesh, moe_impl="ep")
    # 4 experts on data×model = 4 → the widest EP axis is chosen; the pod
    # axis must never join it.
    assert pc.ep_axes == ("data", "model"), pc.ep_axes
    assert "pod" not in pc.ep_axes and pc.token_axes[0] == "pod"
    model = Model(cfg, pc)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)

    with set_mesh(mesh):
        def loss_fn(p):
            logits, aux = model.train_logits(p, {"tokens": tokens},
                                             remat=False)
            return cross_entropy(logits, tokens, cfg.vocab) + 0.01 * aux
        loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()
    print("MESH MOE OK", float(loss))
    """)
