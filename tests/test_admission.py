"""EngineConfig / admission API: pooled concurrent prefill identity,
legacy-kwarg shims, admission policies, and live tenant churn.

The anchor invariants: the prefill pool and tenant admission/eviction are
SCHEDULE and MEMBERSHIP changes — byte-identical token streams for every
request (pool) and every surviving tenant (churn)."""

import dataclasses
import math

import jax
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis if installed

from repro.configs import get_config
from repro.models import Model
from repro.serving import (ColocatedContinuousEngine, ContinuousEngine,
                           EdfAdmission, EngineConfig, FifoAdmission,
                           LengthBucketedAdmission,
                           MultiTenantContinuousEngine, Request, RequestSpec,
                           TenantSpec, TokenBudgetAdmission, apply_pairing,
                           reseat_pairing)


def _model(arch="qwen3-32b", seed=0):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(n=6, seed=0, plen=12, max_new=4, vocab=500):
    rng = np.random.default_rng(seed)
    # Bursty arrivals: several multi-chunk prompts in flight at once, the
    # regime where pooled admission actually diverges from serialized
    # admission in schedule.
    arrivals = [0.0, 0.0, 1.0, 1.0, 2.0, 5.0, 6.0, 8.0]
    return [Request(prompt=list(rng.integers(1, vocab, plen)),
                    max_new_tokens=max_new, arrival=arrivals[i % 8])
            for i in range(n)]


# -- EngineConfig validation ------------------------------------------------

def test_engine_config_validation():
    with pytest.raises(ValueError, match="admission"):
        EngineConfig(admission=FifoAdmission(), prefill_chunk=2)
    with pytest.raises(ValueError, match="admission"):
        EngineConfig(admission=FifoAdmission(), bucket_policy="exact")
    with pytest.raises(ValueError, match="chunk"):
        EngineConfig(prefill_chunk=0)
    with pytest.raises(ValueError, match="budget"):
        EngineConfig(step_token_budget=5)          # budget needs chunking
    with pytest.raises(ValueError, match="pool"):
        EngineConfig(prefill_pool=0)
    with pytest.raises(ValueError, match="pool"):
        EngineConfig(prefill_pool=2)               # pool needs chunking
    with pytest.raises(ValueError, match="chunk"):
        LengthBucketedAdmission(chunk=0)


def test_engine_config_resolves_admission():
    assert isinstance(EngineConfig().resolve_admission(), FifoAdmission)
    a = EngineConfig(prefill_chunk=4).resolve_admission()
    assert isinstance(a, LengthBucketedAdmission) and a.chunk == 4
    b = EngineConfig(prefill_chunk=4,
                     step_token_budget=9).resolve_admission()
    assert isinstance(b, TokenBudgetAdmission) and b.budget == 9
    custom = TokenBudgetAdmission(chunk=2, budget=6, bucket_policy="exact")
    assert EngineConfig(admission=custom).resolve_admission() is custom


def _specs(*chunks):
    return [RequestSpec(chunk=c) for c in chunks]


def test_admission_policy_budgets():
    fifo = FifoAdmission()
    assert fifo.chunk is None and fifo.budget is None
    assert fifo.select(3, _specs(1, 2)) == (0, 1)  # no budget: admit all
    tb = TokenBudgetAdmission(chunk=4, budget=9)
    # 2 active decode rows leave 7 tokens: one 4-chunk + one 3-chunk fit,
    # the next 4-chunk does not (greedy FIFO prefix, no reordering).
    assert tb.select(2, _specs(4, 3, 4)) == (0, 1)
    # An idle engine bypasses the budget — nothing is decoding, so there
    # is nothing to protect (the progress guarantee).
    assert tb.select(0, _specs(99)) == (0,)


def test_chunk_budget_deprecation_shim():
    """The old int-based signature answers through the shim — one
    DeprecationWarning, same prefix counts as before the redesign — both
    on the stock policies and for legacy policies wrapped into select."""
    tb = TokenBudgetAdmission(chunk=4, budget=9)
    with pytest.warns(DeprecationWarning, match="select"):
        assert tb.chunk_budget(2, [4, 3, 4]) == 2
    with pytest.warns(DeprecationWarning, match="select"):
        assert FifoAdmission().chunk_budget(3, [1, 2]) == 2

    class OldPolicy:                      # pre-select third-party policy
        chunk, budget = 4, 9
        bucket_policy = "pow2"

        def pad(self, n):
            return n

        def chunk_budget(self, num_active, chunks):
            return 1 if chunks else 0

    with pytest.warns(DeprecationWarning, match="chunk_budget") as rec:
        shim = EngineConfig(admission=OldPolicy()).resolve_admission()
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    assert shim.select(2, _specs(4, 4)) == (0,)
    assert shim.order(_specs(4, 4)) == (0, 1)
    assert shim.chunk == 4 and shim.budget == 9
    with pytest.raises(TypeError, match="admission"):
        EngineConfig(admission=object()).resolve_admission()


def test_edf_admission_policy():
    """EDF ranks by effective deadline min(deadline, arrival + age_limit)
    and admits work-conservingly under the budget."""
    edf = EdfAdmission(chunk=4, budget=9)
    specs = [RequestSpec(4, arrival=0.0, deadline=50.0),
             RequestSpec(3, arrival=1.0, deadline=5.0),
             RequestSpec(4, arrival=2.0, deadline=10.0)]
    # Deadline order is (1, 2, 0); 2 decode rows leave 7 tokens: the
    # 3-chunk and one 4-chunk fit, the last 4-chunk is SKIPPED, not
    # blocking (work conservation).
    assert edf.select(2, specs) == (1, 2)
    assert edf.order(specs) == (1, 2, 0)
    # Aging: a deadline-free request is treated as due age_limit after
    # arrival, so it cannot starve behind later tight deadlines.
    aged = EdfAdmission(chunk=4, budget=100, age_limit=10.0)
    s = [RequestSpec(4, arrival=0.0),                    # due at 10
         RequestSpec(4, arrival=9.0, deadline=12.0)]
    assert aged.order(s) == (0, 1)
    assert edf.select(0, specs) == (1, 2, 0)   # idle bypass, EDF order
    with pytest.raises(ValueError, match="age_limit"):
        EdfAdmission(chunk=4, age_limit=0.0)


def _edf_cases():
    """(num_active, budget, specs): random deadline streams, some requests
    deadline-free (math.inf exercises the aging path)."""
    def build(seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 9))
        specs = [RequestSpec(
            chunk=int(rng.integers(1, 7)),
            arrival=float(rng.uniform(0, 32)),
            deadline=(math.inf if rng.random() < 0.3
                      else float(rng.uniform(0, 64))))
            for _ in range(n)]
        return int(rng.integers(1, 7)), int(rng.integers(1, 13)), specs
    return st.integers(0, 10_000).map(build)


@settings(max_examples=80, deadline=None)
@given(_edf_cases())
def test_edf_select_work_conserving_property(case):
    """For EVERY deadline stream: the selection is a subsequence of the
    effective-deadline ranking, spends within the budget, and is
    work-conserving — no skipped chunk would still fit the leftover."""
    num_active, budget, specs = case
    edf = EdfAdmission(chunk=4, budget=budget, age_limit=16.0)
    sel = edf.select(num_active, specs)
    assert len(set(sel)) == len(sel)
    ranked = edf.order(specs)
    assert tuple(i for i in ranked if i in set(sel)) == sel, \
        "selection must keep effective-deadline order"
    if num_active == 0:
        assert sel == ranked                    # idle bypass: admit all
        return
    spent = sum(specs[i].chunk for i in sel)
    assert num_active + spent <= max(budget, num_active)
    leftover = budget - num_active - spent
    for i in set(range(len(specs))) - set(sel):
        assert specs[i].chunk > leftover, \
            f"req {i} fits the leftover budget but was not admitted"


def test_edf_reordering_is_placement_only():
    """Single tenant, uniform SLO: every effective deadline is
    arrival + const, so EDF degenerates to FIFO — byte-identical tokens
    AND identical schedule to the FIFO token-budget policy."""
    cfg, model, params = _model()
    spec = TenantSpec(name="t", ttft_p95=20.0, tpot_p95=4.0)
    fifo = ContinuousEngine(
        model, params, 3, 32,
        config=EngineConfig(admission=TokenBudgetAdmission(chunk=4,
                                                           budget=9)))
    ref = fifo.serve(_requests(vocab=cfg.vocab))
    edf = ContinuousEngine(
        model, params, 3, 32,
        config=EngineConfig(admission=EdfAdmission(chunk=4, budget=9),
                            tenants=(spec,)))
    out = edf.serve(_requests(vocab=cfg.vocab))
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in out]
    assert all(r.deadline == r.arrival + 20.0 for r in out), \
        "TenantSpec SLO must stamp each request's deadline"


# -- legacy-kwarg shims -----------------------------------------------------

def test_legacy_kwargs_warn_and_roundtrip():
    cfg, model, params = _model()
    with pytest.warns(DeprecationWarning, match="ContinuousEngine"):
        eng = ContinuousEngine(model, params, 2, 32, prefill_chunk=4,
                               step_token_budget=9)
    assert eng.config == EngineConfig(prefill_chunk=4, step_token_budget=9)
    assert eng.prefill_chunk == 4 and eng.step_token_budget == 9
    with pytest.raises(ValueError, match="both"):
        ContinuousEngine(model, params, 2, 32,
                         config=EngineConfig(prefill_len=4), prefill_len=4)
    with pytest.raises(TypeError, match="prefil_chunk"):
        ContinuousEngine(model, params, 2, 32, prefil_chunk=4)


def test_legacy_kwargs_warn_once_per_engine():
    cfg, ma, pa = _model("phi3.5-moe-42b-a6.6b", seed=0)
    _, mb, pb = _model("phi3.5-moe-42b-a6.6b", seed=1)
    with pytest.warns(DeprecationWarning) as rec:
        ColocatedContinuousEngine(ma, mb, pa, pb, 2, 16, prefill_len=6)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    with pytest.warns(DeprecationWarning) as rec:
        MultiTenantContinuousEngine([ma, mb], [pa, pb], 2, 16,
                                    prefill_len=6)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1


# -- pooled concurrent prefill ----------------------------------------------

@pytest.mark.parametrize("budget", [None, 9])
def test_pooled_prefill_token_identity(budget):
    """K=4 concurrent chunked prefills emit exactly the tokens of
    serialized admission on the same bursty stream — with and without a
    step token budget throttling the pool."""
    cfg, model, params = _model()
    serial = ContinuousEngine(
        model, params, 3, 32,
        config=EngineConfig(prefill_chunk=4, step_token_budget=budget))
    ref = serial.serve(_requests(vocab=cfg.vocab))
    pooled = ContinuousEngine(
        model, params, 3, 32,
        config=EngineConfig(prefill_chunk=4, step_token_budget=budget,
                            prefill_pool=4))
    out = pooled.serve(_requests(vocab=cfg.vocab))
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in out]
    for r in out:
        assert len(r.out_tokens) == r.max_new_tokens


def test_pooled_prefill_ssm_state():
    """The pool's fused chunk sub-calls thread one donated cache through K
    prompts — recurrent (conv/SSD) state must continue per-slot exactly as
    the serialized path's."""
    cfg, model, params = _model("mamba2-1.3b")
    mk = lambda: _requests(4, seed=2, plen=8, vocab=cfg.vocab)
    ref = ContinuousEngine(
        model, params, 2, 32,
        config=EngineConfig(prefill_chunk=4)).serve(mk())
    out = ContinuousEngine(
        model, params, 2, 32,
        config=EngineConfig(prefill_chunk=4, prefill_pool=3)).serve(mk())
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in out]


# -- live tenant churn ------------------------------------------------------

def _moe_models(n, arch="phi3.5-moe-42b-a6.6b"):
    cfg = get_config(arch).reduced()
    models = [Model(cfg) for _ in range(n)]
    params = [m.init(jax.random.PRNGKey(t)) for t, m in enumerate(models)]
    return cfg, models, params


def _streams(n, seed0=1, plen=6, max_new=3, vocab=500):
    return [_requests(2, seed=seed0 + t, plen=plen, max_new=max_new,
                      vocab=vocab) for t in range(n)]


def test_tenant_join_leave_placement_only():
    """A join + serve + leave cycle is invisible to the incumbent tenants:
    their token streams are byte-identical to a churn-free run, and the
    joiner's own tokens do not depend on its expert pairing (placement
    only)."""
    cfg, models, params = _moe_models(2)
    joiner = Model(cfg)
    jp = joiner.init(jax.random.PRNGKey(9))
    n_e = cfg.moe.n_experts

    ref = MultiTenantContinuousEngine(models, params, 2, 32)
    ref_a = ref.serve(_streams(2, seed0=1))
    ref_b = ref.serve(_streams(2, seed0=5))

    out_by_pair = {}
    for pair in (list(range(n_e)), list(reversed(range(n_e)))):
        eng = MultiTenantContinuousEngine(models, params, 2, 32)
        got_a = eng.serve(_streams(2, seed0=1))
        t_new = eng.admit_tenant(joiner, jp, pair=pair)
        assert t_new == 2 and eng.n_tenants == 3
        assert all(len(g) == 3 for g in eng.groups)
        late = _streams(1, seed0=9)[0]
        got_b = eng.serve([*_streams(2, seed0=5), late])
        detached = eng.evict_tenant(t_new)
        assert eng.n_tenants == 2
        assert all(len(g) == 2 for g in eng.groups)
        assert detached.num_active == 0
        for got, want in ((got_a, ref_a), (got_b, ref_b)):
            for t in range(2):
                assert ([r.out_tokens for r in got[t]]
                        == [r.out_tokens for r in want[t]]), f"tenant {t}"
        out_by_pair[tuple(pair)] = [r.out_tokens for r in late]
    a, b = out_by_pair.values()
    assert a == b, "joiner's pairing changed its tokens"


def test_tenant_churn_validates():
    cfg, models, params = _moe_models(2)
    eng = MultiTenantContinuousEngine(models, params, 2, 32)
    with pytest.raises(ValueError, match="permutation"):
        eng.admit_tenant(models[0], params[0], pair=[0, 0, 1, 2])
    t = eng.admit_tenant(models[0], params[0])
    eng.evict_tenant(t)
    eng.evict_tenant(1)
    with pytest.raises(ValueError, match="last"):
        eng.evict_tenant(0)
    with pytest.raises(ValueError, match="tenant"):
        eng.evict_tenant(5)


def test_reseat_pairing_validates_and_roundtrips():
    cfg, models, params = _moe_models(1)
    n_e = cfg.moe.n_experts
    ident = list(range(n_e))
    rev = list(reversed(ident))
    with pytest.raises(ValueError, match="permutation"):
        reseat_pairing(params[0], ident, [0] * n_e, cfg)
    with pytest.raises(ValueError, match="permutation"):
        reseat_pairing(params[0], [0] * n_e, ident, cfg)
    # no-op when unchanged, exact inverse composition otherwise
    assert reseat_pairing(params[0], rev, rev, cfg) is params[0]
    there = reseat_pairing(params[0], ident, rev, cfg)
    back = reseat_pairing(there, rev, ident, cfg)
    for x, y in zip(jax.tree.leaves(params[0]), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(there)[0]),
        np.asarray(jax.tree.leaves(apply_pairing(params[0], rev, cfg))[0]))


def test_adopt_dispatches_plans():
    """The unified ``adopt`` entry point routes a bare replication map
    through ``adopt_replication`` without changing tokens."""
    cfg, models, params = _moe_models(1)
    mk = lambda: _requests(3, seed=3, plen=6, vocab=cfg.vocab)
    ref = ContinuousEngine(models[0], params[0], 2, 32).serve(mk())
    eng = ContinuousEngine(models[0], params[0], 2, 32)
    for r in mk():
        eng.submit(r)
    reqs, step = list(eng.queue), 0
    ident = [[e] for e in range(cfg.moe.n_experts)]
    while eng.step():
        step += 1
        if step == 2:
            eng.adopt(ident)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]
