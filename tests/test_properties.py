"""Property-based tests (hypothesis) on the system's invariants.

Core invariants from the paper's theorems:
  - Thm 4.2: the BvN schedule is contention-free, covers all real traffic,
    and its total duration equals b_max exactly (the proven lower bound).
  - Thm 5.2: same, with per-device bandwidths.
  - No schedule (any policy) can beat b_max in the fluid model.
  - Thm 6.2 / bottleneck matching: Aurora's pairing minimizes the aggregated
    b_max over all pairings (checked exhaustively for small n).
  - Dispatch invariants: capacity bucketing never duplicates a slot; the
    dense MoE combine is a convex combination (gates sum to 1).
"""

import itertools

import numpy as np
from _propcheck import given, settings, st  # hypothesis if installed

from repro.core import (aurora_pairing, aggregate_traffic, aurora_schedule,
                        b_max_homogeneous, fluid_comm_time, rcs_order,
                        sjf_order)
from repro.core.schedule import b_max_of
from repro.core.traffic import strip_diagonal


def traffic_matrices(max_n=6, max_val=50.0):
    return st.integers(2, max_n).flatmap(
        lambda n: st.lists(
            st.lists(st.floats(0, max_val, allow_nan=False), min_size=n,
                     max_size=n),
            min_size=n, max_size=n).map(np.asarray))


@settings(max_examples=60, deadline=None)
@given(traffic_matrices())
def test_schedule_contention_free_and_exact(d):
    d = strip_diagonal(d)
    sched = aurora_schedule(d)
    # 1. Every slot is a partial permutation: receivers unique.
    for slot in sched.slots:
        dsts = [j for j in slot.dst if j >= 0]
        assert len(dsts) == len(set(dsts)), "receiver contention in slot"
    # 2. Coverage: per-pair scheduled time == traffic exactly.
    covered = np.zeros_like(d)
    for slot in sched.slots:
        for i, j in enumerate(slot.dst):
            if j >= 0:
                covered[i, j] += slot.duration
    assert (covered >= d - 1e-5).all(), "real traffic not fully scheduled"
    # 3. Total duration == b_max (optimal, Thm 4.2). The scheduler cleans
    # entries below 1e-9·b_max (they break Hall's condition numerically),
    # so equality holds to a relative tolerance.
    assert abs(sched.total_time - sched.b_max) < 1e-6 + 1e-6 * sched.b_max
    assert abs(sched.b_max - b_max_homogeneous(d)) < \
        1e-6 + 1e-6 * sched.b_max


@settings(max_examples=30, deadline=None)
@given(traffic_matrices(max_n=5),
       st.lists(st.floats(0.5, 4.0), min_size=5, max_size=5))
def test_heterogeneous_schedule_matches_thm52(d, bws):
    d = strip_diagonal(d)
    n = d.shape[0]
    bw = np.asarray(bws[:n])
    sched = aurora_schedule(d, bw)
    assert abs(sched.total_time - sched.b_max) < 1e-6
    assert sched.b_max <= b_max_of(d, bw) + 1e-6


@settings(max_examples=25, deadline=None)
@given(traffic_matrices(max_n=5), st.integers(0, 3))
def test_no_policy_beats_bmax(d, seed):
    """b_max is a true lower bound: SJF/RCS under the fluid model can never
    finish faster (Thm 4.2's optimality)."""
    d = strip_diagonal(d)
    if d.sum() < 1e-9:
        return
    lb = b_max_homogeneous(d)
    for order in (sjf_order(d), rcs_order(d, seed=seed)):
        t = fluid_comm_time(order, 1.0, d.shape[0])
        assert t >= lb - 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 5))
def test_aurora_pairing_minimizes_aggregated_bmax(n, seed):
    """Thm 6.2 / bottleneck matching optimality, checked exhaustively."""
    rng = np.random.default_rng(seed)
    da = strip_diagonal(rng.random((n, n)) * 10)
    db = strip_diagonal(rng.random((n, n)) * 10)
    pair = aurora_pairing(da, db)
    got = b_max_homogeneous(aggregate_traffic(da, db, pair))
    best = min(
        b_max_homogeneous(aggregate_traffic(da, db, list(p)))
        for p in itertools.permutations(range(n)))
    assert got <= best + 1e-6, (got, best)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 32), st.integers(1, 4), st.integers(2, 16),
       st.integers(0, 7))
def test_capacity_dispatch_no_slot_collisions(t, k, e, seed):
    """Two kept assignments never land in the same (expert, slot) bucket."""
    import jax
    from repro.models.moe import capacity, dispatch_indices

    k = min(k, e)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, e, size=(t, k)).astype(np.int32)
    cap = capacity(t, k, e, 1.25)
    slot, keep = dispatch_indices(jax.numpy.asarray(idx), e, cap)
    slot, keep = np.asarray(slot), np.asarray(keep)
    seen = set()
    for ti in range(t):
        for ki in range(k):
            if keep[ti, ki]:
                key = (int(idx[ti, ki]), int(slot[ti, ki]))
                assert key not in seen
                assert slot[ti, ki] < cap
                seen.add(key)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 3))
def test_router_gates_normalized(seed):
    import jax
    from repro.configs.base import MoEConfig
    from repro.models.moe import route

    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (16, 32))
    for router in ("softmax", "sigmoid"):
        moe = MoEConfig(n_experts=8, top_k=2, d_ff=16, router=router)
        w = jax.random.normal(jax.random.PRNGKey(seed + 1), (32, 8))
        gates, idx, aux = route(w, x, moe)
        np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-3)
        assert (np.asarray(idx) < 8).all()
        assert np.isfinite(float(aux))
