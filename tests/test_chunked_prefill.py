"""Chunked prefill: token identity with one-shot admission (GQA + SSM),
budget scheduling, bucket policies, and unsupported-arch gating."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import (ContinuousEngine, EngineConfig, Request,
                           make_bucketer)


def _model(arch, seed=0, cfg_tweak=None):
    cfg = get_config(arch).reduced()
    if cfg_tweak is not None:
        cfg = cfg_tweak(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests():
    # Mixed lengths, including a 16-token prompt that spans several chunks,
    # with staggered arrivals so chunks interleave with live decode.
    return [Request(prompt=[1, 2, 3, 4, 5, 6, 7], max_new_tokens=5,
                    arrival=0.0),
            Request(prompt=[9, 8, 7], max_new_tokens=4, arrival=1.0),
            Request(prompt=list(range(1, 17)), max_new_tokens=6,
                    arrival=2.0),
            Request(prompt=[5, 5, 5, 5, 5], max_new_tokens=3, arrival=9.0)]


@pytest.mark.parametrize("arch", ["qwen3-32b", "mamba2-1.3b"])
def test_chunked_prefill_token_identity(arch):
    """Absorbing prompts chunk-by-chunk must emit exactly the tokens of
    one-shot ``prefill_slot`` admission — chunked prefill changes the
    schedule, never the math. qwen3 exercises the global GQA cache
    continuation, mamba2 the SSM conv/SSD state continuation."""
    cfg, model, params = _model(arch)
    ref = ContinuousEngine(model, params, 2, 48).serve(_requests())
    for chunk in (2, 4):
        out = ContinuousEngine(
            model, params, 2, 48,
            config=EngineConfig(prefill_chunk=chunk)).serve(_requests())
        assert [r.out_tokens for r in ref] == [r.out_tokens for r in out]


def test_model_level_chunk_matches_one_shot():
    """Direct API check: chunked continuation over one batch-1 cache equals
    one-shot prefill bit-for-bit-close (logits and cache)."""
    cfg, model, params = _model("qwen3-32b")
    prompt = np.random.default_rng(0).integers(
        1, cfg.vocab, (1, 8)).astype(np.int32)
    one = model.init_cache(1, 32)
    l_one, one = model.prefill(params, {"tokens": jnp.asarray(prompt)}, one)
    chd = model.init_cache(1, 32)
    for sl in (slice(0, 4), slice(4, 6), slice(6, 8)):
        l_chd, chd = model.prefill(params,
                                   {"tokens": jnp.asarray(prompt[:, sl])},
                                   chd, continuation=True)
    np.testing.assert_allclose(np.asarray(l_one[0, -1]),
                               np.asarray(l_chd[0, -1]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(chd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_step_token_budget_preserves_tokens():
    """A tight per-step budget delays chunks behind decode but never changes
    emitted tokens, and every request still completes."""
    cfg, model, params = _model("qwen3-32b")
    ref = ContinuousEngine(model, params, 2, 48).serve(_requests())
    out = ContinuousEngine(
        model, params, 2, 48,
        config=EngineConfig(prefill_chunk=4,
                            step_token_budget=5)).serve(_requests())
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in out]
    for r in out:
        assert len(r.out_tokens) == r.max_new_tokens


def test_bucket_policies():
    pow2 = make_bucketer("pow2")
    assert [pow2(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    exact = make_bucketer("exact")
    assert [exact(n) for n in (1, 7, 13)] == [1, 7, 13]
    step = make_bucketer("step:4")
    assert [step(n) for n in (1, 4, 5, 9)] == [4, 4, 8, 12]
    custom = make_bucketer(lambda n: n + 2)
    assert custom(6) == 8
    with pytest.raises(ValueError):
        make_bucketer("fibonacci")
    with pytest.raises(ValueError):
        make_bucketer("step:0")


@pytest.mark.parametrize("policy", ["exact", "step:4"])
def test_engine_bucket_policy_token_counts(policy):
    """Alternative pad policies still complete every request correctly
    (pad length changes WHICH tokens greedy decoding picks — left-pad is
    part of the model input — so we check counts/ranges, not identity)."""
    cfg, model, params = _model("qwen3-32b")
    out = ContinuousEngine(
        model, params, 2, 48,
        config=EngineConfig(bucket_policy=policy,
                            prefill_chunk=2)).serve(_requests())
    for r in out:
        assert len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_exact_bucket_matches_exact_prefill_len():
    """bucket_policy='exact' on uniform-length prompts is the same schedule
    as prefill_len=<that length> — outputs must be identical."""
    cfg, model, params = _model("qwen3-32b")
    mk = lambda: [Request(prompt=[i + 1, i + 2, i + 3, i + 4],
                          max_new_tokens=4, arrival=float(i))
                  for i in range(3)]
    a = ContinuousEngine(model, params, 2, 32,
                         config=EngineConfig(prefill_len=4)).serve(mk())
    b = ContinuousEngine(model, params, 2, 32,
                         config=EngineConfig(bucket_policy="exact")).serve(
                             mk())
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]


def test_vector_len_continuation_matches_per_row():
    """Regression: prefill continuation over a PER-SLOT (vector-length)
    cache — each batch row resumes at its own offset — must equal running
    each row's one-shot prefill separately. This used to raise
    NotImplementedError, forcing the scalar-cache + merge detour."""
    cfg, model, params = _model("qwen3-32b")
    rng = np.random.default_rng(3)
    pre = [rng.integers(1, cfg.vocab, n).astype(np.int32) for n in (4, 6)]
    tail = rng.integers(1, cfg.vocab, (2, 3)).astype(np.int32)

    cache = model.init_cache(2, 32, per_slot_len=True)
    for i, p in enumerate(pre):
        _, cache = jax.jit(model.prefill_slot, static_argnames=("cap",))(
            params, {"tokens": jnp.asarray(p[None])}, cache, jnp.int32(i),
            cap=32)
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(tail)},
                                  cache, continuation=True)
    assert np.asarray(cache["len"]).tolist() == [7, 9]

    for i, p in enumerate(pre):
        one = model.init_cache(1, 32)
        full = np.concatenate([p, tail[i]])[None]
        l_one, one = model.prefill(params, {"tokens": jnp.asarray(full)},
                                   one)
        np.testing.assert_allclose(
            np.asarray(logits[i]), np.asarray(l_one[0, len(p):]),
            rtol=1e-5, atol=1e-5)
        for a, b in zip(jax.tree.leaves(one["segments"]),
                        jax.tree.leaves(cache["segments"])):
            np.testing.assert_allclose(
                np.asarray(a[:, 0]), np.asarray(b[:, i]),
                rtol=1e-4, atol=1e-5)


def test_window_fit_prompt_chunks_despite_pow2_pad():
    """Regression: a prompt that FITS the sliding-window ring must be
    chunkable even when the pow2 pad would overshoot the ring (10 tokens →
    pad 16 > ring 12). The engine clamps the pad to the ring; only
    genuinely wrapping prompts are refused."""
    cfg, model, params = _model(
        "gemma3-27b",
        cfg_tweak=lambda c: dataclasses.replace(c, sliding_window=12))
    mk = lambda: [Request(prompt=list(range(1, 11)), max_new_tokens=4)]
    out = ContinuousEngine(model, params, 1, 64,
                           config=EngineConfig(prefill_chunk=4)).serve(mk())
    # Reference: one-shot admission padded to the SAME (clamped) length.
    ref = ContinuousEngine(model, params, 1, 64,
                           config=EngineConfig(prefill_len=12)).serve(mk())
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in out]
    # A prompt that genuinely wraps the 12-ring is still refused loudly.
    eng = ContinuousEngine(model, params, 1, 64,
                           config=EngineConfig(prefill_chunk=4))
    with pytest.raises(ValueError, match="chunk"):
        eng.submit(Request(prompt=list(range(1, 15)), max_new_tokens=2))


def test_chunked_rejects_unsupported_shapes():
    """MLA prefill writes its latent cache at offset 0 only, and a
    sliding-window ring that wraps mid-prompt loses slot identity — both
    must be refused loudly at submit time, not silently miscomputed."""
    cfg, model, params = _model("deepseek-v3-671b")
    eng = ContinuousEngine(model, params, 1, 32,
                           config=EngineConfig(prefill_chunk=2))
    with pytest.raises(ValueError, match="chunk"):
        eng.submit(Request(prompt=[1, 2, 3, 4], max_new_tokens=2))

    cfg_g, model_g, params_g = _model("gemma3-27b")   # window reduced to 16
    eng_g = ContinuousEngine(model_g, params_g, 1, 64,
                             config=EngineConfig(prefill_chunk=4))
    with pytest.raises(ValueError, match="chunk"):
        eng_g.submit(Request(prompt=list(range(1, 21)), max_new_tokens=2))
    # ... but prompts inside the window are fine.
    out = ContinuousEngine(model_g, params_g, 1, 64,
                           config=EngineConfig(prefill_chunk=4)).serve(
        [Request(prompt=[1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=3)])
    assert len(out[0].out_tokens) == 3
