"""Thm 5.1 assignment, Thm 6.2 colocation, and the §7.2 decoupled solution."""

import itertools

import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis if installed

from repro.core import (AuroraPlanner, Cluster, PAPER_HET_TIERS,
                        aurora_assignment, bruteforce_colocated,
                        bruteforce_exclusive, case1_pairing, case2_pairing,
                        colocated_inference_time, exclusive_inference_time,
                        homogeneous_cluster,
                        lina_packing, synthetic_trace)
from repro.core.colocation import aggregate_traffic, send_recv_vectors


def small_trace(n, seed, tokens=1024.0, skew=0.5):
    return synthetic_trace(f"t{seed}", n_experts=n, n_layers=1,
                           tokens_per_device=tokens, skew=skew,
                           ffn_per_token=0.002, ffn_fixed=2.0, seed=seed)


def small_het_cluster(n):
    return Cluster(devices=tuple(PAPER_HET_TIERS[i % 4] for i in range(n)))


# ---------------------------------------------------------------------------
# Thm 5.1: sorted assignment is optimal (vs exhaustive search)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(3, 5), st.integers(0, 10_000))
def test_thm51_assignment_near_optimal(n, seed):
    """Thm 5.1's swap argument assumes a single scalar load per expert.

    With asymmetric send/recv loads the sorted assignment is a (very good)
    heuristic — measured <= 1.11x over random instances (EXPERIMENTS.md
    §Validation); we bound it at 1.20x here.
    """
    trace = small_trace(n, seed)
    cl = small_het_cluster(n)
    e2d = aurora_assignment(trace.layer(0), cl)
    t_aurora = exclusive_inference_time(trace, 0, cl, e2d).inference_time
    t_opt, _ = bruteforce_exclusive(trace, 0, cl)
    assert t_aurora <= t_opt * 1.20 + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 5), st.integers(0, 10_000))
def test_thm51_assignment_optimal_for_symmetric_loads(n, seed):
    """When send == recv per expert (the theorem's implicit regime), the
    sorted assignment minimizes every max-term simultaneously — EXCEPT
    that our comm model follows Appendix B, where flow (i, j) moves at
    min(B_i, B_j): a heavy flow between two slow devices is charged at the
    slow rate for BOTH endpoints, and the paper's Thm 5.1 exchange
    argument is no longer exact (hypothesis found ~0.3% counterexamples).
    Under the main-text normalization (row_i/B_i) sorting IS optimal. We
    bound the Appendix-B gap at 1% (reproduction note, EXPERIMENTS.md)."""
    import dataclasses
    trace = small_trace(n, seed)
    sym = dataclasses.replace(
        trace, layers=tuple((d + d.T) / 2 for d in trace.layers))
    cl = small_het_cluster(n)
    e2d = aurora_assignment(sym.layer(0), cl)
    t_aurora = exclusive_inference_time(sym, 0, cl, e2d).inference_time
    t_opt, _ = bruteforce_exclusive(sym, 0, cl)
    assert t_aurora <= t_opt * 1.01 + 1e-6


# ---------------------------------------------------------------------------
# Thm 6.2 Case I: sort-pairing minimizes the max pair sum
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(2, 7), st.integers(0, 10_000))
def test_thm62_case1_minimizes_max_pair_sum(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.random(n) * 100
    b = rng.random(n) * 100
    pair = case1_pairing(a, b)
    got = max(a[i] + b[pair[i]] for i in range(n))
    best = min(
        max(a[i] + b[perm[i]] for i in range(n))
        for perm in itertools.permutations(range(n))
    )
    assert got == pytest.approx(best)


# ---------------------------------------------------------------------------
# §6.2 Case II: bottleneck matching minimizes aggregated b_max
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 10_000))
def test_case2_minimizes_aggregated_bmax(n, seed):
    da = small_trace(n, seed).layer(0)
    db = small_trace(n, seed + 1, tokens=256.0).layer(0)
    pair, val = case2_pairing(da, db)
    # The bottleneck value is exactly the minimized max row/col sum.
    sa, ra = send_recv_vectors(da)
    sb, rb = send_recv_vectors(db)
    best = min(
        max(max(sa[i] + sb[p[i]], ra[i] + rb[p[i]]) for i in range(n))
        for p in itertools.permutations(range(n))
    )
    assert val == pytest.approx(best)
    got_agg = aggregate_traffic(da, db, pair)
    got = max(got_agg.sum(1).max(), got_agg.sum(0).max())
    # Aggregated matrix's b_max equals the matching bottleneck (diagonals of
    # the aggregated matrix are free on-device traffic and are stripped).
    assert got <= val + 1e-9


def test_aggregate_traffic_indexing():
    da = np.array([[0, 1, 2], [3, 0, 4], [5, 6, 0]], float)
    db = np.array([[0, 10, 20], [30, 0, 40], [50, 60, 0]], float)
    pair = [2, 0, 1]  # device0: a0+b2, device1: a1+b0, device2: a2+b1
    agg = aggregate_traffic(da, db, pair)
    # b-traffic b2->b0 goes device0 -> device1
    assert agg[0, 1] == da[0, 1] + db[2, 0]
    assert agg[1, 2] == da[1, 2] + db[0, 1]


def test_lina_packing_merges_and_balances():
    trace = small_trace(8, 3)
    merged, pairs = lina_packing(trace.layer(0))
    assert merged.shape == (4, 4)
    flat = sorted(e for p in pairs for e in p)
    assert flat == list(range(8))
    # popular paired with unpopular: first pair holds the hottest expert
    loads = trace.layer(0).sum(axis=0)
    hottest = int(np.argmax(loads))
    coldest = int(np.argmin(loads))
    assert hottest in pairs[0] and coldest in pairs[0]
    # traffic conserved up to the intra-pair (diagonal) part
    assert merged.sum() <= trace.layer(0).sum() + 1e-9


# ---------------------------------------------------------------------------
# §7.2 decoupled 3D matching: close to brute-force optimum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_colocating_het_near_optimal(seed):
    n = 4
    ta = small_trace(n, seed, tokens=2048.0, skew=0.3)
    tb = small_trace(n, seed + 10, tokens=512.0, skew=0.2)
    cl = small_het_cluster(n)
    plan = AuroraPlanner(cl).plan_colocated(ta, tb)
    t = colocated_inference_time(ta, tb, 0, cl, plan.pair,
                                 plan.expert_to_device).inference_time
    t_opt, _, _ = bruteforce_colocated(ta, tb, 0, cl)
    assert t >= t_opt - 1e-9  # optimum really is a lower bound
    # paper reports 1.07x average; individual instances stay well below 1.5x
    assert t <= t_opt * 1.5


def test_colocating_hom_pairing_is_optimal_for_bmax(seed=0):
    """Thm 6.1 + 6.2: on homogeneous clusters Aurora's pairing minimizes
    inference time among all pairings."""
    n = 5
    ta = small_trace(n, seed, tokens=2048.0, skew=0.6)
    tb = small_trace(n, seed + 10, tokens=512.0, skew=0.4)
    cl = homogeneous_cluster(n)
    plan = AuroraPlanner(cl).plan_colocated(ta, tb)
    t_aurora = colocated_inference_time(ta, tb, 0, cl, plan.pair).inference_time
    t_opt, _, _ = bruteforce_colocated(ta, tb, 0, cl)
    assert t_aurora <= t_opt + 1e-6
