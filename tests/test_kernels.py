"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Kernels execute with ``interpret=True`` (CPU container; TPU is the target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import decode_attn
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels import ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("e,c,d,f", [
    (2, 128, 64, 128),
    (4, 256, 128, 256),
    (1, 128, 256, 384),
    (3, 384, 96, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["swiglu", "geglu"])
def test_moe_gmm_matches_ref(e, c, d, f, dtype, act):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    wg = jax.random.normal(ks[1], (e, d, f), dtype) * d ** -0.5
    wu = jax.random.normal(ks[2], (e, d, f), dtype) * d ** -0.5
    wd = jax.random.normal(ks[3], (e, f, d), dtype) * f ** -0.5
    got = moe_gmm(x, wg, wu, wd, act=act, interpret=True)
    want = ref.moe_ffn_ref(x, wg, wu, wd, act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("sizes", [
    (0, 0),              # every block dead
    (128, 0),            # one full group, one empty
    (37, 200),           # partial blocks (ragged fill levels)
])
def test_moe_gmm_group_sizes_skip_matches_dense(sizes):
    """Ragged groups: with zero-padded buckets, skipping empty expert blocks
    must be invisible — the output equals the dense (no group_sizes) run and
    the masked reference, because pad rows are zero and FFN(0) == 0."""
    e, c, d, f = 2, 256, 64, 128
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    live = jnp.arange(c)[None, :] < gs[:, None]
    x = jnp.where(live[..., None], x, 0.0)              # zero-padded buckets
    wg = jax.random.normal(ks[1], (e, d, f)) * d ** -0.5
    wu = jax.random.normal(ks[2], (e, d, f)) * d ** -0.5
    wd = jax.random.normal(ks[3], (e, f, d)) * f ** -0.5
    got = moe_gmm(x, wg, wu, wd, group_sizes=gs, block_c=64, interpret=True)
    dense = moe_gmm(x, wg, wu, wd, block_c=64, interpret=True)
    want = ref.moe_ffn_ref(x, wg, wu, wd, "swiglu", group_sizes=gs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got, dense, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [64, 128])
def test_moe_gmm_block_sweep(block):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    e, c, d, f = 2, 256, 128, 256
    x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
    wg = jax.random.normal(ks[1], (e, d, f)) * d ** -0.5
    wu = jax.random.normal(ks[2], (e, d, f)) * d ** -0.5
    wd = jax.random.normal(ks[3], (e, f, d)) * f ** -0.5
    got = moe_gmm(x, wg, wu, wd, block_c=block, block_f=block,
                  interpret=True)
    want = ref.moe_ffn_ref(x, wg, wu, wd, "swiglu")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (2, 8, 8, 512, 64),      # MHA
    (2, 8, 2, 1024, 64),     # GQA 4:1
    (1, 16, 4, 2048, 128),   # GQA 4:1, bigger head
    (3, 4, 1, 512, 128),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn_matches_ref(b, h, hkv, s, d, dtype):
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    valid = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    got = decode_attn(q, k, v, valid, block_s=256, interpret=True)
    want = ref.decode_attn_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attn_partial_fill_blocks():
    """valid_len smaller than one block must zero out later blocks entirely."""
    b, h, hkv, s, d = 1, 4, 4, 1024, 64
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    valid = jnp.array([3], jnp.int32)
    got = decode_attn(q, k, v, valid, block_s=256, interpret=True)
    want = ref.decode_attn_ref(q, k, v, valid)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_decode_attn_matches_model_attention():
    """Cross-check the kernel against the model's attention_core path."""
    from repro.models.layers import attention_core
    b, h, hkv, s, d = 2, 8, 4, 512, 64
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    valid = jnp.full((b,), 400, jnp.int32)
    got = decode_attn(q, k, v, valid, block_s=128, interpret=True)
    # attention_core takes (B, Sq, H, D) and a scalar cache fill level.
    want = attention_core(q[:, None], k, v, causal_offset=None, window=None,
                          valid_len=jnp.int32(400))
    np.testing.assert_allclose(got, want[:, 0], rtol=1e-4, atol=1e-4)
