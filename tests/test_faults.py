"""Fault tolerance: injection, detection, and lossless recovery.

Chaos property tests (via ``tests/_propcheck.py``, so they run with or
without hypothesis): random ``FaultPlan``s against a live engine must leave
token streams BYTE-IDENTICAL to a never-faulted run (recovery is rollback +
repair + re-queue, and greedy decoding is deterministic); replica-backed
failover must drop zero tokens; shed-mode admission must never starve an
admitted request. Plus unit coverage for the ``HealthMonitor`` detectors,
the repair/shrink edge cases, and the typed ``FaultError``/``PlanError``
surfaces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AuroraPlanner, homogeneous_cluster, synthetic_trace
from repro.core.errors import FaultError, PlanError
from repro.core.schedule import check_partial_permutation
from repro.models import Model
from repro.models.moe import (ReplicationSpec, repair_moe_params,
                              replicate_moe_params, shrink_replication)
from repro.serving import (ChaosHarness, ContinuousEngine, DeviceLoss,
                           EdfAdmission, EngineConfig, ExpertCorruption,
                           FaultInjector, FaultPlan, HealthMonitor, Request,
                           Straggler, scale_admission)
from repro.serving.faults import corrupt_moe_params

from tests._propcheck import given, settings, st


# One reduced MoE model for every engine in this module (compile cost is
# per-engine, not per-model, so sharing the model keeps examples honest
# while sharing the expensive init).
_CACHE: dict = {}


def _moe():
    if not _CACHE:
        cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
        model = Model(cfg)
        _CACHE["m"] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _CACHE["m"]


def _stream(n=4, max_new=3, prompt_len=4, seed=123):
    cfg, _, _ = _moe()
    rng = np.random.default_rng(seed)
    return [Request(prompt=[int(x) for x in
                            rng.integers(1, cfg.vocab, prompt_len)],
                    max_new_tokens=max_new, arrival=float(i))
            for i in range(n)]


def _clean_reference():
    """Token streams of the no-fault run of the canonical stream."""
    if "ref" not in _CACHE:
        cfg, model, params = _moe()
        eng = ContinuousEngine(model, params, 2, 32,
                               config=EngineConfig(prefill_len=4))
        done = eng.serve(_stream())
        _CACHE["ref"] = [list(r.out_tokens) for r in done]
    return _CACHE["ref"]


# -- HealthMonitor detectors -------------------------------------------------

def test_heartbeat_timeout_declares_loss_once():
    mon = HealthMonitor(n_devices=3, heartbeat_timeout=2)
    for step in range(2):
        for d in range(3):
            mon.heartbeat(d, step)
        assert mon.check(step) == []
    # Device 1 goes silent; the others keep beating.
    for step in range(2, 6):
        mon.heartbeat(0, step)
        mon.heartbeat(2, step)
        mon.check(step)
    losses = [e for e in mon.events if e.kind == "device_loss"]
    assert [e.device for e in losses] == [1]   # exactly once
    assert mon.lost_devices == (1,)
    assert losses[0].step == 3                 # silent since 1, timeout 2


def test_straggler_flag_fires_once_and_rearms():
    mon = HealthMonitor(n_devices=2, halflife=2.0, straggler_ratio=2.0,
                        min_observations=2)
    for step in range(4):
        mon.observe_step_time(0, 1.0)
        mon.observe_step_time(1, 10.0)
        mon.check(step)
    flags = [e for e in mon.events if e.kind == "straggler"]
    assert [e.device for e in flags] == [1]    # once per episode
    # Recovery: device 1 speeds back up, EWMA decays under the threshold,
    # then it degrades again — the flag re-arms.
    for step in range(4, 16):
        mon.observe_step_time(0, 1.0)
        mon.observe_step_time(1, 1.0)
        mon.check(step)
    for step in range(16, 24):
        mon.observe_step_time(0, 1.0)
        mon.observe_step_time(1, 10.0)
        mon.check(step)
    flags = [e for e in mon.events if e.kind == "straggler"]
    assert [e.device for e in flags] == [1, 1]


def test_nan_guard_dedups_per_step_and_drains():
    mon = HealthMonitor()
    assert mon.observe_output({"x": jnp.zeros(3)}, step=0)
    bad = {"x": jnp.array([1.0, float("nan")])}
    assert not mon.observe_output(bad, step=1)
    assert not mon.observe_output(bad, step=1)     # same step: one event
    assert [e.kind for e in mon.events] == ["nan"]
    assert [e.step for e in mon.drain()] == [1]
    assert mon.drain() == []                       # drained
    assert len(mon.events) == 1                    # history kept


def test_monitor_rejects_degenerate_config():
    with pytest.raises(ValueError):
        HealthMonitor(n_devices=0)
    with pytest.raises(ValueError):
        HealthMonitor(straggler_ratio=1.0)
    with pytest.raises(ValueError):
        HealthMonitor(heartbeat_timeout=0)


def test_synthetic_straggler_reaches_detector():
    # The injector inflates the reported signal (no real sleep); the EWMA
    # path must still flag the device.
    plan = FaultPlan((Straggler(step=0, device=1, factor=10.0,
                                duration=32),))
    inj = FaultInjector(plan, n_devices=2,
                        health=HealthMonitor(n_devices=2, halflife=2.0,
                                             straggler_ratio=3.0,
                                             min_observations=2))
    fn = inj.wrap(lambda: jnp.zeros(4))
    for _ in range(6):
        inj.tick()
        fn()
        inj.health.check(inj.step - 1)
    assert any(e.kind == "straggler" and e.device == 1
               for e in inj.health.events)


# -- FaultPlan ---------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_random_plan_is_deterministic_and_bounded(seed):
    a = FaultPlan.random(seed, horizon=16, n_devices=4, n_experts=8,
                         n_faults=5)
    assert a == FaultPlan.random(seed, horizon=16, n_devices=4,
                                 n_experts=8, n_faults=5)
    assert len(a.faults) == 5
    losses = [f for f in a.faults if isinstance(f, DeviceLoss)]
    assert len({f.device for f in losses}) <= 3   # a survivor always exists
    for f in a.faults:
        assert 1 <= f.step < 16
    assert a.horizon() >= max((f.step for f in a.faults), default=0)


def test_plan_at_and_corruption_flag():
    plan = FaultPlan((DeviceLoss(step=2, device=0),
                      ExpertCorruption(step=2, expert=1),
                      Straggler(step=5, device=1)))
    assert len(plan.at(2)) == 2 and len(plan.at(3)) == 0
    assert plan.has_corruption
    assert not FaultPlan((DeviceLoss(step=1, device=0),)).has_corruption


# -- weight corruption / repair ----------------------------------------------

def _experts_leaves(params):
    return [leaf for path, leaf
            in jax.tree_util.tree_leaves_with_path(params)
            if any(getattr(k, "key", None) == "experts" for k in path)]


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_repair_from_replica_is_byte_identical(seed):
    cfg, _, params = _moe()
    n = cfg.moe.n_experts
    rng = np.random.default_rng(seed)
    counts = [int(c) for c in rng.integers(1, 3, n)]
    if max(counts) < 2:
        counts[int(rng.integers(n))] = 2
    spec = ReplicationSpec.from_counts(counts)
    rep = replicate_moe_params(params, spec)
    # Corrupt one copy of a replicated expert; its sibling is healthy.
    e = int(rng.choice([i for i in range(n) if counts[i] >= 2]))
    phys = spec.base[e] + int(rng.integers(counts[e]))
    bad = corrupt_moe_params(rep, phys)
    assert any(not np.isfinite(np.asarray(leaf)).all()
               for leaf in _experts_leaves(bad))
    healed = repair_moe_params(bad, spec, [phys])
    for a, b in zip(jax.tree_util.tree_leaves(rep),
                    jax.tree_util.tree_leaves(healed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_repair_and_shrink_refuse_last_copy():
    cfg, _, params = _moe()
    n = cfg.moe.n_experts
    with pytest.raises(FaultError):
        repair_moe_params(params, None, [0])       # unreplicated: no donor
    spec = ReplicationSpec.from_counts([2] + [1] * (n - 1))
    with pytest.raises(FaultError):
        # Both copies of expert 0 corrupt: nothing healthy to clone.
        repair_moe_params(replicate_moe_params(params, spec), spec, [0, 1])
    with pytest.raises(FaultError):
        shrink_replication(spec, [spec.base[1]])   # expert 1's only copy
    with pytest.raises(FaultError):
        shrink_replication(None, [0])
    shrunk = shrink_replication(spec, [0])
    assert shrunk is None                           # back to identity


# -- degraded re-planning ----------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_plan_degraded_covers_every_expert_on_survivors(seed):
    n = 8
    rng = np.random.default_rng(seed)
    failed = sorted(rng.choice(n, size=int(rng.integers(1, n)),
                               replace=False).tolist())
    trace = synthetic_trace(f"chaos-{seed}", n_experts=n, n_layers=2,
                            seed=seed)
    planner = AuroraPlanner(homogeneous_cluster(n))
    plan = planner.plan_degraded(trace, failed, ep_compatible=True)
    k = len(plan.survivors)
    assert set(plan.survivors).isdisjoint(failed)
    assert n % k == 0                               # EP-shardable
    total = 0
    for hosts in plan.replication:
        assert len(hosts) >= 1                      # nothing orphaned
        assert all(0 <= h < k for h in hosts)       # survivor frame
        total += len(hosts)
    assert total % k == 0                           # padded for EP


def test_plan_degraded_typed_errors():
    n = 4
    trace = synthetic_trace("err", n_experts=n, n_layers=1, seed=0)
    planner = AuroraPlanner(homogeneous_cluster(n))
    with pytest.raises(FaultError):
        planner.plan_degraded(trace, list(range(n)))   # nobody survives
    with pytest.raises(FaultError):
        planner.plan_degraded(trace, [n + 1])          # out of range
    with pytest.raises(FaultError):
        AuroraPlanner(homogeneous_cluster(n + 1)).plan_degraded(trace, [0])


def test_schedule_and_adopt_raise_typed_errors():
    with pytest.raises(PlanError):
        check_partial_permutation((0, 0), 2, "slot")   # self-send
    with pytest.raises(PlanError):
        check_partial_permutation((1, 5), 2, "slot")   # off the mesh
    cfg, model, params = _moe()
    eng = ContinuousEngine(model, params, 2, 32,
                           config=EngineConfig(prefill_len=4))
    with pytest.raises(PlanError):
        eng.adopt_assignment([0] * cfg.moe.n_experts)  # not a permutation
    with pytest.raises(TypeError, match="bogus_flag"):
        ContinuousEngine(model, params, 2, 32, bogus_flag=7)


def test_scale_admission_preserves_shed_policy():
    pol = EdfAdmission(chunk=4, budget=16, shed=True, queue_cap=7)
    scaled = scale_admission(pol, 0.5)
    assert scaled.budget == 8
    assert scaled.shed and scaled.queue_cap == 7    # shedding survives
    assert scale_admission(pol, None) is pol


# -- chaos: random fault plans vs a live engine ------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_chaos_recovery_is_byte_identical(seed):
    """Any random FaultPlan (corruption, loss, stragglers) must recover to
    the EXACT no-fault token streams: NaN steps roll back and repair,
    lost devices' requests re-queue and re-emit, stragglers are observed
    only. Zero tokens dropped."""
    cfg, model, params = _moe()
    ref = _clean_reference()
    plan = FaultPlan.random(seed, horizon=8, n_devices=2,
                            n_experts=cfg.moe.n_experts, n_faults=2,
                            max_losses=1)
    inj = FaultInjector(plan, n_devices=2,
                        health=HealthMonitor(n_devices=2,
                                             heartbeat_timeout=2,
                                             min_observations=2))
    eng = ContinuousEngine(model, params, 2, 32,
                           config=EngineConfig(prefill_len=4,
                                               step_wrapper=inj.wrap))
    live = ChaosHarness(eng, inj).serve(_stream())
    assert [list(r.out_tokens) for r in live] == ref
    assert all(len(r.out_tokens) == r.max_new_tokens for r in live)


def test_replica_backed_failover_drops_zero_tokens():
    """Corrupting a replicated expert must repair FROM THE REPLICA (not
    the pristine fallback) and still match the unreplicated clean run —
    replication is placement-only and failover is lossless."""
    cfg, model, params = _moe()
    n = cfg.moe.n_experts
    ref = _clean_reference()
    plan = FaultPlan((ExpertCorruption(step=2, expert=0),))
    inj = FaultInjector(plan, n_devices=2,
                        health=HealthMonitor(n_devices=2))
    eng = ContinuousEngine(model, params, 2, 32,
                           config=EngineConfig(prefill_len=4,
                                               step_wrapper=inj.wrap))
    eng.adopt_replication([2] + [1] * (n - 1))
    h = ChaosHarness(eng, inj)
    live = h.serve(_stream())
    assert [list(r.out_tokens) for r in live] == ref
    assert any(r["action"] == "repaired-from-replica"
               for r in h.recoveries)


def test_device_loss_requeues_and_streams_survive():
    """Fail-stop loss mid-stream: the lost device's slots re-queue and the
    finished streams match the clean run byte for byte."""
    cfg, model, params = _moe()
    ref = _clean_reference()
    plan = FaultPlan((DeviceLoss(step=2, device=1),))
    inj = FaultInjector(plan, n_devices=2,
                        health=HealthMonitor(n_devices=2,
                                             heartbeat_timeout=2))
    eng = ContinuousEngine(model, params, 2, 32,
                           config=EngineConfig(prefill_len=4,
                                               step_wrapper=inj.wrap))
    h = ChaosHarness(eng, inj)
    live = h.serve(_stream())
    assert [list(r.out_tokens) for r in live] == ref
    assert any(r["action"] == "requeued" for r in h.recoveries)
    assert any(e.kind == "device_loss" for e in h.health.events)


def test_nan_without_declared_corruption_is_a_real_failure():
    """A NaN the fault plan did not script has no checkpoint to roll back
    to — that is a genuine numeric failure and must surface, not be
    silently absorbed."""
    cfg, model, params = _moe()
    inj = FaultInjector(FaultPlan(), n_devices=1)
    eng = ContinuousEngine(model, params, 2, 32,
                           config=EngineConfig(prefill_len=4,
                                               step_wrapper=inj.wrap))
    h = ChaosHarness(eng, inj)
    eng.params = corrupt_moe_params(eng.params, 0)   # unscripted corruption
    for r in _stream(n=2):
        eng.submit(r)
    with pytest.raises(FaultError):
        for _ in range(8):
            h.step()


# -- shed-mode admission -----------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_shed_never_starves_admitted(seed):
    """Random overload bursts under EdfAdmission(shed=True): every shed
    request is refused with a typed reason and emits nothing; every
    ADMITTED request runs to completion — shedding protects admitted work,
    it never starves it."""
    cfg, model, params = _moe()
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(8):
        t = float(rng.integers(0, 3))
        reqs.append(Request(
            prompt=[int(x) for x in rng.integers(1, cfg.vocab, 4)],
            max_new_tokens=2, arrival=t,
            deadline=t + float(rng.integers(1, 6))))
    eng = ContinuousEngine(
        model, params, 2, 32,
        config=EngineConfig(prefill_len=4,
                            admission=EdfAdmission(chunk=4, budget=6,
                                                   shed=True,
                                                   queue_cap=4)))
    eng.serve(reqs)
    shed_ids = {id(ev.request) for ev in eng.shed_events}
    for ev in eng.shed_events:
        assert ev.reason.startswith(("deadline:", "queue_cap:"))
    for r in reqs:
        if id(r) in shed_ids:
            assert r.out_tokens == []               # refused, not run
        else:
            assert len(r.out_tokens) == r.max_new_tokens
