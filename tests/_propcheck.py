"""Property-testing front-end: hypothesis when installed, seeded fallback
otherwise.

The tier-1 suite must COLLECT AND RUN in a bare environment (numpy + jax +
pytest only), so the property tests import ``given / settings / st`` from
here instead of from ``hypothesis`` directly. With hypothesis installed this
module re-exports the real thing — shrinking, the example database, and the
full strategy zoo included. Without it, a minimal shim replays a fixed
number of seeded random examples per test (deterministic per test name), so
the core invariants — BvN schedule totals, contention-free slots, augment
row/col sums, matching optimality — stay guarded rather than skipped.

The shim implements only what these tests use: ``st.integers``,
``st.floats``, ``st.lists``, ``.map``, ``.flatmap``, ``@settings``,
``@given``.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 25   # cap: the shim does not shrink failures,
    #                               so keep the bare-env runtime bounded

    class _Strategy:
        """A strategy is just ``draw(rng) -> value``."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def flatmap(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)).draw(rng))

    class st:  # noqa: N801 — mirrors ``strategies as st``
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 10

            def draw(rng):
                n = int(rng.integers(min_size, hi + 1))
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

    def settings(max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = min(max_examples, _FALLBACK_MAX_EXAMPLES)
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            _FALLBACK_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    try:
                        fn(*args, *drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsified on example {i}: {drawn!r}") from e

            # Hide the generated parameters from pytest's fixture resolver:
            # functools.wraps copies __wrapped__, and inspect.signature
            # follows it back to (n, seed, ...) — which pytest would then
            # try to inject as fixtures.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
