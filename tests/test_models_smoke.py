"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(≤2–3 layers, d_model ≤ 512, ≤4 experts) and run one forward/train step plus
a prefill→decode round on CPU, asserting output shapes and no NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model, cross_entropy, padded_vocab

BATCH, SEQ = 2, 32


def _inputs(cfg, key, seq=SEQ, batch=BATCH):
    ks = jax.random.split(key, 3)
    batch_d = {}
    if cfg.is_encoder_decoder:
        batch_d["frames"] = jax.random.normal(
            ks[0], (batch, seq, cfg.frontend_dim), jnp.float32)
        batch_d["tokens"] = jax.random.randint(
            ks[1], (batch, max(seq // 4, 4)), 0, cfg.vocab)
    else:
        batch_d["tokens"] = jax.random.randint(
            ks[1], (batch, seq), 0, cfg.vocab)
    return batch_d


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _inputs(cfg, key)
    logits, aux = model.train_logits(params, batch, remat=False)
    s = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, s, padded_vocab(cfg))
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"
    loss = cross_entropy(logits, batch["tokens"], cfg.vocab)
    assert jnp.isfinite(loss)
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grads_finite(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _inputs(cfg, key, seq=16)

    def loss_fn(p):
        logits, aux = model.train_logits(p, batch, remat=False)
        return cross_entropy(logits, batch["tokens"], cfg.vocab) + 0.01 * aux

    grads = jax.grad(loss_fn)(params)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert jnp.isfinite(g).all(), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    cap = SEQ + 4
    inputs = _inputs(cfg, key)
    src_len = SEQ if cfg.is_encoder_decoder else 0
    cache = model.init_cache(BATCH, cap, src_len=src_len)

    logits, cache = model.prefill(params, inputs, cache)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite prefill logits"
    tgt_len = inputs["tokens"].shape[1]
    assert int(cache["len"]) == tgt_len

    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1).astype(jnp.int32)
    for _ in range(2):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (BATCH, 1, padded_vocab(cfg))
        assert jnp.isfinite(logits).all(), f"{arch}: non-finite decode logits"
        tok = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    assert int(cache["len"]) == tgt_len + 2


def test_vlm_prefill_with_patch_embeds():
    cfg = get_config("qwen2-vl-7b").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    embeds = jax.random.normal(key, (BATCH, SEQ, cfg.frontend_dim),
                               jnp.float32)
    cache = model.init_cache(BATCH, SEQ + 2)
    logits, cache = model.prefill(params, {"embeds": embeds}, cache)
    assert logits.shape == (BATCH, SEQ, padded_vocab(cfg))
    assert jnp.isfinite(logits).all()
