"""Hot-expert replication: traffic math, the greedy planner, the
shard-of-token dispatch identity, mid-stream engine adoption, and the
predictive re-replication loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AuroraPlanner, homogeneous_cluster,
                        heterogeneous_cluster, identity_replication,
                        replicated_ffn_loads, replicated_traffic,
                        trace_from_counts, validate_replication)
from repro.models import KernelConfig, Model, NO_PARALLEL, ParallelContext
from repro.models.moe import (ReplicationSpec, dereplicate_moe_params,
                              init_moe, moe_apply, replicate_moe_params)
from repro.serving import (ContinuousEngine, EngineConfig, OnlineReplanner,
                           Request, TrafficMonitor)


# -- traffic math -----------------------------------------------------------

def test_validate_replication_rejects_bad_placements():
    ok = validate_replication([(0, 2), (1,), (2,)], 3)
    assert ok == ((0, 2), (1,), (2,))
    with pytest.raises(ValueError, match="one host tuple per expert"):
        validate_replication([(0,), (1,)], 3)
    with pytest.raises(ValueError, match="home device"):
        validate_replication([(1, 0), (1,), (2,)], 3)
    with pytest.raises(ValueError, match="duplicate"):
        validate_replication([(0, 0), (1,), (2,)], 3)
    with pytest.raises(ValueError, match="out of range"):
        validate_replication([(0, 3), (1,), (2,)], 3)
    assert identity_replication(3) == ((0,), (1,), (2,))


def test_replicated_traffic_hand_computed():
    """Columns split 1/r across hosts; a replica on the token's own source
    absorbs its share locally (diagonal stripped), so replication cuts both
    the hot column and total network bytes."""
    d = np.array([[0.0, 6.0, 0.0],
                  [4.0, 0.0, 2.0],
                  [8.0, 1.0, 0.0]])
    rep = validate_replication([(0, 2), (1,), (2,)], 3)
    out = replicated_traffic(d, rep)
    # Column 0 (12 tokens off-source) splits in half between hosts 0 and 2;
    # source 2's share to host 2 is self-absorbed.
    exp = np.array([[0.0, 6.0, 0.0],
                    [2.0, 0.0, 2.0 + 2.0],
                    [4.0, 1.0, 0.0]])
    np.testing.assert_allclose(out, exp)
    assert out.sum() < d.sum()                      # bytes left the network
    # Identity placement is a no-op.
    np.testing.assert_allclose(
        replicated_traffic(d, identity_replication(3)), d)


def test_replicated_ffn_loads_include_local_shares():
    """FFN load counts the locally-absorbed shares too — total compute is
    conserved, only the peak moves."""
    d = np.array([[0.0, 6.0, 0.0],
                  [4.0, 0.0, 2.0],
                  [8.0, 1.0, 0.0]])
    ident = replicated_ffn_loads(d, identity_replication(3))
    np.testing.assert_allclose(ident, d.sum(axis=0))
    rep = replicated_ffn_loads(d, [(0, 2), (1,), (2,)])
    np.testing.assert_allclose(rep, [6.0, 7.0, 8.0])
    np.testing.assert_allclose(rep.sum(), ident.sum())
    assert rep.max() < ident.max()


# -- planner ----------------------------------------------------------------

def _skewed_trace(n=8, hot=0, ratio=20.0, layers=2):
    counts = np.ones((layers, n))
    counts[:, hot] = ratio
    return trace_from_counts("skew", counts, tokens_per_device=256.0)


def test_plan_replicated_balances_skewed_trace():
    planner = AuroraPlanner(homogeneous_cluster(8))
    tr = _skewed_trace()
    plan = planner.plan_replicated(tr, tolerance=0.1)
    assert plan.scenario == "exclusive+homogeneous+replicated"
    rep = plan.replication
    assert rep is not None and len(rep[0]) > 1      # the hot expert copied
    assert plan.replication_counts[0] == len(rep[0])
    d = np.mean([tr.layer(l) for l in range(len(tr.layers))], axis=0)
    before = replicated_ffn_loads(d, identity_replication(8))
    after = replicated_ffn_loads(d, rep)
    assert after.max() < before.max()
    # Scored better than (or equal to) serving unreplicated.
    ident = planner.evaluate_replicated(tr, identity_replication(8))
    assert plan.predicted.inference_time <= ident.inference_time + 1e-12


def test_plan_replicated_total_multiple_pads_physical_experts():
    planner = AuroraPlanner(homogeneous_cluster(8))
    plan = planner.plan_replicated(_skewed_trace(), tolerance=0.1,
                                   total_multiple=8)
    n_phys = sum(len(h) for h in plan.replication)
    assert n_phys % 8 == 0 and n_phys > 8


def test_plan_replicated_validates_cluster():
    tr = _skewed_trace()
    with pytest.raises(ValueError, match="home device"):
        AuroraPlanner(homogeneous_cluster(4)).plan_replicated(tr)
    with pytest.raises(ValueError, match="homogeneous"):
        AuroraPlanner(heterogeneous_cluster(8)).plan_replicated(tr)


# -- shard-of-token dispatch identity ---------------------------------------

def _rep_pc(spec, kernel=False):
    if kernel:
        return ParallelContext(moe_impl="kernel", kernels=KernelConfig(),
                               moe_replication=spec)
    return ParallelContext(moe_replication=spec)


@pytest.mark.parametrize("kernel", [False, True])
@pytest.mark.parametrize("t", [3, 16])
def test_moe_apply_replication_identity(kernel, t):
    """Replicas are pure copies and routing stays logical, so dispatch with
    widened expert leaves is BYTE-identical to unreplicated dispatch —
    outputs, aux loss, and logical-frame counts — on dense and kernel
    paths, including when capacity drops tokens."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    moe = cfg.moe                                   # 4 experts, cf 1.25
    p = init_moe(jax.random.PRNGKey(0), cfg.d_model, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model),
                          jnp.float32)
    spec = ReplicationSpec.from_counts((2, 1, 3, 1))
    p_rep = replicate_moe_params(p, spec, axis=0)
    base_pc = _rep_pc(None, kernel) if kernel else NO_PARALLEL
    y, aux, c = moe_apply(p, x, moe, cfg.act, base_pc, return_counts=True)
    y_r, aux_r, c_r = moe_apply(p_rep, x, moe, cfg.act, _rep_pc(spec, kernel),
                                return_counts=True)
    np.testing.assert_array_equal(np.asarray(y_r), np.asarray(y))
    assert float(aux_r) == float(aux)
    assert c_r.shape == c.shape and c.shape[-1] == moe.n_experts  # logical
    np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c))


def test_replicate_dereplicate_roundtrip():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg.d_model, cfg.moe, jnp.float32)
    spec = ReplicationSpec.from_counts((1, 2, 1, 2))
    wide = replicate_moe_params(p, spec, axis=0)
    for k, leaf in wide["experts"].items():
        assert leaf.shape[0] == spec.n_phys
        # Replica slots hold byte-identical copies of their home expert.
        for phys, e in enumerate(spec.phys_to_logical):
            np.testing.assert_array_equal(np.asarray(leaf[phys]),
                                          np.asarray(p["experts"][k][e]))
    back = dereplicate_moe_params(wide, spec, axis=0)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ReplicationSpec.from_counts((1, 1, 1)) is None
    with pytest.raises(ValueError):
        ReplicationSpec(counts=(1, 0, 2))


# -- engine adoption (placement-only) ---------------------------------------

def _requests(vocab, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, vocab, 6)),
                    max_new_tokens=5, arrival=float(i)) for i in range(n)]


@pytest.mark.parametrize("kernels", [False, True])
def test_engine_adopt_replication_token_identity(kernels):
    """Adopting a replication mid-stream (and dropping back to identity
    later) widens the live expert leaves but cannot change one emitted
    token — the engine invariant the CI bench gates on."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def serve(adopt_at=None):
        eng = ContinuousEngine(model, params, 2, 32,
                               config=EngineConfig(kernels=kernels))
        for r in _requests(cfg.vocab):
            eng.submit(r)
        reqs, step = list(eng.queue), 0
        while eng.step():
            step += 1
            if adopt_at is not None and step == adopt_at:
                eng.adopt_replication([(0, 1), (1,), (2,), (3, 0)])
            if adopt_at is not None and step == adopt_at + 4:
                eng.adopt_replication(None)          # back to unreplicated
        return [r.out_tokens for r in reqs]

    ref = serve()
    assert all(ref)
    assert serve(adopt_at=3) == ref


def test_adopt_replication_accepts_counts_and_is_idempotent():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousEngine(model, params, 1, 16)
    eng.adopt_replication((2, 1, 1, 1))              # bare counts form
    spec = eng.model.pc.moe_replication
    assert spec is not None and spec.counts == (2, 1, 1, 1)
    wide = eng.params
    eng.adopt_replication([(0, 3), (1,), (2,), (3,)])  # same counts: no-op
    assert eng.params is wide
    eng.adopt_replication((1, 1, 1, 1))              # identity == None
    assert eng.model.pc.moe_replication is None


# -- monitor prediction + online re-replication -----------------------------

def _observe(mon, l0, l1, reps=1):
    """Feed batches whose layer-0 slots route to experts ``l0`` and layer-1
    slots to ``l1`` (one token each)."""
    stats = np.zeros((2, len(l0), mon.n_experts))
    for s, e in enumerate(l0):
        stats[0, s, e] = 1.0
    for s, e in enumerate(l1):
        stats[1, s, e] = 1.0
    for _ in range(reps):
        mon.observe(stats)


def test_predictor_leads_drifting_traffic():
    """The fast EWMA reacts before the slow one, and pushing it through the
    learned inter-layer affinities predicts the NEXT layer's mix before the
    slow rates catch up."""
    mon = TrafficMonitor(n_experts=4, n_layers=2, halflife=64.0)
    # Teach both associations: layer-0 e0 -> layer-1 e1, e2 -> e3.
    _observe(mon, [0, 0, 0, 2], [1, 1, 1, 3], reps=40)
    # Drift: layer 0 now overwhelmingly routes to e2.
    _observe(mon, [2, 2, 2, 2], [3, 3, 3, 3], reps=4)
    slow, fast = mon.rates, mon.fast_rates
    assert fast[0, 2] / fast[0].sum() > slow[0, 2] / slow[0].sum()
    pred = mon.predicted_rates()
    np.testing.assert_allclose(pred[0], fast[0])     # layer 0: fast mix
    # Layer 1 prediction follows the affinity e2 -> e3, leading the slow mix.
    assert pred[1, 3] / pred[1].sum() > slow[1, 3] / slow[1].sum()
    assert pred[1].sum() > 0
    tr = mon.predicted_trace(tokens_per_device=128.0)
    assert tr.name.endswith("+pred") and tr.n == 4


def test_predicted_rates_fallback_without_affinity():
    mon = TrafficMonitor(n_experts=4, n_layers=2)
    np.testing.assert_allclose(mon.predicted_rates(), mon.fast_rates)


def test_maybe_replicate_applies_and_hysteresis():
    """The replanner replicates the hot expert from live traffic, records
    the event, and — once adopted — keeps the placement on a re-check
    (hysteresis: no churn without improvement)."""
    planner = AuroraPlanner(homogeneous_cluster(8))
    mon = TrafficMonitor(n_experts=8, n_layers=2, halflife=8.0)
    _observe(mon, [0] * 6 + [1, 2], [0] * 6 + [3, 4], reps=12)
    rp = OnlineReplanner(planner, interval=4, threshold=0.0, warmup=2)
    assert rp.maybe_replicate(2, mon) is None        # off-interval
    plan = rp.maybe_replicate(4, mon)
    assert plan is not None and len(plan.replication[0]) > 1
    ev = rp.events[-1]
    assert ev.applied and ev.replication == plan.replication
    assert ev.candidate_time < ev.stale_time
    # Same traffic, current placement already the candidate: keep it.
    assert rp.maybe_replicate(8, mon, plan.replication) is None
    assert not rp.events[-1].applied


def test_maybe_replicate_warmup_and_baseline():
    planner = AuroraPlanner(homogeneous_cluster(8))
    mon = TrafficMonitor(n_experts=8, n_layers=2)
    _observe(mon, [0] * 8, [0] * 8, reps=3)
    rp = OnlineReplanner(planner, interval=2, threshold=0.0, warmup=50,
                         baseline_replication=identity_replication(8))
    assert rp.maybe_replicate(2, mon) is None        # still warming up
    assert rp.events == []
    _observe(mon, [0] * 8, [0] * 8, reps=50)
    plan = rp.maybe_replicate(4, mon)
    assert plan is not None
    assert rp.events[-1].baseline_time is not None


def test_maybe_replicate_predictive_uses_forecast():
    """``predictive=True`` plans against the affinity forecast: drift seen
    only in layer 0's fast mix already moves the layer-1 replication."""
    planner = AuroraPlanner(homogeneous_cluster(8))
    mon = TrafficMonitor(n_experts=8, n_layers=2, halflife=32.0)
    _observe(mon, [0, 1, 2, 3, 4, 5, 6, 7], [0, 1, 2, 3, 4, 5, 6, 7],
             reps=30)                               # uniform, e -> e affinity
    _observe(mon, [5] * 8, [5] * 8, reps=6)          # drift toward e5
    rp = OnlineReplanner(planner, interval=1, threshold=-1e9, warmup=1,
                         predictive=True)
    plan = rp.maybe_replicate(1, mon)
    assert plan is not None
    assert len(plan.replication[5]) >= max(
        len(h) for e, h in enumerate(plan.replication) if e != 5)


def test_monitor_slot_to_expert_rejects_non_permutation():
    mon = TrafficMonitor(n_experts=4, n_layers=1)
    with pytest.raises(ValueError, match="permutation"):
        mon.slot_to_expert = [0, 1, 1, 2]
    mon.slot_to_expert = [3, 2, 1, 0]
    stats = np.zeros((1, 1, 4))
    stats[0, 0, 0] = 2.0                             # slot 0 == expert 3
    mon.observe(stats)
    assert mon.counts[0, 3] == 2.0 and mon.counts[0, 0] == 0.0
