"""Kernelized serving hot path: sort-based ragged dispatch + engine wiring.

The kernel tier runs twice in CI: once with the pure-jnp fallback (fast,
every matrix leg) and once with ``REPRO_KERNEL_TIER=interpret`` exported,
which forces the engine-level tests through the Pallas kernel bodies in
interpret mode (the closest a CPU container gets to the TPU path).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.configs import get_config
from repro.models import KernelConfig, Model, NO_PARALLEL, ParallelContext
from repro.models.moe import (capacity, dispatch_indices, init_moe,
                              moe_apply, routed_counts, sort_dispatch)
from repro.serving import (ColocatedContinuousEngine, ContinuousEngine,
                           EngineConfig, MultiTenantContinuousEngine,
                           OnlineReplanner, Request, TrafficMonitor)

INTERPRET_TIER = os.environ.get("REPRO_KERNEL_TIER") == "interpret"


def _engine_kernels():
    """``EngineConfig.kernels`` value for engine tests: plain fallback
    normally, Pallas interpret mode when the interpret tier is selected."""
    return KernelConfig(interpret=True) if INTERPRET_TIER else True


def _kernel_pc(**kw):
    return ParallelContext(moe_impl="kernel", kernels=KernelConfig(**kw))


def _model(arch="phi3.5-moe-42b-a6.6b", seed=0):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(n=5, seed=0, max_new=5, plen=6, vocab=500):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, vocab, plen)),
                    max_new_tokens=max_new, arrival=float(i))
            for i in range(n)]


# -- sort-based dispatch ----------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 4), st.integers(1, 16),
       st.integers(0, 10_000))
def test_sort_dispatch_matches_one_hot(t, k, e, seed):
    """Sort-based dispatch is ``dispatch_indices`` bit for bit: same bucket
    slot, same kept/dropped set under capacity pressure (GShard token-order
    tie-breaking), and group sizes equal to the offered-traffic histogram."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    # Deliberately tight capacity so overflow actually happens.
    cap = int(rng.integers(1, max(2, t // 2 + 1)))
    slot_ref, keep_ref = dispatch_indices(idx, e, cap)
    _, sizes, slot, keep = sort_dispatch(idx, e, cap)
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_ref))
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_ref))
    hist = np.bincount(np.asarray(idx).reshape(-1), minlength=e)
    np.testing.assert_array_equal(np.asarray(sizes), hist)


def test_routed_counts_matches_one_hot():
    rng = np.random.default_rng(3)
    idx = jnp.asarray(rng.integers(0, 8, (12, 2)), jnp.int32)
    want = jax.nn.one_hot(idx, 8, dtype=jnp.float32).sum(axis=1)
    np.testing.assert_allclose(np.asarray(routed_counts(idx, 8)),
                               np.asarray(want))


# -- kernel MoE layer vs dense reference ------------------------------------

@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "deepseek-v3-671b"])
@pytest.mark.parametrize("t", [2, 4, 33])
def test_moe_apply_kernel_matches_dense(arch, t):
    """Same routing, same drops, same combine: kernel-path outputs match the
    dense reference to fp32 tolerance for both router families (softmax and
    sigmoid+shared-expert), at decode- and prefill-sized token counts."""
    cfg = get_config(arch).reduced()
    moe = cfg.moe
    p = init_moe(jax.random.PRNGKey(0), cfg.d_model, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model),
                          jnp.float32)
    y_d, aux_d, c_d = moe_apply(p, x, moe, cfg.act, NO_PARALLEL,
                                return_counts=True)
    y_k, aux_k, c_k = moe_apply(p, x, moe, cfg.act, _kernel_pc(),
                                return_counts=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_d),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_k), float(aux_d), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_d))


@pytest.mark.parametrize("block_c", [3, 8, 128])
def test_moe_apply_kernel_interpret_capacity_alignment(block_c):
    """Regression: ``capacity(multiple=8)`` need not divide into the kernel's
    ``block_c`` grid — the kernel path pads the bucket to ``align_capacity``
    and must stay exact through the Pallas body (interpret mode) for block
    sizes that divide, shrink to, and overshoot the capacity."""
    from repro.kernels.moe_gmm import align_capacity

    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    moe = cfg.moe
    p = init_moe(jax.random.PRNGKey(0), cfg.d_model, moe, jnp.float32)
    t = 16                                  # capacity() -> 16, not 8-aligned
    cap = capacity(t, moe.top_k, moe.n_experts, moe.capacity_factor)
    assert align_capacity(cap, block_c) % min(block_c, cap) == 0
    x = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model),
                          jnp.float32)
    y_d, _ = moe_apply(p, x, moe, cfg.act)
    y_k, _ = moe_apply(p, x, moe, cfg.act,
                       _kernel_pc(interpret=True, block_c=block_c))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_d),
                               rtol=2e-5, atol=2e-5)


def test_moe_apply_counts_flow_on_every_path():
    """``return_counts`` is available on every dispatch path: dense and
    kernel locally (here), EP/aurora in-collective — routing runs inside the
    shard_map all-to-all, so the counts are psum-replicated out of it
    (mesh-backed equality with the dense histogram is asserted in
    ``tests/test_distributed_serving.py``)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg.d_model, cfg.moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model))
    _, _, c_dense = moe_apply(p, x, cfg.moe, cfg.act, return_counts=True)
    _, _, c_kernel = moe_apply(p, x, cfg.moe, cfg.act, _kernel_pc(),
                               return_counts=True)
    assert c_dense.shape == (4, cfg.moe.n_experts)
    np.testing.assert_array_equal(np.asarray(c_kernel), np.asarray(c_dense))


# -- decode_attn_auto -------------------------------------------------------

def test_decode_attn_auto_broadcasts_and_tiles():
    from repro.kernels import ref
    from repro.kernels.ops import decode_attn_auto

    b, h, hkv, s, d = 2, 4, 2, 24, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    # scalar fill level broadcasts to every row
    got = decode_attn_auto(q, k, v, jnp.int32(7))
    want = ref.decode_attn_ref(q, k, v, jnp.full((b,), 7, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # interpret mode: S=24 does not divide block_s=16 — a legal block is
    # derived (the largest divisor) instead of tripping the grid check
    got_i = decode_attn_auto(q, k, v, jnp.full((b,), 7, jnp.int32),
                             block_s=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got_i), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- engines ----------------------------------------------------------------

def test_continuous_engine_kernel_tokens_and_logits():
    """A full ``ContinuousEngine.serve`` run on the kernel path emits the
    dense path's greedy tokens exactly, and the step-level fp32 logits agree
    to tolerance (checked on a prefill + decode pair with matched caches)."""
    cfg, model, params = _model()
    reqs = lambda: _requests(6, seed=1, max_new=6, vocab=cfg.vocab)
    dense = ContinuousEngine(model, params, 3, 48,
                             config=EngineConfig(prefill_len=8))
    out_d = dense.serve(reqs())
    kern = ContinuousEngine(
        model, params, 3, 48,
        config=EngineConfig(prefill_len=8, kernels=_engine_kernels()))
    out_k = kern.serve(reqs())
    assert [r.out_tokens for r in out_d] == [r.out_tokens for r in out_k]

    mk = model.with_kernels(_engine_kernels())
    toks = jnp.asarray(np.random.default_rng(0).integers(
        1, cfg.vocab, (2, 8)), jnp.int32)
    ld, cd = model.prefill(params, {"tokens": toks}, model.init_cache(2, 16))
    lk, ck = mk.prefill(params, {"tokens": toks}, mk.init_cache(2, 16))
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=2e-4, atol=2e-4)
    tok = jnp.argmax(ld[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
    ld, _ = model.decode_step(params, tok, cd)
    lk, _ = mk.decode_step(params, tok, ck)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(ld[:, :, :cfg.vocab], -1)),
        np.asarray(jnp.argmax(lk[:, :, :cfg.vocab], -1)))


def test_kernel_engine_monitor_counts_match_dense():
    """Routing counts harvested on the kernel path equal the dense path's —
    the re-planner sees the same traffic either way."""
    cfg, model, params = _model()
    reqs = lambda: _requests(4, seed=2, max_new=4, vocab=cfg.vocab)
    mon_d = TrafficMonitor(cfg.moe.n_experts, model.n_moe_layers)
    ContinuousEngine(model, params, 2, 48,
                     config=EngineConfig(prefill_len=8),
                     monitor=mon_d).serve(reqs())
    mon_k = TrafficMonitor(cfg.moe.n_experts, model.n_moe_layers)
    ContinuousEngine(
        model, params, 2, 48,
        config=EngineConfig(prefill_len=8, kernels=_engine_kernels()),
        monitor=mon_k).serve(reqs())
    assert mon_k.observations == mon_d.observations
    np.testing.assert_allclose(mon_k.rates, mon_d.rates, atol=1e-9)


def test_replan_drift_with_kernels():
    """The online re-planning loop runs unchanged on the kernel path: live
    counts flow, plans fire, and re-pairing stays placement-only (token
    streams identical to a never-replanning kernel run)."""
    from repro.core import AuroraPlanner, homogeneous_cluster

    cfg_a, ma, pa = _model(seed=0)
    cfg_b, mb, pb = _model(seed=1)
    planner = AuroraPlanner(homogeneous_cluster(cfg_a.moe.n_experts))
    kern = _engine_kernels()

    mk_a = lambda: _requests(5, seed=3)
    mk_b = lambda: _requests(4, seed=4)
    ref = ColocatedContinuousEngine(ma, mb, pa, pb, 2, 48,
                                    config=EngineConfig(kernels=kern))
    ra0, rb0 = ref.serve(mk_a(), mk_b())

    rp = OnlineReplanner(planner, interval=3, threshold=-1.0, warmup=1)
    eng = ColocatedContinuousEngine(ma, mb, pa, pb, 2, 48, replan=rp,
                                    config=EngineConfig(kernels=kern))
    ra1, rb1 = eng.serve(mk_a(), mk_b())
    assert [r.out_tokens for r in ra0] == [r.out_tokens for r in ra1]
    assert [r.out_tokens for r in rb0] == [r.out_tokens for r in rb1]
    applied = [e for e in eng.replan_events if e.applied]
    assert applied, "forced re-planning never fired on the kernel path"
    assert eng.pair == applied[-1].pair


def test_multi_tenant_kernel_tokens_identical():
    cfg, m0, p0 = _model(seed=0)
    _, m1, p1 = _model(seed=1)
    streams = lambda: [_requests(3, seed=5), _requests(3, seed=6)]
    dense = MultiTenantContinuousEngine([m0, m1], [p0, p1], 2, 48,
                                        config=EngineConfig(prefill_len=8))
    out_d = dense.serve(streams())
    kern = MultiTenantContinuousEngine(
        [m0, m1], [p0, p1], 2, 48,
        config=EngineConfig(prefill_len=8, kernels=_engine_kernels()))
    out_k = kern.serve(streams())
    for sd, sk in zip(out_d, out_k):
        assert [r.out_tokens for r in sd] == [r.out_tokens for r in sk]
