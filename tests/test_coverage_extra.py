"""Additional coverage: enc-dec serving with frames, sliding-window ring
cache beyond the window, SSM long decode, and reduced-config invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.models.transformer import forward, segments_of
from repro.serving import Request, ServingEngine


def test_encdec_serving_with_frames():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=2, cache_cap=32,
                        src_len=16)
    frames = np.random.default_rng(0).standard_normal(
        (2, 16, cfg.frontend_dim), dtype=np.float32)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4),
            Request(prompt=[4, 5], max_new_tokens=4)]
    out = eng.serve(reqs, frames=frames)
    assert all(len(r.out_tokens) == 4 for r in out)
    assert all(0 <= t < cfg.vocab for r in out for t in r.out_tokens)


def test_sliding_window_decode_past_window():
    """Decoding beyond the ring-cache window must stay finite and match the
    full-context model inside the window."""
    cfg = get_config("gemma3-27b").reduced()  # window 16, pattern LG
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cap = 64
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 20), 0, cfg.vocab)
    cache = model.init_cache(1, cap)
    logits, cache = model.prefill(params, {"tokens": prompt}, cache)
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], -1).astype(jnp.int32)
    for _ in range(30):  # well past the local window of 16
        logits, cache = model.decode_step(params, tok, cache)
        assert jnp.isfinite(logits).all()
        tok = jnp.argmax(logits[:, :, : cfg.vocab], -1).astype(jnp.int32)
    assert int(cache["len"]) == 50


def test_ssm_decode_matches_prefill_extension():
    """Mamba2: decode via state recurrence == teacher-forcing via SSD scan."""
    cfg = get_config("mamba2-1.3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, cfg.vocab)
    cache = model.init_cache(2, 16)
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache)
    # decode tokens 8..11 one at a time
    outs = []
    for t in range(8, 12):
        logits_d, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(logits_d)
    # teacher-forced reference over the full 12 tokens
    logits_f, _, _, _ = forward(params, cfg, tokens=toks, mode="train")
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits_f[:, 8:12]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_segment_decomposition_covers_all_layers(arch):
    cfg = get_config(arch)
    segs = segments_of(cfg)
    total = sum(len(s.kinds) * s.count for s in segs)
    assert total == cfg.n_layers, (arch, total, cfg.n_layers)
    # Reduced variants must also decompose exactly.
    r = cfg.reduced()
    segs_r = segments_of(r)
    assert sum(len(s.kinds) * s.count for s in segs_r) == r.n_layers


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_within_band(arch):
    """Analytic parameter count is within ±40% of the name-plate size
    (names encode the official count; vocab/frontend variance allowed)."""
    import re
    cfg = get_config(arch)
    m = re.search(r"(\d+(?:\.\d+)?)b", arch)
    if not m:
        pytest.skip("no size in arch id")
    plate = float(m.group(1)) * 1e9
    got = cfg.param_count()
    assert 0.6 * plate < got < 1.6 * plate, (arch, got / 1e9, plate / 1e9)
