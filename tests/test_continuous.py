"""Continuous-batching engine: token identity with the static engine,
staggered arrivals, slot reuse, and the colocated pairing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import (ColocatedContinuousEngine, ColocatedEngine,
                           ContinuousEngine, EngineConfig, Request,
                           ServingEngine, apply_pairing, inverse_pair)


def _model(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests():
    return [Request(prompt=[1, 2, 3, 4], max_new_tokens=6),
            Request(prompt=[5, 6, 7, 8], max_new_tokens=3),
            Request(prompt=[9, 10, 11, 12], max_new_tokens=6),
            Request(prompt=[2, 4, 6, 8], max_new_tokens=5)]


@pytest.mark.parametrize("arch", ["qwen3-32b", "gemma3-27b"])
def test_continuous_matches_static_at_t0(arch):
    """All requests arrive at t=0 → token-identical to ServingEngine.

    Both engines left-pad to the same length (prefill_len == the static
    batch's max prompt length), so per-slot prefill + per-slot-length decode
    must reproduce the static batch exactly — continuous batching changes
    the schedule, never the math. gemma3 exercises the sliding-window ring
    cache; qwen3 the global GQA cache.
    """
    cfg, model, params = _model(arch)
    static = ServingEngine(model, params, batch_slots=4, cache_cap=32)
    ref = static.serve(_requests())
    cont = ContinuousEngine(model, params, batch_slots=4, cache_cap=32,
                            config=EngineConfig(prefill_len=4))
    out = cont.serve(_requests())
    for r, o in zip(ref, out):
        assert r.out_tokens == o.out_tokens


def test_staggered_arrivals_complete_with_correct_counts():
    cfg, model, params = _model("qwen3-32b")
    reqs = [Request(prompt=[i + 1, i + 2, i + 3, i + 4],
                    max_new_tokens=3 + i, arrival=float(2 * i))
            for i in range(5)]
    eng = ContinuousEngine(model, params, batch_slots=2, cache_cap=32,
                           config=EngineConfig(prefill_len=4))
    out = eng.serve(reqs)
    for r in out:
        assert len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)
    # 5 requests through 2 slots forces queueing AND slot reuse.
    assert eng.decode_steps < sum(r.max_new_tokens for r in reqs)


def test_slot_reuse_does_not_leak_cache_state():
    """A request decoded in a reused slot must produce exactly the tokens it
    would produce in a fresh single-slot engine."""
    cfg, model, params = _model("qwen3-32b")
    reqs = [Request(prompt=[7, 7, 7, 7], max_new_tokens=4, arrival=0.0),
            Request(prompt=[3, 1, 4, 1], max_new_tokens=4, arrival=0.0),
            # arrives after both slots have been used and one freed
            Request(prompt=[2, 7, 1, 8], max_new_tokens=5, arrival=6.0)]
    eng = ContinuousEngine(model, params, batch_slots=2, cache_cap=32,
                           config=EngineConfig(prefill_len=4))
    out = eng.serve(reqs)
    for r in out:
        solo = ContinuousEngine(model, params, batch_slots=1, cache_cap=32,
                                config=EngineConfig(prefill_len=4))
        ref = solo.serve([Request(prompt=list(r.prompt),
                                  max_new_tokens=r.max_new_tokens)])[0]
        assert r.out_tokens == ref.out_tokens


def test_continuous_ssm_state_isolation():
    """Mamba conv/SSD state is rebuilt from zero at slot prefill — a reused
    slot must not inherit the previous occupant's recurrent state."""
    cfg, model, params = _model("mamba2-1.3b")
    reqs = [Request(prompt=[9, 9, 9, 9], max_new_tokens=3, arrival=0.0),
            Request(prompt=[1, 2, 3, 4], max_new_tokens=4, arrival=4.0)]
    eng = ContinuousEngine(model, params, batch_slots=1, cache_cap=32,
                           config=EngineConfig(prefill_len=4))
    out = eng.serve(reqs)
    solo = ContinuousEngine(model, params, batch_slots=1, cache_cap=32,
                            config=EngineConfig(prefill_len=4))
    ref = solo.serve([Request(prompt=[1, 2, 3, 4], max_new_tokens=4)])[0]
    assert out[1].out_tokens == ref.out_tokens


def test_colocated_continuous_matches_solo_pools():
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b")
    cfg_b = get_config("phi4-mini-3.8b").reduced()
    mb = Model(cfg_b)
    pb = mb.init(jax.random.PRNGKey(1))

    mk_a = lambda: [Request([1, 2, 3, 4], 5, arrival=0.0),
                    Request([4, 3, 2, 1], 4, arrival=2.0)]
    mk_b = lambda: [Request([5, 6, 7, 8], 6, arrival=1.0)]
    eng = ColocatedContinuousEngine(ma, mb, pa, pb, batch_slots=2,
                                    cache_cap=16,
                                    config=EngineConfig(prefill_len=4))
    ra, rb = eng.serve(mk_a(), mk_b())
    cfg4 = EngineConfig(prefill_len=4)
    solo_a = ContinuousEngine(ma, pa, 2, 16, config=cfg4).serve(mk_a())
    solo_b = ContinuousEngine(mb, pb, 2, 16, config=cfg4).serve(mk_b())
    assert [r.out_tokens for r in ra] == [r.out_tokens for r in solo_a]
    assert [r.out_tokens for r in rb] == [r.out_tokens for r in solo_b]


def test_apply_pairing_roundtrip_and_function_invariance():
    """Pairing is a physical placement choice: applying the inverse
    permutation restores the params exactly, and a paired model serves the
    SAME tokens as the unpaired one (router columns follow the experts)."""
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b")
    cfg_b = get_config("phi4-mini-3.8b").reduced()
    mb = Model(cfg_b)
    pb = mb.init(jax.random.PRNGKey(1))

    e = cfg_a.moe.n_experts
    pair = list(np.random.default_rng(3).permutation(e))
    paired = apply_pairing(pa, pair, cfg_a)
    restored = apply_pairing(paired, inverse_pair(pair), cfg_a)
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    prompts_a = jnp.array([[1, 2, 3, 4]], jnp.int32)
    prompts_b = jnp.array([[5, 6, 7, 8]], jnp.int32)
    out0, _ = ColocatedEngine(ma, mb, pa, pb).serve(
        prompts_a, prompts_b, max_new_tokens=4, cache_cap=16)
    out1, _ = ColocatedEngine(ma, mb, paired, pb).serve(
        prompts_a, prompts_b, max_new_tokens=4, cache_cap=16)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
