"""Unified serving telemetry: ring buffers, the event bus, span nesting,
the disabled fast path, exports, and the engines' watch-only invariant
(telemetry never changes emitted tokens)."""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import (ContinuousEngine, EdfAdmission, EngineConfig,
                           EventBus, HealthMonitor, Request, RingBuffer,
                           Telemetry)
from repro.serving.telemetry import _NULL_SPAN, record_adoption

from _propcheck import given, settings, st  # hypothesis if installed


def _model(arch="qwen3-32b"):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests():
    return [Request(prompt=[1, 2, 3, 4], max_new_tokens=6),
            Request(prompt=[5, 6, 7, 8], max_new_tokens=3),
            Request(prompt=[9, 10, 11, 12], max_new_tokens=6),
            Request(prompt=[2, 4, 6, 8], max_new_tokens=5)]


# -- ring buffer -------------------------------------------------------------

def test_ring_drop_oldest_and_count():
    dropped = []
    ring = RingBuffer(3, on_drop=dropped.append)
    for i in range(5):
        ring.append(i)
    assert list(ring) == [2, 3, 4]
    assert len(ring) == 3
    assert ring.dropped == 2
    assert dropped == [0, 1]
    assert ring[0] == 2 and ring[-1] == 4
    assert ring[1:] == [3, 4]


def test_ring_list_compat():
    ring = RingBuffer(8)
    assert not ring and len(ring) == 0
    ring.extend([1, 2, 3])
    assert ring and list(ring) == [1, 2, 3]
    assert ring[:2] == [1, 2]
    ring.clear()
    assert list(ring) == [] and ring.dropped == 0


def test_ring_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        RingBuffer(0)


@settings(max_examples=25)
@given(st.integers(1, 8), st.integers(0, 40))
def test_ring_retention_property(capacity, n):
    """len == min(n, cap); dropped == max(0, n - cap); contents are the
    LAST cap items in append order."""
    ring = RingBuffer(capacity)
    for i in range(n):
        ring.append(i)
    assert len(ring) == min(n, capacity)
    assert ring.dropped == max(0, n - capacity)
    assert list(ring) == list(range(n))[-capacity:]


# -- event bus ---------------------------------------------------------------

def test_bus_seq_monotonic_and_counts():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    bus = EventBus(capacity=4, clock=clock)
    for i in range(6):
        bus.publish("replan" if i % 2 else "shed", {"i": i}, step=i)
    seqs = [e.seq for e in bus]
    assert seqs == sorted(seqs)
    assert bus.counts["shed"] == 3 and bus.counts["replan"] == 3
    assert len(bus) == 4 and bus.dropped == 2
    assert [e.payload["i"] for e in bus] == [2, 3, 4, 5]
    assert list(bus.events(kind="replan")) == [e for e in bus
                                               if e.kind == "replan"]


def test_bus_deterministic_under_fixed_seed():
    """Same seeded publish sequence -> identical (seq, kind, step) stream."""

    def run(seed):
        rng = np.random.default_rng(seed)
        t = [0.0]

        def clock():
            t[0] += float(rng.random())
            return t[0]

        bus = EventBus(capacity=64, clock=clock)
        kinds = ("shed", "replan", "fault")
        for i in range(20):
            bus.publish(kinds[int(rng.integers(3))], i, step=i)
        return [(e.seq, e.kind, e.step, e.ts) for e in bus]

    assert run(7) == run(7)
    assert run(7) != run(8)


# -- spans -------------------------------------------------------------------

def test_span_nesting_depths():
    tel = Telemetry()
    with tel.span("outer"):
        with tel.span("mid"):
            with tel.span("inner"):
                pass
    by_name = {s.name: s for s in tel.spans}
    assert by_name["outer"].depth == 0
    assert by_name["mid"].depth == 1
    assert by_name["inner"].depth == 2
    # children close first, so finish seq is inner < mid < outer
    assert (by_name["inner"].seq < by_name["mid"].seq
            < by_name["outer"].seq)
    # windows nest: child inside parent
    o, i = by_name["outer"], by_name["inner"]
    assert o.ts <= i.ts and i.ts + i.dur <= o.ts + o.dur + 1e-9


def test_span_closes_on_exception_and_truncates_stack():
    tel = Telemetry()
    with pytest.raises(RuntimeError):
        with tel.span("outer"):
            with tel.span("inner"):
                raise RuntimeError("boom")
    assert tel._stack == []            # nothing leaked on the live stack
    by_name = {s.name: s for s in tel.spans}
    assert by_name["inner"].error == "RuntimeError"
    assert by_name["outer"].error == "RuntimeError"
    # a new top-level span starts back at depth 0
    with tel.span("after"):
        pass
    assert [s for s in tel.spans if s.name == "after"][0].depth == 0


def test_disabled_span_is_shared_singleton():
    tel = Telemetry(enabled=False)
    s1, s2 = tel.span("a", x=1), tel.span("b")
    assert s1 is s2 is _NULL_SPAN      # no per-call allocation
    with s1:
        pass
    tel.count("c_total")
    tel.gauge("g", 1.0)
    tel.observe("h", 0.5)
    assert tel.publish("k", {"v": 1}) is None
    assert len(tel.spans) == 0 and len(tel.bus) == 0
    assert "c_total" not in tel.metrics
    assert "g" not in tel.metrics and "h" not in tel.metrics
    record_adoption(tel, "rounds", step=1)
    record_adoption(None, "rounds", step=1)       # no-op, must not raise
    assert "serving_adoptions_total" not in tel.metrics


# -- metrics -----------------------------------------------------------------

def test_metrics_registry_and_prometheus_text():
    tel = Telemetry()
    tel.count("serving_tokens_total", 3, help="tokens", tenant="a")
    tel.count("serving_tokens_total", 2, tenant="b")
    tel.gauge("serving_queue_depth", 5, tenant="a")
    tel.observe("serving_ttft_steps", 3.0, bounds=(1.0, 4.0), tenant="a")
    tel.observe("serving_ttft_steps", 9.0, bounds=(1.0, 4.0), tenant="a")
    text = tel.prometheus_text()
    assert '# TYPE serving_tokens_total counter' in text
    assert 'serving_tokens_total{tenant="a"} 3' in text
    assert 'serving_tokens_total{tenant="b"} 2' in text
    assert 'serving_queue_depth{tenant="a"} 5' in text
    # histogram buckets are cumulative with an implicit +Inf
    assert 'serving_ttft_steps_bucket{tenant="a",le="4"} 1' in text
    assert 'serving_ttft_steps_bucket{tenant="a",le="+Inf"} 2' in text
    assert 'serving_ttft_steps_count{tenant="a"} 2' in text
    snap = tel.snapshot()
    assert snap["metrics"]["serving_tokens_total"]["kind"] == "counter"
    json.loads(json.dumps(snap))       # snapshot must be JSON-clean

    with pytest.raises(TypeError):
        tel.metrics.gauge("serving_tokens_total")   # kind mismatch


# -- exports -----------------------------------------------------------------

def test_jsonl_and_chrome_trace_round_trip():
    tel = Telemetry()
    with tel.span("engine_step", step=0):
        with tel.span("decode_step", tenant="a"):
            pass
    tel.publish("shed", {"reason": "deadline:late"}, step=0)
    tel.emit_span("dispatch_round", ts=0.0, dur=0.001, depth=2, r=0,
                  estimated=True)
    for line in tel.jsonl().splitlines():
        json.loads(line)               # every JSONL line round-trips
    trace = json.loads(json.dumps(tel.chrome_trace()))
    phases = {e["ph"] for e in trace["traceEvents"]}
    assert phases <= {"X", "i", "M"} and "X" in phases and "i" in phases
    ts = [e["ts"] for e in trace["traceEvents"] if e["ph"] != "M"]
    assert ts == sorted(ts)            # timeline order
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"engine_step", "decode_step", "dispatch_round", "shed"} <= names
    # tenant maps to its own track with a thread_name record
    tids = {e["tid"] for e in trace["traceEvents"]
            if e["ph"] == "X" and e["args"].get("tenant") == "a"}
    assert tids == {1}
    thread_names = [e["args"]["name"] for e in trace["traceEvents"]
                    if e["ph"] == "M" and e["name"] == "thread_name"]
    assert "tenant:a" in thread_names


def test_records_sorted_and_payloads_sanitized():
    tel = Telemetry()
    with tel.span("s"):
        pass
    tel.publish("fault", {"arr": np.arange(3), "bad": float("nan")})
    recs = tel.records()
    ts = [r["ts"] for r in recs]
    assert ts == sorted(ts)
    ev = [r for r in recs if r["type"] == "event"][0]
    assert ev["payload"]["arr"] == [0, 1, 2]
    assert ev["payload"]["bad"] == "nan"
    json.loads(tel.jsonl().splitlines()[-1])


# -- engine integration ------------------------------------------------------

def test_engine_tokens_identical_with_telemetry():
    """Telemetry only watches: same stream, telemetry on vs None, byte-
    identical tokens — and the hub actually recorded the serve."""
    cfg, model, params = _model()
    base = ContinuousEngine(model, params, 4, 48,
                            config=EngineConfig(prefill_len=4))
    ref = _requests()
    base.serve(ref)

    tel = Telemetry()
    traced = ContinuousEngine(model, params, 4, 48,
                              config=EngineConfig(prefill_len=4,
                                                  telemetry=tel))
    live = _requests()
    traced.serve(live)
    assert [r.out_tokens for r in live] == [r.out_tokens for r in ref]

    names = {s.name for s in tel.spans}
    assert {"engine_step", "prefill", "decode_step"} <= names
    tokens = sum(len(r.out_tokens) for r in live)
    assert tel.metrics["serving_tokens_total"].value(tenant="") == tokens
    assert "serving_queue_depth" in tel.metrics
    assert "serving_ttft_steps" in tel.metrics
    # telemetry=None engines carry no hub at all (pre-telemetry path)
    assert base._telemetry is None


def test_engine_disabled_hub_records_nothing():
    cfg, model, params = _model()
    tel = Telemetry(enabled=False)
    eng = ContinuousEngine(model, params, 2, 32,
                           config=EngineConfig(prefill_len=4,
                                               telemetry=tel))
    eng.serve(_requests()[:2])
    assert len(tel.spans) == 0 and len(tel.bus) == 0
    assert "serving_tokens_total" not in tel.metrics


def test_shed_events_ring_bounded():
    """An overload burst under shed-mode EDF with a tiny event_capacity:
    the per-engine shed list keeps only the newest events and counts the
    evictions (and every shed still lands on the hub's bus)."""
    cfg, model, params = _model()
    tel = Telemetry()
    eng = ContinuousEngine(
        model, params, 2, 32,
        config=EngineConfig(
            admission=EdfAdmission(chunk=4, budget=6, shed=True,
                                   queue_cap=2),
            prefill_len=4, telemetry=tel, event_capacity=2))
    reqs = [Request(prompt=[1 + i, 2, 3, 4], max_new_tokens=3,
                    arrival=0.0, deadline=0.5) for i in range(8)]
    sheds = 0
    for r in reqs:
        if eng.submit(r) is not None:
            sheds += 1
    while eng.step():
        pass
    assert sheds >= 3, "burst did not overload — test setup broken"
    assert len(eng.shed_events) == 2
    assert eng.shed_events.dropped == sheds - 2
    assert tel.metrics["serving_events_total"].value(kind="shed") == sheds
    assert len([e for e in tel.bus if e.kind == "shed"]) == sheds


# -- health monitor ----------------------------------------------------------

def test_health_ewma_cold_start_warmup():
    """The first min_observations samples average with EQUAL weight, so a
    slow first step (compile) cannot bias the straggler baseline; the
    detector arms only after warm-up."""
    h = HealthMonitor(n_devices=2, min_observations=4, halflife=8.0,
                      straggler_ratio=3.0)
    assert not h.armed(0) and h.warming_devices == (0, 1)
    samples = [0.3, 0.1, 0.1, 0.1]     # slow cold start, then steady
    for dt in samples:
        h.observe_step_time(0, dt)
        h.observe_step_time(1, 0.1)
    assert h.armed(0) and h.warming_devices == ()
    # warm-up is a plain mean — NOT decay-weighted toward the 1.0 sample
    np.testing.assert_allclose(h.step_times()[0], np.mean(samples))
    # device 0's cold start must not read as a straggler vs device 1
    h.heartbeat(0, 4)
    h.heartbeat(1, 4)
    assert [e for e in h.check(4) if e.kind == "straggler"] == []


def test_health_not_flagged_while_warming():
    h = HealthMonitor(n_devices=2, min_observations=4, straggler_ratio=2.0)
    for _ in range(3):
        h.observe_step_time(0, 10.0)   # looks straggling, but still warming
        h.observe_step_time(1, 0.1)
    h.heartbeat(0, 3)
    h.heartbeat(1, 3)
    assert h.check(3) == []
    h.observe_step_time(0, 10.0)       # 4th sample arms the detector
    h.observe_step_time(1, 0.1)
    assert any(e.kind == "straggler" and e.device == 0 for e in h.check(4))


def test_health_events_ring_bounded_and_published():
    tel = Telemetry()
    h = HealthMonitor(n_devices=1, capacity=2, telemetry=tel)
    for step in range(3):
        assert not h.observe_output({"x": np.array([np.nan])}, step)
    assert len(h.events) == 2 and h.events.dropped == 1
    assert len(h.drain()) == 2         # pending ring is bounded too
    assert h.drain() == []
    assert tel.metrics["serving_faults_total"].value(kind="nan") == 3
    assert len([e for e in tel.bus if e.kind == "fault"]) == 3


def test_health_gauges_exported():
    tel = Telemetry()
    h = HealthMonitor(n_devices=1, min_observations=2, telemetry=tel)
    h.observe_step_time(0, 0.2)
    assert tel.metrics["device_detector_armed"].value(device="0") == 0.0
    h.observe_step_time(0, 0.2)
    assert tel.metrics["device_detector_armed"].value(device="0") == 1.0
    np.testing.assert_allclose(
        tel.metrics["device_step_seconds"].value(device="0"), 0.2)


# -- config ------------------------------------------------------------------

def test_event_capacity_validated():
    with pytest.raises(ValueError):
        EngineConfig(event_capacity=0)
