"""Hopcroft–Karp and bottleneck matching correctness vs brute force."""

import itertools

import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis if installed

from repro.core.matching import (bottleneck_perfect_matching, hopcroft_karp,
                                 has_perfect_matching, perfect_matching)


def brute_max_matching(adj, n_left, n_right):
    best = 0
    def rec(u, used):
        nonlocal best
        if u == n_left:
            best = max(best, len(used))
            return
        # upper-bound prune
        if len(used) + (n_left - u) <= best:
            return
        rec(u + 1, used)
        for v in adj[u]:
            if v not in used:
                rec(u + 1, used | {v})
    rec(0, frozenset())
    return best


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 100_000))
def test_hopcroft_karp_matches_bruteforce(nl, nr, seed):
    rng = np.random.default_rng(seed)
    adj = [sorted(rng.choice(nr, size=rng.integers(0, nr + 1), replace=False).tolist())
           for _ in range(nl)]
    size, match_l = hopcroft_karp(adj, nl, nr)
    assert size == brute_max_matching(adj, nl, nr)
    # the returned matching must be consistent
    used = [v for v in match_l if v >= 0]
    assert len(used) == len(set(used)) == size
    for u, v in enumerate(match_l):
        if v >= 0:
            assert v in adj[u]


def brute_bottleneck(w):
    n = w.shape[0]
    best = float("inf")
    for perm in itertools.permutations(range(n)):
        best = min(best, max(w[i, perm[i]] for i in range(n)))
    return best


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 6), st.integers(0, 100_000))
def test_bottleneck_matching_is_optimal(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((n, n)) * 100
    match, val = bottleneck_perfect_matching(w)
    assert val == pytest.approx(brute_bottleneck(w))
    assert max(w[i, match[i]] for i in range(n)) == pytest.approx(val)
    assert sorted(match) == list(range(n))


def test_perfect_matching_none_when_impossible():
    allowed = np.array([[True, False], [True, False]])
    assert perfect_matching(allowed) is None
    assert not has_perfect_matching(allowed)
