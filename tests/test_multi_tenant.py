"""Multi-tenant (N > 2) colocation: k-way grouping, the N-way phase
simulator, plan_multi, the MultiTenantContinuousEngine, and the
placement-only re-grouping invariant.

The anchor property throughout: at N = 2 every multi-tenant code path must
reduce EXACTLY to the existing pair path (same grouping, same predicted
times, token-identical streams) — the generalization adds scenarios, never
changes the ones the paper validates.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AuroraPlanner, aggregate_traffic,
                        aggregate_traffic_multi, aurora_grouping,
                        aurora_pairing, colocated_inference_time,
                        group_pairs, homogeneous_cluster,
                        multi_colocated_inference_time, random_grouping,
                        synthetic_trace)
from repro.core.cluster import Cluster, V50G, V100G
from repro.models import Model
from repro.serving import (ColocatedContinuousEngine, ContinuousEngine,
                           EngineConfig, MultiTenantContinuousEngine,
                           OnlineReplanner, Request, apply_pairing)


def _model(arch, seed=0):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _requests(n=4, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(rng.integers(1, 500, 6)),
                    max_new_tokens=max_new, arrival=float(i))
            for i in range(n)]


def _traces(k, n_experts=6, seed=0):
    return [synthetic_trace(f"t{i}", n_experts=n_experts, n_layers=2,
                            skew=0.3 + 0.4 * i, seed=seed + 13 * i)
            for i in range(k)]


# -- grouping ---------------------------------------------------------------

def test_grouping_n2_reduces_to_pairing():
    ta, tb = _traces(2)
    ma, mb = np.mean(ta.layers, axis=0), np.mean(tb.layers, axis=0)
    groups = aurora_grouping([ma, mb])
    pair = aurora_pairing(ma, mb)
    assert [g[0] for g in groups] == list(range(ta.n))
    assert [g[1] for g in groups] == list(pair)
    np.testing.assert_allclose(aggregate_traffic_multi([ma, mb], groups),
                               aggregate_traffic(ma, mb, pair))


def test_grouping_structure_and_validation():
    mats = [np.mean(t.layers, axis=0) for t in _traces(3)]
    groups = aurora_grouping(mats)
    n = mats[0].shape[0]
    assert len(groups) == n and all(len(g) == 3 for g in groups)
    # Each tenant's experts form a permutation across the groups.
    for t in range(3):
        assert sorted(g[t] for g in groups) == list(range(n))
    perms = group_pairs(groups)
    assert perms[0] == list(range(n))
    with pytest.raises(ValueError):
        aurora_grouping([])
    with pytest.raises(ValueError):
        aurora_grouping([mats[0], mats[1][:4, :4]])


def test_random_grouping_anchors_tenant0():
    groups = random_grouping(6, 4, seed=1)
    assert [g[0] for g in groups] == list(range(6))
    for t in range(4):
        assert sorted(g[t] for g in groups) == list(range(6))


# -- N-way simulator --------------------------------------------------------

def test_multi_sim_n2_matches_colocated():
    ta, tb = _traces(2)
    cl = homogeneous_cluster(ta.n)
    pair = aurora_pairing(np.mean(ta.layers, axis=0),
                          np.mean(tb.layers, axis=0))
    groups = [(g, pair[g]) for g in range(ta.n)]
    for layer in range(2):
        r2 = colocated_inference_time(ta, tb, layer, cl, pair)
        rm = multi_colocated_inference_time([ta, tb], layer, cl, groups)
        assert rm.inference_time == pytest.approx(r2.inference_time)
        assert rm.utilization == pytest.approx(r2.utilization)


def test_multi_sim_more_tenants_cost_more_but_overlap():
    """Adding a tenant adds its traffic and compute, so time grows — but by
    less than the tenant's standalone cost (the overlap is real)."""
    traces = _traces(3)
    cl = homogeneous_cluster(traces[0].n)
    g2 = aurora_grouping([np.mean(t.layers, axis=0) for t in traces[:2]])
    g3 = aurora_grouping([np.mean(t.layers, axis=0) for t in traces])
    t2 = multi_colocated_inference_time(traces[:2], 0, cl, g2).inference_time
    t3 = multi_colocated_inference_time(traces, 0, cl, g3).inference_time
    solo = multi_colocated_inference_time(
        [traces[2]], 0, cl, [(g,) for g in range(traces[2].n)]).inference_time
    assert t3 > t2
    assert t3 < t2 + solo


def test_multi_sim_validates():
    traces = _traces(2)
    cl = homogeneous_cluster(traces[0].n)
    with pytest.raises(ValueError):
        multi_colocated_inference_time([], 0, cl, [])
    with pytest.raises(ValueError):        # wrong group arity
        multi_colocated_inference_time(
            traces, 0, cl, [(g,) for g in range(traces[0].n)])


# -- planner ----------------------------------------------------------------

def test_plan_multi_n2_matches_plan_colocated_homogeneous():
    ta, tb = _traces(2)
    planner = AuroraPlanner(homogeneous_cluster(ta.n))
    p_co = planner.plan_colocated(ta, tb)
    p_mu = planner.plan_multi([ta, tb])
    assert p_mu.scenario == "multi+homogeneous"
    assert list(p_mu.pair) == list(p_co.pair)
    assert [g[1] for g in p_mu.groups] == list(p_co.pair)
    assert p_mu.predicted.inference_time == pytest.approx(
        p_co.predicted.inference_time)
    assert p_mu.n_tenants == 2


def test_plan_multi_n2_matches_plan_colocated_heterogeneous():
    ta, tb = _traces(2)
    cl = Cluster(devices=(V100G,) * 3 + (V50G,) * 3)
    planner = AuroraPlanner(cl)
    p_co = planner.plan_colocated(ta, tb)
    p_mu = planner.plan_multi([ta, tb])
    assert p_mu.scenario == "multi+heterogeneous"
    assert list(p_mu.pair) == list(p_co.pair)
    np.testing.assert_array_equal(p_mu.expert_to_device,
                                  p_co.expert_to_device)
    assert p_mu.predicted.inference_time == pytest.approx(
        p_co.predicted.inference_time)


def test_plan_multi_beats_random_grouping_n3():
    """The bench gate's configuration: on skew-diverse tenants the greedy
    grouping must predict faster than the random-grouping mean. (Greedy is
    a heuristic — on near-uniform traffic a lucky random draw can match it,
    so this pins the skewed regime the paper targets.)"""
    traces = [synthetic_trace(f"tenant{t}", n_experts=8, n_layers=2,
                              skew=0.3 + 0.5 * t, seed=17 * t)
              for t in range(3)]
    planner = AuroraPlanner(homogeneous_cluster(8))
    plan = planner.plan_multi(traces)
    rand = [planner.evaluate_multi(traces, random_grouping(8, 3, seed=s))
            .inference_time for s in range(6)]
    assert plan.predicted.inference_time <= np.mean(rand) + 1e-9
    # evaluate_multi on the planned grouping reproduces the prediction
    ev = planner.evaluate_multi(traces, list(plan.groups))
    assert ev.inference_time == pytest.approx(plan.predicted.inference_time)


def test_plan_multi_validates():
    planner = AuroraPlanner(homogeneous_cluster(6))
    with pytest.raises(ValueError):
        planner.plan_multi([_traces(1)[0]])


# -- engine -----------------------------------------------------------------

def test_multi_engine_n2_token_identical_to_colocated():
    """The satellite equivalence: N=2 MultiTenantContinuousEngine under the
    planner's grouping emits exactly the dual-model engine's streams."""
    cfg_a, ma, pa = _model("phi3.5-moe-42b-a6.6b", seed=0)
    cfg_b, mb, pb = _model("phi3.5-moe-42b-a6.6b", seed=1)
    pair0 = [2, 0, 3, 1]
    pb_paired = apply_pairing(pb, pair0, cfg_b)
    mk = lambda s, n: _requests(n, seed=s)

    co = ColocatedContinuousEngine(ma, mb, pa, pb_paired, 2, 32,
                                   config=EngineConfig(prefill_len=6),
                                   pair=pair0)
    ca, cb = co.serve(mk(1, 3), mk(2, 2))
    mu = MultiTenantContinuousEngine(
        [ma, mb], [pa, pb_paired], 2, 32,
        config=EngineConfig(prefill_len=6),
        groups=[(g, pair0[g]) for g in range(4)])
    sa, sb = mu.serve([mk(1, 3), mk(2, 2)])
    assert [r.out_tokens for r in sa] == [r.out_tokens for r in ca]
    assert [r.out_tokens for r in sb] == [r.out_tokens for r in cb]


def test_multi_engine_n3_matches_solo_pools():
    ms, ps = [], []
    for s in range(3):
        _, m, p = _model("phi3.5-moe-42b-a6.6b", seed=s)
        ms.append(m)
        ps.append(p)
    eng = MultiTenantContinuousEngine(ms, ps, 2, 32,
                                      config=EngineConfig(prefill_len=6))
    streams = eng.serve([_requests(3, 1), _requests(2, 2), _requests(3, 3)])
    for t, reqs_seed in enumerate([(3, 1), (2, 2), (3, 3)]):
        solo = ContinuousEngine(
            ms[t], ps[t], 2, 32, config=EngineConfig(prefill_len=6)).serve(
                _requests(*reqs_seed))
        assert ([r.out_tokens for r in streams[t]]
                == [r.out_tokens for r in solo]), f"tenant {t}"


def test_multi_engine_regroup_is_placement_only_n3():
    """The N=3 property test: a stream served with the most aggressive
    re-grouping possible (threshold < 0 adopts every changed candidate)
    emits exactly the tokens of a run that never re-groups — across all
    three pools, including chunked admissions."""
    ms, ps = [], []
    for s in range(3):
        cfg, m, p = _model("phi3.5-moe-42b-a6.6b", seed=s)
        ms.append(m)
        ps.append(p)
    planner = AuroraPlanner(homogeneous_cluster(cfg.moe.n_experts))
    mk = lambda: [_requests(3, 1), _requests(2, 2), _requests(3, 3)]

    ref = MultiTenantContinuousEngine(ms, ps, 2, 48,
                                      config=EngineConfig(prefill_chunk=2))
    out0 = ref.serve(mk())
    rp = OnlineReplanner(planner, interval=3, threshold=-1.0, warmup=1)
    eng = MultiTenantContinuousEngine(ms, ps, 2, 48,
                                      config=EngineConfig(prefill_chunk=2),
                                      replan=rp)
    out1 = eng.serve(mk())
    for t in range(3):
        assert ([r.out_tokens for r in out1[t]]
                == [r.out_tokens for r in out0[t]]), f"tenant {t}"
    applied = [e for e in eng.replan_events if e.applied]
    assert applied, "forced re-grouping never fired"
    assert eng.groups == applied[-1].groups
    # Tenant 0 stays the anchor through every re-group.
    assert [g[0] for g in eng.groups] == list(range(len(eng.groups)))
    # Monitors track the realized placement for translation.
    for t in range(1, 3):
        assert eng.monitors[t].slot_to_expert == [g[t] for g in eng.groups]


def test_multi_engine_regroup_hysteresis_keeps_groups():
    ms, ps = [], []
    for s in range(3):
        cfg, m, p = _model("phi3.5-moe-42b-a6.6b", seed=s)
        ms.append(m)
        ps.append(p)
    planner = AuroraPlanner(homogeneous_cluster(cfg.moe.n_experts))
    rp = OnlineReplanner(planner, interval=3, threshold=10.0, warmup=1)
    eng = MultiTenantContinuousEngine(ms, ps, 2, 32, replan=rp)
    groups0 = list(eng.groups)
    eng.serve([_requests(3, 4), _requests(2, 5), _requests(2, 6)])
    assert eng.groups == groups0
    assert eng.replan_events and not any(e.applied for e in eng.replan_events)


def test_multi_engine_validates():
    cfg, m, p = _model("phi3.5-moe-42b-a6.6b", seed=0)
    with pytest.raises(ValueError, match=">= 2 tenants"):
        MultiTenantContinuousEngine([m], [p], 2, 32)
    with pytest.raises(ValueError, match="params"):
        MultiTenantContinuousEngine([m, m], [p], 2, 32)
    with pytest.raises(ValueError, match="anchors"):
        MultiTenantContinuousEngine([m, m], [p, p], 2, 32,
                                    groups=[(1, 0), (0, 1), (2, 2), (3, 3)])
    with pytest.raises(ValueError, match="groups for"):    # wrong count
        MultiTenantContinuousEngine([m, m], [p, p], 2, 32,
                                    groups=[(0, 0), (1, 1)])
    with pytest.raises(ValueError, match="permutation"):   # duplicate expert
        MultiTenantContinuousEngine([m, m], [p, p], 2, 32,
                                    groups=[(0, 0), (1, 0), (2, 2), (3, 3)])
    _, md, pd = _model("qwen3-32b", seed=1)          # dense model
    planner = AuroraPlanner(homogeneous_cluster(cfg.moe.n_experts))
    with pytest.raises(ValueError, match="MoE"):
        MultiTenantContinuousEngine([m, md], [p, pd], 2, 32,
                                    replan=OnlineReplanner(planner))
