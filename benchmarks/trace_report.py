"""Render a telemetry JSONL trace into a per-step timeline table.

  PYTHONPATH=src python -m benchmarks.trace_report BENCH_trace_worker.jsonl
  PYTHONPATH=src python -m benchmarks.trace_report trace.jsonl --markdown

Input is the JSONL written by ``Telemetry.write_jsonl`` (one record per
line: spans and bus events, timeline-ordered) — what ``repro.launch.serve
--trace-out`` and the ``serving_bench --trace`` mesh worker produce.

The table is the paper's Fig. 3 view reconstructed from the host side: one
row per engine step, splitting the step's wall window into

* **comm (est)** — the summed ``dispatch_round`` child spans. These are
  EQUAL subdivisions of the measured compiled-step window (a host cannot
  see intra-step device timing without a device profiler), so the split is
  an estimate and is labelled as such; the round COUNT per step is exact.
* **compute** — the rest of the step span: compiled work outside the round
  schedule plus host-side scheduling (admission, slot management).
* **idle** — the gap between this step's end and the next step's start
  (arrival waits, driver bookkeeping between steps).

Bus events (replans, sheds, faults, adoptions, recoveries) print as
interleaved rows at their timeline position, so "the straggler was flagged
two steps after the rounds swap" reads straight off the table.

``--markdown`` emits a GitHub-flavored table (CI posts it to the step
summary); default is aligned plain text.
"""

from __future__ import annotations

import argparse
import json

# Top-level per-engine-step spans, and the compiled-program spans nested
# inside them (the names engine.py / colocated.py wrap their jitted steps
# with).
STEP_SPANS = ("engine_step", "lockstep_step")
COMPILED_SPANS = ("prefill", "prefill_chunk", "decode_step", "pool_step",
                  "lockstep_decode")


def load_records(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(ln) for ln in f if ln.strip()]


def build_timeline(records: list[dict]) -> dict:
    """Group spans into per-step rows with interleaved events.

    Returns ``{"rows": [...], "events_by_kind": {...}, "totals": {...}}``.
    Each row is either ``{"row": "step", ...}`` with the comm/compute/idle
    split or ``{"row": "event", ...}`` at its timeline position.
    """
    spans = [r for r in records if r.get("type") == "span"]
    events = [r for r in records if r.get("type") == "event"]
    steps = [s for s in spans if s["name"] in STEP_SPANS]
    if not steps:
        # Traces captured without the engine-step wrapper (e.g. spans
        # emitted around bare compiled calls): treat top-level compiled
        # spans as the steps so the table still renders.
        steps = [s for s in spans if s["name"] in COMPILED_SPANS
                 and s.get("depth", 0) == 0]
    steps.sort(key=lambda s: s["ts"])

    def children(step):
        lo, hi = step["ts"], step["ts"] + step["dur"]
        return [s for s in spans
                if s is not step and lo <= s["ts"] < hi
                and s.get("depth", 0) > step.get("depth", 0)]

    rows: list[dict] = []
    totals = {"wall_s": 0.0, "comm_s": 0.0, "compute_s": 0.0, "idle_s": 0.0}
    for i, st in enumerate(steps):
        kids = children(st)
        rounds = [k for k in kids if k["name"] == "dispatch_round"]
        comm = sum(k["dur"] for k in rounds)
        compute = max(st["dur"] - comm, 0.0)
        idle = (max(steps[i + 1]["ts"] - (st["ts"] + st["dur"]), 0.0)
                if i + 1 < len(steps) else 0.0)
        compiled = [k["name"] for k in kids
                    if k["name"] in COMPILED_SPANS]
        rows.append({
            "row": "step", "ts": st["ts"],
            "step": st.get("attrs", {}).get("step", i),
            "span": st["name"],
            "compiled": "+".join(dict.fromkeys(compiled)) or "-",
            "rounds": len(rounds),
            "comm_ms": comm * 1e3, "compute_ms": compute * 1e3,
            "idle_ms": idle * 1e3, "total_ms": st["dur"] * 1e3,
            "tenant": st.get("attrs", {}).get("tenant"),
        })
        totals["wall_s"] += st["dur"] + idle
        totals["comm_s"] += comm
        totals["compute_s"] += compute
        totals["idle_s"] += idle
    for e in events:
        rows.append({"row": "event", "ts": e["ts"], "kind": e["kind"],
                     "step": e.get("step"), "payload": e.get("payload")})
    rows.sort(key=lambda r: r["ts"])

    by_kind: dict[str, int] = {}
    for e in events:
        by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
    return {"rows": rows, "events_by_kind": by_kind, "totals": totals,
            "n_steps": len(steps), "n_events": len(events)}


def _event_text(r: dict) -> str:
    payload = r.get("payload")
    detail = ""
    if isinstance(payload, dict):
        # Keep the headline fields; full payloads live in the JSONL.
        keys = [k for k in ("kind", "device", "reason", "applied",
                            "n_rounds", "detail") if k in payload]
        detail = " ".join(f"{k}={payload[k]}" for k in keys)[:60]
    step = "" if r.get("step") is None else f" @ step {r['step']}"
    return f"{r['kind']}{step}" + (f" ({detail})" if detail else "")


def render(timeline: dict, markdown: bool = False) -> str:
    cols = ("step", "span", "compiled", "rounds", "comm (est) ms",
            "compute ms", "idle ms", "total ms")
    lines: list[str] = []
    if markdown:
        lines.append("| " + " | ".join(cols) + " |")
        lines.append("|" + "|".join("---" for _ in cols) + "|")
    else:
        lines.append(f"{'step':>5} {'span':<13} {'compiled':<15} "
                     f"{'rounds':>6} {'comm(est)ms':>12} {'compute ms':>11} "
                     f"{'idle ms':>8} {'total ms':>9}")
    for r in timeline["rows"]:
        if r["row"] == "event":
            txt = _event_text(r)
            if markdown:
                lines.append(f"| | **{r['kind']}** | {txt} | | | | | |")
            else:
                lines.append(f"      >> {txt}")
            continue
        vals = (r["step"], r["span"], r["compiled"], r["rounds"],
                f"{r['comm_ms']:.2f}", f"{r['compute_ms']:.2f}",
                f"{r['idle_ms']:.2f}", f"{r['total_ms']:.2f}")
        if markdown:
            lines.append("| " + " | ".join(str(v) for v in vals) + " |")
        else:
            lines.append(f"{vals[0]!s:>5} {vals[1]:<13} {vals[2]:<15} "
                         f"{vals[3]:>6} {vals[4]:>12} {vals[5]:>11} "
                         f"{vals[6]:>8} {vals[7]:>9}")
    t = timeline["totals"]
    wall = max(t["wall_s"], 1e-12)
    summary = (f"{timeline['n_steps']} steps over {t['wall_s'] * 1e3:.1f} ms"
               f" — comm(est) {t['comm_s'] / wall:.0%}, compute "
               f"{t['compute_s'] / wall:.0%}, idle {t['idle_s'] / wall:.0%}"
               f"; {timeline['n_events']} events "
               f"{timeline['events_by_kind']}")
    lines.append("")
    lines.append(summary if not markdown else f"**{summary}**")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="trace JSONL from Telemetry.write_jsonl "
                                  "(serve --trace-out / bench --trace)")
    ap.add_argument("--markdown", action="store_true",
                    help="emit a GitHub-flavored table (for CI step "
                         "summaries)")
    ap.add_argument("--max-rows", type=int, default=None,
                    help="truncate the table to the first N rows")
    args = ap.parse_args()

    timeline = build_timeline(load_records(args.jsonl))
    if args.max_rows is not None:
        hidden = len(timeline["rows"]) - args.max_rows
        timeline["rows"] = timeline["rows"][:args.max_rows]
        if hidden > 0:
            print(f"(showing first {args.max_rows} rows; {hidden} hidden)")
    print(render(timeline, markdown=args.markdown))
    return 0


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:       # e.g. `... | head` closing stdout early
        raise SystemExit(0)
