"""Continuous vs static batching under a streaming arrival process.

  PYTHONPATH=src python -m benchmarks.serving_bench

Both engines serve the SAME request stream (Poisson arrivals, mixed output
lengths) on a reduced config. The static engine packs requests into
fixed batches in arrival order: a batch cannot start until its last request
has arrived and cannot retire a slot until its longest request finishes.
The continuous engine admits each request into the first free slot and
evicts on completion. Arrival waiting costs the static engine nothing here
(sim-time only), so the comparison isolates the slot-stall waste — the
serving-layer inefficiency the paper's deployment work sits on top of.

Reports wall-clock throughput (tokens/s, post-warmup) and scheduling
efficiency (tokens per decode step); exits non-zero if continuous batching
loses on either metric.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_static(model, params, reqs, batch_slots, cache_cap):
    """Fixed batches in arrival order; returns (tokens, steps, wall_s)."""
    from repro.serving import Request, ServingEngine

    eng = ServingEngine(model, params, batch_slots, cache_cap)
    # Warm-up compile outside the timed region.
    eng.serve([Request(prompt=list(r.prompt), max_new_tokens=1)
               for r in reqs[:batch_slots]])
    eng.decode_steps = 0
    wall = 0.0
    for i in range(0, len(reqs), batch_slots):
        batch = reqs[i:i + batch_slots]
        t0 = time.perf_counter()
        eng.serve(batch)
        wall += time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in reqs)
    return tokens, eng.decode_steps, wall


def run_continuous(model, params, reqs, batch_slots, cache_cap, prefill_len):
    from repro.serving import ContinuousEngine, Request

    eng = ContinuousEngine(model, params, batch_slots, cache_cap,
                           prefill_len=prefill_len)
    eng.serve([Request(prompt=list(reqs[0].prompt), max_new_tokens=2)])
    eng.decode_steps = 0
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in reqs)
    return tokens, eng.decode_steps, wall


def bench(arch="qwen3-32b", n_requests=16, batch_slots=4, prompt_len=8,
          cache_cap=48, rate=0.75, seed=0):
    import jax
    from repro.configs import get_config
    from repro.models import Model
    from repro.serving import Request, poisson_requests

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    stream = poisson_requests(rng, n_requests, rate, cfg.vocab, prompt_len,
                              max_new_lo=4, max_new_hi=24)

    clone = lambda: [Request(prompt=list(r.prompt),
                             max_new_tokens=r.max_new_tokens,
                             arrival=r.arrival) for r in stream]
    s_tok, s_steps, s_wall = run_static(model, params, clone(),
                                        batch_slots, cache_cap)
    c_tok, c_steps, c_wall = run_continuous(model, params, clone(),
                                            batch_slots, cache_cap,
                                            prefill_len=prompt_len)
    assert s_tok == c_tok, (s_tok, c_tok)

    rows = [("static", s_tok, s_steps, s_wall),
            ("continuous", c_tok, c_steps, c_wall)]
    print(f"== serving bench: {arch} (reduced), {n_requests} requests, "
          f"{batch_slots} slots, Poisson rate {rate}/step ==")
    print(f"{'engine':<12} {'tokens':>7} {'steps':>6} {'tok/step':>9} "
          f"{'wall s':>8} {'tok/s':>9}")
    for name, tok, steps, wall in rows:
        print(f"{name:<12} {tok:>7} {steps:>6} {tok / steps:>9.2f} "
              f"{wall:>8.2f} {tok / wall:>9.1f}")
    speedup = (s_wall / c_wall, (c_tok / c_steps) / (s_tok / s_steps))
    print(f"continuous speedup: {speedup[0]:.2f}x wall, "
          f"{speedup[1]:.2f}x per-step efficiency")
    return {"static": rows[0], "continuous": rows[1],
            "ok": c_tok / c_wall >= s_tok / s_wall and c_steps <= s_steps}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--rate", type=float, default=0.75)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rec = bench(arch=args.arch, n_requests=args.num_requests,
                batch_slots=args.batch, rate=args.rate, seed=args.seed)
    if not rec["ok"]:
        print("FAIL: continuous batching did not beat static batching")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
