"""Serving benchmarks: continuous batching, chunked prefill, online re-plan,
multi-tenant colocation.

  PYTHONPATH=src python -m benchmarks.serving_bench             # classic
  PYTHONPATH=src python -m benchmarks.serving_bench --chunked   # stall study
  PYTHONPATH=src python -m benchmarks.serving_bench --admission # TTFT pool
  PYTHONPATH=src python -m benchmarks.serving_bench --drift     # + re-plan
  PYTHONPATH=src python -m benchmarks.serving_bench --skew      # replication
  PYTHONPATH=src python -m benchmarks.serving_bench --multi     # N tenants
  PYTHONPATH=src python -m benchmarks.serving_bench --sweep     # 4 scenarios
  PYTHONPATH=src python -m benchmarks.serving_bench --chaos     # faults
  PYTHONPATH=src python -m benchmarks.serving_bench --trace     # telemetry
  PYTHONPATH=src python -m benchmarks.serving_bench --all --json BENCH_serving.json

Each section is a pass/fail experiment:

* **continuous** — continuous vs static batching on the SAME Poisson stream
  (PR 1's experiment): continuous must win wall-clock throughput and
  per-step efficiency.
* **chunked** — a long prompt arrives while short requests are decoding.
  One-shot admission absorbs the whole prompt inside one engine step,
  stalling every active slot for that step; chunked prefill bounds per-step
  work at ``prefill_chunk`` tokens. Compares the step-latency tail (max /
  p95 wall per step) of the two schedulers on identical streams; chunked
  must cut the max step latency and emit identical tokens.
* **admission** — pooled concurrent prefill vs serialized chunked
  admission. A bursty stream of multi-chunk prompts queues several
  half-absorbed prefills; ``EngineConfig(prefill_pool=K)`` fuses up to K
  chunk sub-steps plus the decode into one jitted program per engine step,
  so queued prompts absorb together instead of waiting their turn. The
  pooled leg must cut the TTFT p95 (median of paired reps) and emit
  byte-identical tokens — the pool is a schedule change, never a math
  change.
* **drift** — traffic-driven online re-planning. The colocated engine's
  initial expert pairing is planned from a SYNTHETIC historical trace (what
  ``repro.launch.serve`` does — the paper's §2.4 setup), then a drifting
  Poisson stream arrives (prompts shift from one vocab region to another, so
  live expert popularity diverges from history). The adaptive engine
  re-pairs from live ``TrafficMonitor`` traces mid-stream; the stale engine
  keeps the historical pairing. Both pairings are then scored by the paper's
  Table-2 simulator ON THE SAME live trace — the adaptive placement must be
  predicted no slower, and (placement-only invariant) both runs must emit
  byte-identical tokens.
* **skew** — hot-expert replication on a Zipf-skewed drifting stream.
  Prompts draw token ids from a Zipf law over a narrow vocab band (a few
  head tokens — and so a few experts — dominate) and the band flips
  mid-stream. The adaptive engine closes the replication loop end-to-end:
  live counts → ``TrafficMonitor`` → predictive
  ``OnlineReplanner.maybe_replicate`` → ``adopt_replication``. The
  committed placement must simulate faster than serving unreplicated on the
  same live traces, token streams must be byte-identical (replication is
  placement-only), and the measured throughput must not pay more than the
  no-tax slack.
* **multi** — N-tenant colocation (N ∈ {2, 3, 4}). For each tenant count:
  plan a k-way expert grouping with ``AuroraPlanner.plan_multi`` (greedy
  repeated bottleneck matching) and score it against random grouping (REC
  baseline, mean over seeds) with the N-way phase simulator — aurora must
  predict a no-slower inference time at every N. Then serve N Poisson
  streams through ``MultiTenantContinuousEngine`` under the aurora grouping
  (tenant params physically permuted) and under identity placement: token
  streams must be identical (grouping is placement-only), and the fused
  N-tenant engine's measured throughput is recorded for the trend gate.
* **sweep** — the four-scenario SLO matrix (not part of ``--all``; it has a
  dedicated CI step). One Zipf-drifting Poisson stream is served under every
  cluster scenario — exclusive/colocated x homogeneous/heterogeneous — each
  closing its own live re-planning loop (replicate / reassign / replan /
  regroup) under deadline-aware ``EdfAdmission`` with ``TenantSpec`` SLO
  targets. Per scenario: >= 1 live adoption, token streams byte-identical to
  a static leg, and step-clock p95 TTFT/TPOT SLO attainment reported as
  trend-gated metrics.
* **chaos** — fault-tolerant serving (not part of ``--all``; it has a
  dedicated CI step). Mesh leg (subprocess, 8 host devices): one stream
  served clean and under a ``FaultPlan`` that NaN-corrupts an expert and
  fail-stops a device mid-stream; the ``ChaosHarness`` must detect both
  (health monitor), roll back + repair the corrupt step, re-queue the dead
  device's work, adopt a survivor-only degraded plan, and finish with
  BYTE-IDENTICAL token streams. Shed leg: a same-instant overload burst
  under ``EdfAdmission(shed=True)`` must reject the provably-late tail
  with typed reasons while the admitted requests' p95 TTFT stays within
  the no-overload bound and none of them starve.
* **trace** — unified telemetry (not part of ``--all``; it has a dedicated
  CI step). Overhead leg: the same stream through ``telemetry=None``,
  ``Telemetry(enabled=False)`` and an enabled hub — byte-identical tokens,
  the disabled hub within the overhead floor of untraced, and the token
  counter exactly matching emitted tokens. Mesh leg (subprocess, 8 host
  devices, overlap dispatch): records per-round ``dispatch_round`` spans
  plus straggler-fault and rounds-swap adoption events, writes the JSONL +
  Chrome-trace exports, and validates them from disk (round-trip,
  interleaving, timeline order, token identity vs a clean run).

Every section's JSON legs share one base schema (``_leg``): ``tokens``,
``wall_s``, ``tok_per_s``, plus section-specific extras — ``compare.py``
keys off these names and rejects sections it does not know.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _build(arch: str, seed: int = 0):
    import jax
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _clone(reqs):
    from repro.serving import Request

    return [Request(prompt=list(r.prompt), max_new_tokens=r.max_new_tokens,
                    arrival=r.arrival) for r in reqs]


def _leg(tokens, wall_s, **extra):
    """One engine-leg record in the SHARED schema: every section's per-leg
    dict carries ``tokens`` / ``wall_s`` / ``tok_per_s`` under these exact
    snake_case names (compare.py indexes them by path — a stray alias like
    ``tokens_per_sec`` or ``ttftP95`` would silently fall out of the trend
    table). Section-specific extras ride along unchanged."""
    rec = {"tokens": int(tokens), "wall_s": float(wall_s),
           "tok_per_s": float(tokens / wall_s) if wall_s > 0 else 0.0}
    rec.update(extra)
    return rec


def _worker_env(n_devices: int) -> dict:
    """Environment for a subprocess bench worker that needs its own
    host-platform device mesh (the main bench process must keep one device
    so the other sections' timings do not change)."""
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                          f"={n_devices}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return env


def _run_worker(script: str, env: dict, name: str, sentinel: str,
                timeout: float = 1200, retries: int = 1):
    """Run a subprocess bench worker with a hard timeout and ``retries``
    re-attempts (host-device mesh workers share oversubscribed CI cores —
    a hung collective must fail the LEG with a clear message, not hang the
    whole bench job). Returns ``(record, None)`` parsed from the worker's
    ``sentinel``-prefixed JSON line, or ``(None, error_message)`` after the
    final attempt."""
    import subprocess
    import sys

    last = ""
    for attempt in range(1, retries + 2):
        tag = f"{name} worker (attempt {attempt}/{retries + 1})"
        try:
            out = subprocess.run([sys.executable, "-c", script], env=env,
                                 capture_output=True, text=True,
                                 timeout=timeout)
        except subprocess.TimeoutExpired as e:
            last = f"{tag} timed out after {timeout:g}s"
            print(last)
            tail = (e.stdout or b"")
            if tail:
                print(tail.decode(errors="replace")[-2000:]
                      if isinstance(tail, bytes) else str(tail)[-2000:])
            continue
        if out.returncode != 0:
            last = f"{tag} exited {out.returncode}"
            print(last)
            print(out.stdout[-2000:])
            print(out.stderr[-2000:])
            continue
        line = next((ln for ln in out.stdout.splitlines()
                     if ln.startswith(sentinel)), None)
        if line is None:
            last = (f"{tag} exited 0 but never printed its "
                    f"'{sentinel.strip()}' result line")
            print(last)
            print(out.stdout[-2000:])
            continue
        return json.loads(line.split(" ", 1)[1]), None
    return None, last


def _timed_serve(eng, reqs):
    """Serve a stream, recording wall time of every engine step."""
    from repro.serving import serve_stream

    times = []

    def step():
        t0 = time.perf_counter()
        busy = eng.step()
        times.append(time.perf_counter() - t0)
        return busy

    serve_stream(step, [(eng, reqs)])
    return times


def _ttft_serve(eng, reqs):
    """Serve a stream recording per-request time-to-first-token.

    The same arrival-clock loop as ``serve_stream``, with a wall-clock
    stamp at each request's ``submit`` and another when its first decoded
    token appears — TTFT is what concurrent prefill admission buys, so the
    driver has to watch individual requests, not just total wall.
    Returns ``(wall_s, ttfts)`` with one TTFT per request in stream order.
    """
    pend = sorted(reqs, key=lambda r: r.arrival)
    submit_at, first_at = {}, {}
    t, i = 0.0, 0
    t0 = time.perf_counter()
    while i < len(pend) or eng.queue or eng.num_active or eng.num_pending:
        while i < len(pend) and pend[i].arrival <= t:
            submit_at[id(pend[i])] = time.perf_counter()
            eng.submit(pend[i])
            i += 1
        busy = eng.step()
        now = time.perf_counter()
        for r in pend[:i]:
            if r.out_tokens and id(r) not in first_at:
                first_at[id(r)] = now
        if not busy and i < len(pend):
            t = max(t + 1.0, pend[i].arrival)
        else:
            t += 1.0
    wall = time.perf_counter() - t0
    return wall, [first_at[id(r)] - submit_at[id(r)] for r in pend]


def _slo_serve(step_fn, pools, on_step=None):
    """Arrival/STEP-clock SLO driver: ``serve_stream``'s loop with the
    engine-step counter as the latency clock. Per request it records TTFT
    (steps from arrival to first emitted token) and mean TPOT (steps per
    subsequent token) — deterministic functions of the schedule alone, so
    the sweep's CI attainment gate sees real scheduling changes, never
    machine noise. ``on_step(step_index)`` runs after every engine step
    (the sweep's external adoption loops live there).

    Returns ``(ttfts, tpots, steps, wall_s)``; latencies are in stream
    order across pools.
    """
    streams = [[eng, sorted(reqs, key=lambda r: r.arrival), 0]
               for eng, reqs in pools]
    t, steps = 0.0, 0
    first, last = {}, {}
    t0 = time.perf_counter()
    while any(i < len(p) or e.queue or e.num_active or e.num_pending
              for e, p, i in streams):
        for s in streams:
            eng, pend, i = s
            while i < len(pend) and pend[i].arrival <= t:
                eng.submit(pend[i])
                i += 1
            s[2] = i
        busy = step_fn()
        steps += 1
        if on_step is not None:
            on_step(steps)
        for _, pend, i in streams:
            for r in pend[:i]:
                k = id(r)
                if r.out_tokens and k not in first:
                    first[k] = t
                if len(r.out_tokens) >= r.max_new_tokens and k not in last:
                    last[k] = t
        due = [p[i].arrival for _, p, i in streams if i < len(p)]
        if not busy and due:
            t = max(t + 1.0, min(due))               # jump idle gaps
        else:
            t += 1.0
    wall = time.perf_counter() - t0
    ttfts, tpots = [], []
    for _, pend, _ in streams:
        for r in pend:
            ttfts.append(first[id(r)] + 1.0 - r.arrival)
            if len(r.out_tokens) > 1:
                tpots.append((last[id(r)] - first[id(r)])
                             / (len(r.out_tokens) - 1))
    return ttfts, tpots, steps, wall


# ---------------------------------------------------------------------------
# Section 1: continuous vs static (PR 1)
# ---------------------------------------------------------------------------

def bench(arch="qwen3-32b", n_requests=16, batch_slots=4, prompt_len=8,
          cache_cap=48, rate=0.75, seed=0, repeats=3):
    from repro.serving import (ContinuousEngine, EngineConfig, Request,
                               ServingEngine, poisson_requests)

    cfg, model, params = _build(arch)
    rng = np.random.default_rng(seed)
    stream = poisson_requests(rng, n_requests, rate, cfg.vocab, prompt_len,
                              max_new_lo=4, max_new_hi=24)

    s_eng = ServingEngine(model, params, batch_slots, cache_cap)
    s_eng.serve([Request(prompt=list(r.prompt), max_new_tokens=1)
                 for r in stream[:batch_slots]])     # warm-up compile
    c_eng = ContinuousEngine(model, params, batch_slots, cache_cap,
                             config=EngineConfig(prefill_len=prompt_len))
    c_eng.serve([Request(prompt=list(stream[0].prompt), max_new_tokens=2)])

    def run_static():
        reqs = _clone(stream)
        s_eng.decode_steps = 0
        wall = 0.0
        for i in range(0, len(reqs), batch_slots):
            t0 = time.perf_counter()
            s_eng.serve(reqs[i:i + batch_slots])
            wall += time.perf_counter() - t0
        return sum(len(r.out_tokens) for r in reqs), s_eng.decode_steps, wall

    def run_continuous():
        reqs = _clone(stream)
        c_eng.decode_steps = 0
        t0 = time.perf_counter()
        c_eng.serve(reqs)
        wall = time.perf_counter() - t0
        return sum(len(r.out_tokens) for r in reqs), c_eng.decode_steps, wall

    # Interleave repetitions so transient machine load hits both engines
    # alike; gate on the median of per-rep wall ratios.
    s_runs, c_runs = [], []
    for _ in range(repeats):
        s_runs.append(run_static())
        c_runs.append(run_continuous())
    s_tok, s_steps, _ = s_runs[-1]
    c_tok, c_steps, _ = c_runs[-1]
    assert s_tok == c_tok, (s_tok, c_tok)
    s_wall = float(np.median([r[2] for r in s_runs]))
    c_wall = float(np.median([r[2] for r in c_runs]))
    wall_ratio = float(np.median(
        [s_runs[i][2] / c_runs[i][2] for i in range(repeats)]))

    rows = [("static", s_tok, s_steps, s_wall),
            ("continuous", c_tok, c_steps, c_wall)]
    print(f"== serving bench: {arch} (reduced), {n_requests} requests, "
          f"{batch_slots} slots, Poisson rate {rate}/step ==")
    print(f"{'engine':<12} {'tokens':>7} {'steps':>6} {'tok/step':>9} "
          f"{'wall s':>8} {'tok/s':>9}")
    for name, tok, steps, wall in rows:
        print(f"{name:<12} {tok:>7} {steps:>6} {tok / steps:>9.2f} "
              f"{wall:>8.2f} {tok / wall:>9.1f}")
    eff = (c_tok / c_steps) / (s_tok / s_steps)
    print(f"continuous speedup: {wall_ratio:.2f}x wall (median of "
          f"{repeats} paired reps), {eff:.2f}x per-step efficiency")
    return {
        "arch": arch, "n_requests": n_requests, "batch_slots": batch_slots,
        "static": _leg(s_tok, s_wall, steps=s_steps),
        "continuous": _leg(c_tok, c_wall, steps=c_steps),
        "wall_speedup": wall_ratio, "step_efficiency": eff,
        "ok": bool(wall_ratio >= 1.0 and c_steps <= s_steps),
    }


# ---------------------------------------------------------------------------
# Section 2: chunked prefill vs one-shot admission (long-prompt stall)
# ---------------------------------------------------------------------------

def bench_chunked(arch="qwen3-32b", batch_slots=4, short_len=8, long_len=512,
                  chunk=32, n_short=6, max_new=12, seed=0, repeats=5):
    import gc

    import jax
    from repro.serving import ContinuousEngine, Request

    # This section gates on step-latency TAILS, which drown in dispatch
    # jitter when the process carries other sections' compiled programs and
    # buffers — start from a clean heap.
    jax.clear_caches()
    gc.collect()

    cfg, model, params = _build(arch)
    cache_cap = long_len + max_new + 16
    rng = np.random.default_rng(seed)

    def stream():
        # Short requests keep the slots busy; the long prompt lands at t=2,
        # mid-decode — the stall scenario.
        reqs = [Request(prompt=list(rng.integers(1, cfg.vocab, short_len)),
                        max_new_tokens=max_new, arrival=float(i))
                for i in range(n_short)]
        reqs.insert(2, Request(
            prompt=list(rng.integers(1, cfg.vocab, long_len)),
            max_new_tokens=max_new, arrival=2.0))
        return reqs

    base = stream()
    engines = {}
    outs = {}
    for name, kw in (("one-shot", {}), ("chunked", {"prefill_chunk": chunk})):
        engines[name] = ContinuousEngine(model, params, batch_slots,
                                         cache_cap, **kw)
        _timed_serve(engines[name], _clone(base))    # warm-up compiles
    # Transient machine load would sink whichever engine happens to be
    # measured during the spike, so INTERLEAVE the repetitions and gate on
    # the median of per-rep stall ratios — paired samples see the same
    # load environment.
    runs = {"one-shot": [], "chunked": []}
    for _ in range(repeats):
        for name in ("one-shot", "chunked"):
            final = _clone(base)
            runs[name].append(np.asarray(_timed_serve(engines[name], final)))
            outs[name] = [r.out_tokens for r in final]
    assert outs["one-shot"] == outs["chunked"], \
        "chunked prefill changed emitted tokens"

    # External load spikes only ever ADD time, so the MIN over reps of each
    # engine's worst step is the clean estimator of its structural stall
    # (the timeit convention); medians are reported alongside for context.
    results = {}
    for name, arrs in runs.items():
        results[name] = {
            "steps": len(arrs[-1]),
            "wall_s": float(np.median([a.sum() for a in arrs])),
            "max_step_ms": float(min(a.max() for a in arrs) * 1e3),
            "max_step_ms_median": float(
                np.median([a.max() for a in arrs]) * 1e3),
            "p95_step_ms": float(np.median(
                [np.percentile(a, 95) for a in arrs]) * 1e3),
            "mean_step_ms": float(np.median(
                [a.mean() for a in arrs]) * 1e3),
        }
    r1, r2 = results["one-shot"], results["chunked"]
    stall_cut = r1["max_step_ms"] / r2["max_step_ms"]

    print(f"== chunked prefill: {arch} (reduced), {long_len}-token prompt "
          f"into a busy pool, chunk={chunk} ==")
    print(f"{'scheduler':<10} {'steps':>6} {'max ms':>8} {'p95 ms':>8} "
          f"{'mean ms':>8}")
    for name in ("one-shot", "chunked"):
        r = results[name]
        print(f"{name:<10} {r['steps']:>6} {r['max_step_ms']:>8.2f} "
              f"{r['p95_step_ms']:>8.2f} {r['mean_step_ms']:>8.2f}")
    print(f"long-prompt stall (max step latency) cut {stall_cut:.2f}x "
          f"(best-of-{repeats} reps per engine); tokens identical")
    return {
        "arch": arch, "long_len": long_len, "chunk": chunk,
        "one_shot": r1, "chunked": r2, "stall_cut": stall_cut,
        "ok": bool(stall_cut > 1.0),
    }


# ---------------------------------------------------------------------------
# Section 1c: pooled concurrent prefill vs serialized admission
# ---------------------------------------------------------------------------

def bench_admission(arch="qwen3-32b", n_requests=12, batch_slots=4,
                    prompt_len=32, chunk=8, pool=4, max_new=8, rate=1.5,
                    cache_cap=64, seed=0, repeats=3):
    """K-wide prefill pool vs serialized chunked admission, same stream.

    A bursty Poisson stream of multi-chunk prompts (``prompt_len/chunk``
    chunks each) piles several half-absorbed prefills behind one another;
    serialized admission advances ONE of them per engine step, so every
    queued prompt's first token waits for its predecessors' remaining
    chunks. The pooled engine fuses up to ``pool`` chunk sub-steps (plus
    the decode) into one jitted program per step, so concurrent prompts
    absorb together. Gates: byte-identical tokens across legs (the pool is
    a schedule change, never a math change) and the pooled leg must cut
    the TTFT p95 (median of per-rep paired ratios).
    """
    import gc

    import jax
    from repro.serving import (ContinuousEngine, EngineConfig,
                               poisson_requests)

    jax.clear_caches()          # TTFT tails drown in stale-heap jitter
    gc.collect()

    cfg, model, params = _build(arch)
    rng = np.random.default_rng(seed)
    base = poisson_requests(rng, n_requests, rate, cfg.vocab, prompt_len,
                            max_new_lo=max_new // 2, max_new_hi=max_new)

    engines = {
        "serial": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_chunk=chunk)),
        "pooled": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_chunk=chunk, prefill_pool=pool)),
    }
    for eng in engines.values():
        _ttft_serve(eng, _clone(base))                  # warm-up compiles
    runs = {"serial": [], "pooled": []}
    outs = {}
    for _ in range(repeats):
        for name in ("serial", "pooled"):               # interleaved pairs
            final = _clone(base)
            wall, ttfts = _ttft_serve(engines[name], final)
            toks = sum(len(r.out_tokens) for r in final)
            runs[name].append((wall, float(np.percentile(ttfts, 95)), toks))
            outs[name] = [r.out_tokens for r in final]
    assert outs["serial"] == outs["pooled"], \
        "pooled prefill admission changed emitted tokens"

    results = {}
    for name, reps in runs.items():
        results[name] = _leg(
            reps[-1][2], float(np.median([w for w, _, _ in reps])),
            ttft_p95_s=float(np.median([p for _, p, _ in reps])))
        results[name]["tok_per_s"] = float(
            np.median([t / w for w, _, t in reps]))
    cut = float(np.median([s[1] / p[1] for s, p in
                           zip(runs["serial"], runs["pooled"])]))

    print(f"== prefill pool: {arch} (reduced), {n_requests} x "
          f"{prompt_len}-token prompts, chunk={chunk}, pool={pool} ==")
    print(f"{'admission':<8} {'tok/s':>8} {'wall s':>8} {'ttft p95 ms':>12}")
    for name in ("serial", "pooled"):
        r = results[name]
        print(f"{name:<8} {r['tok_per_s']:>8.1f} {r['wall_s']:>8.2f} "
              f"{r['ttft_p95_s'] * 1e3:>12.2f}")
    print(f"TTFT p95 cut {cut:.2f}x (median of {repeats} paired reps); "
          f"tokens identical")
    return {
        "arch": arch, "prompt_len": prompt_len, "chunk": chunk, "pool": pool,
        "serial": results["serial"], "pooled": results["pooled"],
        "ttft_p95_cut": cut, "ok": bool(cut > 1.0),
    }


# ---------------------------------------------------------------------------
# Section 2b: kernelized hot path — dense vs sort-based ragged dispatch
# ---------------------------------------------------------------------------

def bench_kernels(arch="phi3.5-moe-42b-a6.6b", n_experts=32, n_requests=10,
                  batch_slots=4, prompt_len=8, max_new=24, rate=1.0,
                  cache_cap=48, seed=0, repeats=3):
    """Dense one-hot dispatch vs the kernel path in identical engines.

    Decode-heavy stream (short prompts, long generations) at a production-
    shaped expert count: ``reduced()`` clamps to 4 experts, where the dense
    path's garbage-row compute is negligible — widen to ``n_experts`` (tiny
    weights, same code paths) so the quantity the kernel path eliminates
    (every expert runs its full capacity bucket even when a handful of
    decode tokens routed to it) actually shows. The kernel engine must win
    decode throughput AND emit byte-identical greedy tokens (same routing /
    capacity semantics, different machinery).
    """
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.models import Model
    from repro.serving import (ContinuousEngine, EngineConfig,
                               poisson_requests)

    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=n_experts))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    stream = poisson_requests(rng, n_requests, rate, cfg.vocab, prompt_len,
                              max_new_lo=max_new // 2, max_new_hi=max_new)

    engines = {
        "dense": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len)),
        "kernel": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len, kernels=True)),
    }
    for eng in engines.values():
        _timed_serve(eng, _clone(stream))               # warm-up compiles
    # Interleave repetitions (paired samples see the same machine load) and
    # gate on the median of per-rep throughput ratios.
    runs = {name: [] for name in engines}
    outs = {}
    for _ in range(repeats):
        for name, eng in engines.items():
            final = _clone(stream)
            eng.decode_steps = 0
            times = np.asarray(_timed_serve(eng, final))
            tokens = sum(len(r.out_tokens) for r in final)
            runs[name].append((tokens / times.sum(), times))
            outs[name] = [r.out_tokens for r in final]
    assert outs["dense"] == outs["kernel"], \
        "kernel dispatch changed emitted tokens"

    # fp32 logits parity on matched caches — the throughput win must come
    # from skipped garbage-row compute, not numerics drift.
    import jax.numpy as jnp

    mk = model.with_kernels(True)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (batch_slots, prompt_len)),
                       jnp.int32)
    ld, cd = model.prefill(params, {"tokens": toks},
                           model.init_cache(batch_slots, cache_cap))
    lk, ck = mk.prefill(params, {"tokens": toks},
                        mk.init_cache(batch_slots, cache_cap))
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=2e-4, atol=2e-4)
    tok = jnp.argmax(ld[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
    ld, _ = model.decode_step(params, tok, cd)
    lk, _ = mk.decode_step(params, tok, ck)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=2e-4, atol=2e-4)
    max_abs = float(np.max(np.abs(np.asarray(lk) - np.asarray(ld))))

    results = {}
    for name, rs in runs.items():
        results[name] = _leg(
            sum(len(toks) for toks in outs[name]),
            float(np.median([t.sum() for _, t in rs])),
            steps=len(rs[-1][1]),
            p95_step_ms=float(np.median(
                [np.percentile(t, 95) for _, t in rs]) * 1e3),
            mean_step_ms=float(np.median(
                [t.mean() for _, t in rs]) * 1e3))
        results[name]["tok_per_s"] = float(np.median([r for r, _ in rs]))
    speedup = float(np.median(
        [runs["kernel"][i][0] / runs["dense"][i][0] for i in range(repeats)]))

    print(f"== kernel path: {arch} (reduced, {n_experts} experts), "
          f"{n_requests} decode-heavy requests, {batch_slots} slots ==")
    print(f"{'dispatch':<8} {'tokens':>7} {'steps':>6} {'tok/s':>9} "
          f"{'p95 ms':>8} {'mean ms':>8}")
    for name in ("dense", "kernel"):
        r = results[name]
        print(f"{name:<8} {r['tokens']:>7} {r['steps']:>6} "
              f"{r['tok_per_s']:>9.1f} {r['p95_step_ms']:>8.2f} "
              f"{r['mean_step_ms']:>8.2f}")
    print(f"kernel decode throughput {speedup:.2f}x dense (median of "
          f"{repeats} paired reps); token streams identical, decode logits "
          f"max |Δ| {max_abs:.2e}")
    return {
        "arch": arch, "n_experts": n_experts, "n_requests": n_requests,
        "dense": results["dense"], "kernel": results["kernel"],
        "decode_speedup": speedup, "logits_max_abs_diff": max_abs,
        "ok": bool(speedup >= 1.15),
    }


# ---------------------------------------------------------------------------
# Section 2c: distributed dispatch — synchronous vs round-pipelined rounds
# ---------------------------------------------------------------------------

_OVERLAP_WORKER = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs.base import MoEConfig
from repro.core import synthetic_trace
from repro.models.layers import ParallelContext
from repro.models.moe import init_moe, moe_apply
from repro.serving import rounds_from_trace
import dataclasses

n_dev = {n_devices}
n_experts = {n_experts}
mesh = jax.make_mesh((n_dev,), ("model",))
moe = MoEConfig(n_experts=n_experts, top_k=2, d_ff={d_ff},
                capacity_factor=2.0)
p = init_moe(jax.random.PRNGKey(0), {d_model}, moe, jnp.float32)
rounds = rounds_from_trace(
    synthetic_trace("hist", n_experts=n_experts, n_layers=2, seed=0), n_dev)
pc = ParallelContext(mesh=mesh, data_axes=(), model_axis=None,
                     ep_axes=("model",), token_axes=("model",),
                     moe_impl="aurora", aurora_rounds=rounds)
shapes = {{"decode": ({t_decode}, 1, {d_model}),
          "prefill": ({n_devices}, {s_prefill}, {d_model})}}
rec = {{"n_devices": n_dev, "n_experts": n_experts, "rounds": len(rounds)}}
max_abs = 0.0
with set_mesh(mesh):
    for name, shape in shapes.items():
        x = jax.random.normal(jax.random.PRNGKey(1), shape)
        outs = {{}}
        for leg, overlap in (("sync", False), ("pipelined", True)):
            pcl = dataclasses.replace(pc, ep_overlap=overlap)
            fn = jax.jit(lambda x, pcl=pcl:
                         moe_apply(p, x, moe, "swiglu", pcl)[0])
            y = fn(x); y.block_until_ready()          # compile + warm
            reps, t0 = {reps}, time.perf_counter()
            for _ in range(reps):
                y = fn(x)
            y.block_until_ready()
            wall = time.perf_counter() - t0
            tokens = reps * shape[0] * shape[1]
            outs[leg] = y
            rec.setdefault(leg, {{}})[name + "_tok_per_s"] = tokens / wall
        d = float(np.max(np.abs(np.asarray(outs["pipelined"])
                                - np.asarray(outs["sync"]))))
        max_abs = max(max_abs, d)
        rec[name + "_speedup"] = (rec["pipelined"][name + "_tok_per_s"]
                                  / rec["sync"][name + "_tok_per_s"])
rec["max_abs_diff"] = max_abs
rec["ok"] = bool(max_abs < 1e-5)
print("OVERLAP_JSON " + json.dumps(rec))
"""


def bench_overlap(n_devices=8, n_experts=32, d_model=64, d_ff=128,
                  t_decode=8, s_prefill=32, reps=30):
    """Synchronous vs round-pipelined Aurora dispatch on a host-device mesh.

    Runs in a SUBPROCESS with ``--xla_force_host_platform_device_count`` so
    the main bench process keeps one device (the other sections' timings
    must not change). Shapes follow the PR 4 kernel bench (32 experts,
    decode-heavy) at the 8-way EP sharding. On a host-platform CPU mesh the
    virtual devices share cores, so the overlap is NOT expected to win
    wall-clock here — the gate is output identity (tokens must not change
    when compute and communication interleave); the recorded throughputs
    feed the CI trend table.
    """
    script = _OVERLAP_WORKER.format(
        n_devices=n_devices, n_experts=n_experts, d_model=d_model,
        d_ff=d_ff, t_decode=t_decode, s_prefill=s_prefill, reps=reps)
    rec, err = _run_worker(script, _worker_env(n_devices), "overlap",
                           "OVERLAP_JSON ", timeout=1200, retries=1)
    if rec is None:
        return {"ok": False, "error": err}
    print(f"== overlap bench: {n_experts} experts EP-sharded over "
          f"{rec['n_devices']} host devices, {rec['rounds']} BvN rounds ==")
    print(f"{'dispatch':<10} {'decode tok/s':>13} {'prefill tok/s':>14}")
    for leg in ("sync", "pipelined"):
        print(f"{leg:<10} {rec[leg]['decode_tok_per_s']:>13.1f} "
              f"{rec[leg]['prefill_tok_per_s']:>14.1f}")
    print(f"pipelined/sync: decode {rec['decode_speedup']:.2f}x, prefill "
          f"{rec['prefill_speedup']:.2f}x (virtual devices share CPU cores "
          f"— identity is the gate); max |Δ| {rec['max_abs_diff']:.2e}")
    return rec


# ---------------------------------------------------------------------------
# Section 3: traffic drift + online re-planning
# ---------------------------------------------------------------------------

def bench_drift(arch="phi3.5-moe-42b-a6.6b", n_phase=12, batch_slots=2,
                prompt_len=8, max_new=6, rate=0.6, interval=6,
                cache_cap=32, halflife=16.0, seed=0):
    from repro.core import AuroraPlanner, homogeneous_cluster, synthetic_trace
    from repro.serving import (ColocatedContinuousEngine, OnlineReplanner,
                               Request, apply_pairing)

    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.models import Model

    # reduced() clamps to 4 experts, which leaves only 4! = 24 pairings — a
    # random historical pairing is too often near-optimal by luck. Widen to
    # 8 experts (still tiny weights) so placement quality actually varies.
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8))
    cfg_a = cfg_b = cfg
    model_a, model_b = Model(cfg_a), Model(cfg_b)
    params_a = model_a.init(jax.random.PRNGKey(0))
    params_b = model_b.init(jax.random.PRNGKey(1))
    n = cfg_a.moe.n_experts
    planner = AuroraPlanner(homogeneous_cluster(n))

    # Historical plan (what repro.launch.serve does today): pair from a
    # synthetic trace. Live traffic will look nothing like it — the drift.
    hist_a = synthetic_trace("hist-a", n_experts=n, n_layers=2, seed=seed)
    hist_b = synthetic_trace("hist-b", n_experts=n, n_layers=2, seed=seed + 1)
    plan0 = planner.plan_colocated(hist_a, hist_b)
    pair0 = list(plan0.pair)
    params_b = apply_pairing(params_b, pair0, cfg_b)

    # Prompts come from NARROW vocab bands (sharply skewed expert
    # popularity), and the band flips mid-stream — a strong popularity
    # drift, the regime MoETuner/Huang et al. show stales out placements.
    v = cfg_a.vocab
    bands = [(1, 1 + v // 16), (v // 2, v // 2 + v // 16)]

    def drifting_stream(rng, flip=False):
        reqs = []
        t = 0.0
        for i in range(2 * n_phase):
            t += float(rng.exponential(1.0 / rate))
            lo, hi = bands[(i >= n_phase) ^ flip]
            reqs.append(Request(
                prompt=list(rng.integers(lo, hi, prompt_len)),
                max_new_tokens=max_new, arrival=t))
        return reqs

    rng = np.random.default_rng(seed)
    reqs_a = drifting_stream(rng)
    reqs_b = drifting_stream(rng, flip=True)

    # Static leg: historical pairing, no re-planning.
    static = ColocatedContinuousEngine(model_a, model_b, params_a, params_b,
                                       batch_slots, cache_cap, pair=pair0)
    sa, sb = static.serve(_clone(reqs_a), _clone(reqs_b))

    # Adaptive leg: same stream, re-planning from live routing stats. The
    # replanner also scores the frozen historical pairing on every live
    # trace (baseline_pair) so the two trajectories are directly comparable.
    rp = OnlineReplanner(planner, interval=interval, threshold=0.02,
                         warmup=interval, baseline_pair=pair0)
    adap = ColocatedContinuousEngine(model_a, model_b, params_a, params_b,
                                     batch_slots, cache_cap, pair=pair0,
                                     replan=rp, monitor_halflife=halflife)
    aa, ab = adap.serve(_clone(reqs_a), _clone(reqs_b))

    assert [r.out_tokens for r in sa] == [r.out_tokens for r in aa], \
        "re-planning changed model A tokens (placement-only violated)"
    assert [r.out_tokens for r in sb] == [r.out_tokens for r in ab], \
        "re-planning changed model B tokens (placement-only violated)"

    # Trajectory score: at every checkpoint the engine's COMMITTED pairing
    # (events[i].stale_time) vs the frozen historical pairing
    # (events[i].baseline_time), both simulated on the live trace of that
    # moment. Identical streams → identical routing, so the adaptive run's
    # checkpoints speak for both legs.
    events = adap.replan_events
    applied = [e for e in events if e.applied]
    t_static = float(np.mean([e.baseline_time for e in events]))
    t_adapt = float(np.mean([e.stale_time for e in events]))

    print(f"== drift bench: {arch} x2 (reduced), {2 * n_phase} reqs/model, "
          f"narrow-band popularity flip, replan every {interval} steps ==")
    print(f"historical pairing     : {pair0}")
    print(f"final adaptive pairing : {adap.pair} "
          f"({len(applied)} re-plan(s) applied)")
    print(f"{'step':>6} {'historical':>11} {'committed':>10} "
          f"{'candidate':>10}   decision")
    for e in events:
        tag = "APPLIED" if e.applied else "kept"
        print(f"{e.step:>6} {e.baseline_time:>11.3f} {e.stale_time:>10.3f} "
              f"{e.candidate_time:>10.3f}   {tag}")
    gain = t_static / t_adapt if t_adapt > 0 else 1.0
    print(f"mean predicted inference time over the stream: "
          f"historical {t_static:.3f} vs adaptive {t_adapt:.3f} "
          f"({gain:.3f}x)")
    print("token streams identical across legs (placement-only invariant)")
    return {
        "arch": arch, "pair0": pair0, "pair_final": list(adap.pair),
        "replans_applied": len(applied),
        "events": [{"step": e.step, "historical": e.baseline_time,
                    "committed": e.stale_time,
                    "candidate": e.candidate_time, "applied": e.applied}
                   for e in events],
        "static_time": t_static, "adaptive_time": t_adapt,
        "improvement": gain,
        "ok": bool(len(applied) >= 1 and t_adapt <= t_static * (1 + 1e-9)),
    }


# ---------------------------------------------------------------------------
# Section 3b: Zipf-skewed traffic + online hot-expert replication
# ---------------------------------------------------------------------------

def bench_skew(arch="phi3.5-moe-42b-a6.6b", n_phase=10, batch_slots=2,
               prompt_len=8, max_new=6, rate=0.6, interval=5, cache_cap=32,
               halflife=8.0, zipf_a=1.3, tax_floor=0.85, seed=0, repeats=3):
    """Hot-expert replication on a Zipf-skewed drifting stream.

    Prompts draw token ids from a Zipf law over a narrow vocab band (a
    handful of head tokens dominate, so a handful of experts run hot), and
    the band FLIPS mid-stream — which experts are hot drifts. The adaptive
    leg closes the replication loop end-to-end: live routing counts →
    ``TrafficMonitor`` → ``OnlineReplanner.maybe_replicate`` (predictive:
    the fast EWMA pushed through the learned inter-layer affinities) →
    ``adopt_replication`` mid-stream. Gates: at least one replication
    applied; the committed placement simulates FASTER than serving
    unreplicated on the same live traces (the paper's Table-2 scorer);
    token streams byte-identical across legs (replication is
    placement-only); and the measured engine throughput pays no more than
    ``1 - tax_floor`` tax (widened expert leaves + monitor overhead on a
    CPU-reduced model — the simulator carries the win, the engine must not
    give it back)."""
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.core import (AuroraPlanner, homogeneous_cluster,
                            identity_replication)
    from repro.models import Model
    from repro.serving import (ContinuousEngine, EngineConfig,
                               OnlineReplanner, Request, TrafficMonitor)

    # Same widening as the drift section: at reduced()'s 4 experts a single
    # replica already rebalances everything — 8 experts give the greedy
    # planner an actual placement space.
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8))
    n = cfg.moe.n_experts
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    planner = AuroraPlanner(homogeneous_cluster(n))

    v = cfg.vocab
    band = max(v // 8, 4)
    lows = [1, v // 2]

    def zipf_stream(rng):
        reqs, t = [], 0.0
        for i in range(2 * n_phase):
            t += float(rng.exponential(1.0 / rate))
            lo = lows[i >= n_phase]                  # hot band flips here
            ranks = (rng.zipf(zipf_a, prompt_len) - 1) % band
            reqs.append(Request(prompt=[int(lo + r) for r in ranks],
                                max_new_tokens=max_new, arrival=t))
        return reqs

    stream = zipf_stream(np.random.default_rng(seed))

    mon = TrafficMonitor(n, model.n_moe_layers, halflife=halflife)
    rp = OnlineReplanner(planner, interval=interval, threshold=0.0,
                         warmup=interval, predictive=True,
                         baseline_replication=identity_replication(n))
    # Both legs run the kernelized hot path: the sort-based ragged dispatch's
    # compute follows ROUTED tokens, so widening the physical expert axis is
    # near-free — dense one-hot dispatch would pay proportional to n_phys
    # and the throughput gate would measure the dispatch style, not the
    # replication.
    engines = {
        "static": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len, kernels=True)),
        "replicated": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len, kernels=True),
            monitor=mon),
    }
    current = None

    def run(name, adapt=False):
        nonlocal current
        eng = engines[name]
        reqs = _clone(stream)
        for r in reqs:
            eng.submit(r)
        step = 0
        t0 = time.perf_counter()
        while eng.step():
            step += 1
            if adapt:
                plan = rp.maybe_replicate(step, mon, current)
                if plan is not None:
                    eng.adopt_replication(plan.replication)
                    current = plan.replication
        wall = time.perf_counter() - t0
        tokens = sum(len(r.out_tokens) for r in reqs)
        return tokens, wall, [r.out_tokens for r in reqs]

    # Adaptive phase (untimed): the replication loop runs live — counts →
    # monitor → predictive replanner → mid-stream adoption — and settles on
    # a placement. Every adoption of a NEW physical expert count re-jits the
    # engine steps; that compile cost amortizes to nothing in production
    # but would swamp a CPU-reduced timing, so the throughput legs below
    # serve with the placements PINNED (one more untimed pass after the
    # last adoption warms the final placement's compiles).
    run("static")
    run("replicated", adapt=True)
    run("replicated")
    # Interleaved paired repetitions on pinned placements, median ratio.
    runs = {name: [] for name in engines}
    outs = {}
    for _ in range(repeats):
        for name in engines:
            tokens, wall, toks = run(name)
            runs[name].append((tokens, wall))
            outs[name] = toks
    assert outs["static"] == outs["replicated"], \
        "replication changed emitted tokens (placement-only violated)"

    events = rp.events
    applied = [e for e in events if e.applied]
    t_ident = float(np.mean([e.baseline_time for e in events]))
    t_repl = float(np.mean([e.stale_time for e in events]))
    gain = t_ident / t_repl if t_repl > 0 else 1.0
    ratio = float(np.median(
        [(runs["replicated"][i][0] / runs["replicated"][i][1])
         / (runs["static"][i][0] / runs["static"][i][1])
         for i in range(repeats)]))

    results = {}
    for name, rs in runs.items():
        results[name] = _leg(rs[-1][0],
                             float(np.median([w for _, w in rs])))
        results[name]["tok_per_s"] = float(
            np.median([t / w for t, w in rs]))
    final = current
    print(f"== skew bench: {arch} (reduced, {n} experts), Zipf(a={zipf_a}) "
          f"prompts, hot band flips mid-stream, replicate every {interval} "
          f"steps ==")
    print(f"{'step':>6} {'unreplicated':>13} {'committed':>10} "
          f"{'candidate':>10}   decision")
    for e in events:
        tag = "APPLIED" if e.applied else "kept"
        print(f"{e.step:>6} {e.baseline_time:>13.3f} {e.stale_time:>10.3f} "
              f"{e.candidate_time:>10.3f}   {tag}")
    print(f"final replication      : "
          f"{None if final is None else [list(h) for h in final]} "
          f"({len(applied)} adoption(s))")
    print(f"{'engine':<12} {'tokens':>7} {'wall s':>8} {'tok/s':>9}")
    for name in ("static", "replicated"):
        r = results[name]
        print(f"{name:<12} {r['tokens']:>7} {r['wall_s']:>8.2f} "
              f"{r['tok_per_s']:>9.1f}")
    print(f"mean simulated inference time: unreplicated {t_ident:.3f} vs "
          f"replicated {t_repl:.3f} ({gain:.3f}x); measured throughput "
          f"ratio {ratio:.2f} (floor {tax_floor}); tokens identical")
    return {
        "arch": arch, "n_experts": n, "zipf_a": zipf_a,
        "static": results["static"], "replicated": results["replicated"],
        "throughput_ratio": ratio, "tax_floor": tax_floor,
        "replans_applied": len(applied),
        "final_replication": (None if final is None
                              else [list(h) for h in final]),
        "events": [{"step": e.step, "unreplicated": e.baseline_time,
                    "committed": e.stale_time,
                    "candidate": e.candidate_time, "applied": e.applied}
                   for e in events],
        "identity_time": t_ident, "replicated_time": t_repl,
        "improvement": gain,
        "ok": bool(len(applied) >= 1
                   and t_repl <= t_ident * (1 + 1e-9)
                   and ratio >= tax_floor),
    }


# ---------------------------------------------------------------------------
# Section 4: multi-tenant colocation (N > 2), aurora vs random grouping
# ---------------------------------------------------------------------------

def bench_multi(arch="phi3.5-moe-42b-a6.6b", tenant_counts=(2, 3, 4),
                n_experts=8, n_reqs=6, batch_slots=2, prompt_len=8,
                max_new=5, rate=0.6, cache_cap=32, rand_seeds=6, seed=0):
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.core import (AuroraPlanner, group_pairs, homogeneous_cluster,
                            random_grouping, synthetic_trace)
    from repro.models import Model
    from repro.serving import (EngineConfig, MultiTenantContinuousEngine,
                               Request, apply_pairing, poisson_requests)

    # Same widening as the drift section: reduced() clamps to 4 experts,
    # where the grouping space is too small for placement quality to vary.
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=n_experts))
    max_t = max(tenant_counts)
    models = [Model(cfg) for _ in range(max_t)]
    params = [m.init(jax.random.PRNGKey(t)) for t, m in enumerate(models)]
    planner = AuroraPlanner(homogeneous_cluster(n_experts))

    print(f"== multi-tenant bench: {arch} (reduced, {n_experts} experts), "
          f"N ∈ {list(tenant_counts)}, aurora vs random grouping ==")
    print(f"{'N':>2} {'aurora t':>9} {'random t':>9} {'gain':>6} "
          f"{'aurora util':>11} {'random util':>11} {'tok/s':>8}")
    per_n = {}
    rng = np.random.default_rng(seed)
    for nt in tenant_counts:
        # Tenants differ in popularity skew — the complementarity k-way
        # grouping exploits (one tenant's hot expert rides with others'
        # cold ones).
        traces = [synthetic_trace(f"tenant{t}", n_experts=n_experts,
                                  n_layers=2, skew=0.3 + 0.5 * t,
                                  seed=seed + 17 * t)
                  for t in range(nt)]
        plan = planner.plan_multi(traces)
        t_aurora = plan.predicted.inference_time
        u_aurora = plan.predicted.utilization
        rand = [planner.evaluate_multi(
                    traces, random_grouping(n_experts, nt, seed=s))
                for s in range(rand_seeds)]
        t_rand = float(np.mean([r.inference_time for r in rand]))
        u_rand = float(np.mean([r.utilization for r in rand]))

        # Engine leg: identical Poisson streams under identity placement and
        # under the aurora grouping (params permuted per tenant) — grouping
        # must be placement-only; throughput measured on the aurora run.
        streams = [poisson_requests(rng, n_reqs, rate, cfg.vocab, prompt_len,
                                    max_new_lo=2, max_new_hi=max_new)
                   for _ in range(nt)]
        ident = MultiTenantContinuousEngine(
            models[:nt], params[:nt], batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len))
        out_i = ident.serve([_clone(s) for s in streams])

        perms = group_pairs(list(plan.groups))
        grouped_params = [params[0]] + [
            apply_pairing(params[t], perms[t], cfg) for t in range(1, nt)]
        eng = MultiTenantContinuousEngine(
            models[:nt], grouped_params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len),
            groups=list(plan.groups))
        eng.serve([_clone(s) for s in streams])          # warm-up compile
        eng.decode_steps = 0
        final = [_clone(s) for s in streams]
        t0 = time.perf_counter()
        out_a = eng.serve(final)
        wall = time.perf_counter() - t0
        for t in range(nt):
            assert ([r.out_tokens for r in out_a[t]]
                    == [r.out_tokens for r in out_i[t]]), \
                f"grouping changed tenant {t} tokens (placement-only violated)"
        tokens = sum(len(r.out_tokens) for s in out_a for r in s)

        gain = t_rand / t_aurora if t_aurora > 0 else 1.0
        print(f"{nt:>2} {t_aurora:>9.3f} {t_rand:>9.3f} {gain:>5.2f}x "
              f"{u_aurora:>11.3f} {u_rand:>11.3f} {tokens / wall:>8.1f}")
        per_n[str(nt)] = {
            "aurora_time": t_aurora, "random_time": t_rand, "gain": gain,
            "aurora_util": u_aurora, "random_util": u_rand,
            "groups": [list(g) for g in plan.groups],
            "engine": {"tokens": tokens, "steps": eng.decode_steps,
                       "wall_s": wall, "tok_per_s": tokens / wall},
        }
    ok = all(v["aurora_time"] <= v["random_time"] * (1 + 1e-9)
             for v in per_n.values())
    print("aurora grouping no slower than random at every N; token streams "
          "identical across placements" if ok else
          "FAIL: random grouping beat aurora")
    return {"arch": arch, "n_experts": n_experts,
            "tenant_counts": list(tenant_counts), "tenants": per_n,
            "ok": bool(ok)}


# ---------------------------------------------------------------------------
# Section 5: four-scenario SLO sweep (exclusive/colocated x homo/hetero)
# ---------------------------------------------------------------------------

def bench_sweep(arch="phi3.5-moe-42b-a6.6b", n_phase=10, batch_slots=2,
                prompt_len=8, max_new=6, rate=0.6, interval=5, cache_cap=32,
                halflife=8.0, zipf_a=1.3, ttft_slo=8.0, tpot_slo=1.5,
                seed=0):
    """One Zipf-drifting Poisson stream through ALL FOUR cluster scenarios.

    The paper's core claim spans the exclusive/colocated x homo/hetero
    matrix; this section closes the bench side of it. The SAME primary
    stream (Zipf-banded prompts, hot band flips mid-stream) is served under
    each cell's engine + live re-planning action:

      exclusive+homogeneous    ``maybe_replicate`` (assignment is
                               irrelevant there — observation 1 — so hot
                               experts replicate instead)
      exclusive+heterogeneous  ``maybe_reassign`` (Thm 5.1 expert↔GPU
                               re-assignment on live traffic)
      colocated+homogeneous    ``maybe_replan`` (Thm 6.2 re-pairing)
      colocated+heterogeneous  hetero-aware ``maybe_regroup`` (grouping +
                               §7.2 group↔device re-matching, realized as
                               one placement-only reseat)

    Every engine runs deadline-aware admission: ``TenantSpec`` SLO targets
    (p95 TTFT / TPOT in engine-step units) stamp per-request deadlines and
    ``EdfAdmission`` schedules against them. Gates per scenario: >= 1 live
    adoption event, token streams byte-identical to a never-adopting static
    leg (placement-only invariant, asserted), and per-scenario p95
    TTFT/TPOT SLO attainment reported for the CI trend gate — measured on
    the deterministic step clock, so attainment only moves when the
    schedule itself changes.
    """
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.core import (AuroraPlanner, heterogeneous_cluster,
                            homogeneous_cluster)
    from repro.models import Model
    from repro.serving import (ColocatedContinuousEngine, ContinuousEngine,
                               EdfAdmission, EngineConfig,
                               MultiTenantContinuousEngine, OnlineReplanner,
                               Request, TenantSpec, TrafficMonitor)

    # Same widening as the drift/skew sections: reduced()'s 4 experts leave
    # placement spaces too small for any planner choice to matter; the
    # heterogeneous tier list also needs the device count divisible by 4.
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8))
    n = cfg.moe.n_experts
    model_a, model_b = Model(cfg), Model(cfg)
    params_a = model_a.init(jax.random.PRNGKey(seed))
    params_b = model_b.init(jax.random.PRNGKey(seed + 1))

    v = cfg.vocab
    band = max(v // 8, 4)
    lows = [1, v // 2]

    def zipf_stream(rng):
        reqs, t = [], 0.0
        for i in range(2 * n_phase):
            t += float(rng.exponential(1.0 / rate))
            lo = lows[i >= n_phase]                  # hot band flips here
            ranks = (rng.zipf(zipf_a, prompt_len) - 1) % band
            reqs.append(Request(prompt=[int(lo + r) for r in ranks],
                                max_new_tokens=max_new, arrival=t))
        return reqs

    primary = zipf_stream(np.random.default_rng(seed))
    secondary = zipf_stream(np.random.default_rng(seed + 1))

    spec_a = TenantSpec(name="primary", ttft_p95=ttft_slo,
                        tpot_p95=tpot_slo)
    spec_b = TenantSpec(name="secondary", ttft_p95=ttft_slo,
                        tpot_p95=tpot_slo)
    admission = EdfAdmission(chunk=prompt_len,
                             budget=prompt_len + batch_slots)

    def config(tenants, **kw):
        return EngineConfig(admission=admission, tenants=tenants, **kw)

    def slo_record(action, adoptions, ttfts, tpots, steps, wall, tokens):
        rec = _leg(tokens, wall, steps=steps, action=action,
                   adoptions=int(adoptions))
        rec["ttft_p95_steps"] = float(np.percentile(ttfts, 95))
        rec["tpot_p95_steps"] = float(np.percentile(tpots, 95))
        rec["ttft_attainment"] = float(
            np.mean([t <= ttft_slo for t in ttfts]))
        rec["tpot_attainment"] = float(
            np.mean([t <= tpot_slo for t in tpots]))
        return rec

    def outs(streams):
        return [[r.out_tokens for r in s] for s in streams]

    scenarios = {}

    # -- exclusive + homogeneous: online hot-expert replication ------------
    planner = AuroraPlanner(homogeneous_cluster(n))
    mon = TrafficMonitor(n, model_a.n_moe_layers, halflife=halflife)
    rp = OnlineReplanner(planner, interval=interval, threshold=0.0,
                         warmup=interval, predictive=True)
    # Kernelized hot path as in the skew section: the sort-based dispatch's
    # compute follows routed tokens, so widening the physical expert axis
    # on adoption is near-free.
    eng = ContinuousEngine(model_a, params_a, batch_slots, cache_cap,
                           config=config((spec_a,), kernels=True),
                           monitor=mon)
    current = [None]

    def adopt_replication(step):
        plan = rp.maybe_replicate(step, mon, current[0],
                                  total_multiple=None)
        if plan is not None:
            eng.adopt(plan)
            current[0] = plan.replication

    live = _clone(primary)
    t1, t2, steps, wall = _slo_serve(eng.step, [(eng, live)],
                                     on_step=adopt_replication)
    static = ContinuousEngine(model_a, params_a, batch_slots, cache_cap,
                              config=config((spec_a,), kernels=True))
    ref = _clone(primary)
    _slo_serve(static.step, [(static, ref)])
    assert outs([live]) == outs([ref]), \
        "replication adoption changed tokens (placement-only violated)"
    scenarios["exclusive+homogeneous"] = slo_record(
        "replicate", len([e for e in rp.events if e.applied]), t1, t2,
        steps, wall, sum(len(r.out_tokens) for r in live))

    # -- exclusive + heterogeneous: online expert<->GPU re-assignment ------
    planner = AuroraPlanner(heterogeneous_cluster(n))
    mon = TrafficMonitor(n, model_a.n_moe_layers, halflife=halflife)
    rp = OnlineReplanner(planner, interval=interval, threshold=0.0,
                         warmup=interval,
                         baseline_assignment=list(range(n)))
    eng = ContinuousEngine(model_a, params_a, batch_slots, cache_cap,
                           config=config((spec_a,)), monitor=mon)

    def adopt_assignment(step):
        plan = rp.maybe_reassign(step, mon, eng.assignment)
        if plan is not None:
            eng.adopt(plan)

    live = _clone(primary)
    t1, t2, steps, wall = _slo_serve(eng.step, [(eng, live)],
                                     on_step=adopt_assignment)
    static = ContinuousEngine(model_a, params_a, batch_slots, cache_cap,
                              config=config((spec_a,)))
    ref = _clone(primary)
    _slo_serve(static.step, [(static, ref)])
    assert outs([live]) == outs([ref]), \
        "re-assignment changed tokens (placement-only violated)"
    scenarios["exclusive+heterogeneous"] = slo_record(
        "reassign", len([e for e in rp.events if e.applied]), t1, t2,
        steps, wall, sum(len(r.out_tokens) for r in live))

    # -- colocated + homogeneous: online re-pairing ------------------------
    rp = OnlineReplanner(AuroraPlanner(homogeneous_cluster(n)),
                         interval=interval, threshold=0.0, warmup=interval)
    eng = ColocatedContinuousEngine(model_a, model_b, params_a, params_b,
                                    batch_slots, cache_cap,
                                    config=config((spec_a, spec_b)),
                                    replan=rp, monitor_halflife=halflife)
    live_a, live_b = _clone(primary), _clone(secondary)
    t1, t2, steps, wall = _slo_serve(
        eng.step, [(eng.pool_a, live_a), (eng.pool_b, live_b)])
    static = ColocatedContinuousEngine(model_a, model_b, params_a, params_b,
                                       batch_slots, cache_cap,
                                       config=config((spec_a, spec_b)))
    ref_a, ref_b = _clone(primary), _clone(secondary)
    _slo_serve(static.step,
               [(static.pool_a, ref_a), (static.pool_b, ref_b)])
    assert outs([live_a, live_b]) == outs([ref_a, ref_b]), \
        "re-pairing changed tokens (placement-only violated)"
    scenarios["colocated+homogeneous"] = slo_record(
        "replan", len([e for e in rp.events if e.applied]), t1, t2,
        steps, wall,
        sum(len(r.out_tokens) for r in live_a + live_b))

    # -- colocated + heterogeneous: hetero-aware re-grouping ---------------
    rp = OnlineReplanner(AuroraPlanner(heterogeneous_cluster(n)),
                         interval=interval, threshold=0.0, warmup=interval)
    eng = MultiTenantContinuousEngine([model_a, model_b],
                                      [params_a, params_b], batch_slots,
                                      cache_cap,
                                      config=config((spec_a, spec_b)),
                                      replan=rp, monitor_halflife=halflife)
    live_a, live_b = _clone(primary), _clone(secondary)
    t1, t2, steps, wall = _slo_serve(
        eng.step, [(eng.pools[0], live_a), (eng.pools[1], live_b)])
    static = MultiTenantContinuousEngine([model_a, model_b],
                                         [params_a, params_b], batch_slots,
                                         cache_cap,
                                         config=config((spec_a, spec_b)))
    ref_a, ref_b = _clone(primary), _clone(secondary)
    _slo_serve(static.step,
               [(static.pools[0], ref_a), (static.pools[1], ref_b)])
    assert outs([live_a, live_b]) == outs([ref_a, ref_b]), \
        "hetero re-grouping changed tokens (placement-only violated)"
    scenarios["colocated+heterogeneous"] = slo_record(
        "regroup", len([e for e in rp.events if e.applied]), t1, t2,
        steps, wall,
        sum(len(r.out_tokens) for r in live_a + live_b))

    print(f"== SLO sweep: {arch} (reduced, {n} experts), same Zipf-drifting "
          f"stream, EDF admission, targets ttft<={ttft_slo:g} "
          f"tpot<={tpot_slo:g} steps ==")
    print(f"{'scenario':<26} {'action':<10} {'adopt':>5} {'ttft p95':>9} "
          f"{'tpot p95':>9} {'ttft att':>9} {'tpot att':>9} {'tok/s':>8}")
    for name, r in scenarios.items():
        print(f"{name:<26} {r['action']:<10} {r['adoptions']:>5} "
              f"{r['ttft_p95_steps']:>9.1f} {r['tpot_p95_steps']:>9.2f} "
              f"{r['ttft_attainment']:>9.2f} {r['tpot_attainment']:>9.2f} "
              f"{r['tok_per_s']:>8.1f}")
    ok = all(r["adoptions"] >= 1 for r in scenarios.values())
    print("every scenario adopted >= 1 live plan; token streams identical "
          "across adoption legs" if ok else
          "FAIL: a scenario never adopted a live plan")
    return {"arch": arch, "n_experts": n, "ttft_slo": ttft_slo,
            "tpot_slo": tpot_slo, "scenarios": scenarios, "ok": bool(ok)}


# ---------------------------------------------------------------------------
# Section 6: chaos — fault injection, failover, and shed-mode admission
# ---------------------------------------------------------------------------

_CHAOS_WORKER = """
import dataclasses, json, time
import numpy as np
import jax
from repro.configs import get_config
from repro.core import AuroraPlanner, homogeneous_cluster, synthetic_trace
from repro.launch.mesh import make_ep_mesh
from repro.models import Model
from repro.serving import (ChaosHarness, DeviceLoss, DistributedEngine,
                           EngineConfig, ExpertCorruption, FaultInjector,
                           FaultPlan, HealthMonitor, Request)

n_dev = {n_devices}
cfg = get_config("{arch}").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts={n_experts}, capacity_factor=8.0))
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_ep_mesh(n_dev)
trace = synthetic_trace("live", n_experts={n_experts}, n_layers=cfg.n_layers,
                        seed=0)
planner = AuroraPlanner(homogeneous_cluster(n_dev))

def stream():
    rng = np.random.default_rng(0)
    return [Request(prompt=[int(x) for x in rng.integers(1, cfg.vocab, 6)],
                    max_new_tokens={max_new}, arrival=float(i))
            for i in range({n_requests})]

# Reference: the same stream with no faults.
ref_eng = DistributedEngine(model, params, 2, 32, mesh=mesh,
                            config=EngineConfig(prefill_len=8))
t0 = time.perf_counter()
ref = ref_eng.serve(stream())
ref_wall = time.perf_counter() - t0
out_ref = [r.out_tokens for r in ref]

# Chaos: a device dies mid-stream AND an expert's weights corrupt; the
# harness must detect both, roll back / repair the NaN step, re-queue the
# lost device's work, and adopt a survivor-only degraded plan.
plan = FaultPlan(faults=(ExpertCorruption(step={corrupt_step}, expert=1),
                         DeviceLoss(step={kill_step}, device=n_dev - 3)),
                 name="bench")
inj = FaultInjector(plan, n_devices=n_dev,
                    health=HealthMonitor(n_devices=n_dev,
                                         heartbeat_timeout=2))
eng = DistributedEngine(model, params, 2, 32, mesh=mesh,
                        config=EngineConfig(prefill_len=8,
                                            step_wrapper=inj.wrap))
h = ChaosHarness(eng, inj, planner=planner, trace=trace)
t0 = time.perf_counter()
live = h.serve(stream())
wall = time.perf_counter() - t0
out = [r.out_tokens for r in live]

kinds = sorted({{e.kind for e in h.health.events}})
actions = sorted({{r["action"] for r in h.recoveries}})
tokens = sum(len(t) for t in out)
rec = {{
    "n_devices": n_dev, "n_experts": {n_experts},
    "survivors": eng.n_ep,
    "detected": kinds, "recoveries": actions,
    "reference": {{"tokens": sum(len(t) for t in out_ref),
                  "wall_s": ref_wall,
                  "tok_per_s": sum(len(t) for t in out_ref) / ref_wall}},
    "faulted": {{"tokens": tokens, "wall_s": wall,
                "tok_per_s": tokens / wall}},
    "complete": all(len(r.out_tokens) == r.max_new_tokens for r in live),
    "identical": out == out_ref,
}}
rec["ok"] = bool(
    "device_loss" in kinds and "nan" in kinds
    and rec["survivors"] < n_dev
    and rec["complete"] and rec["identical"])
print("CHAOS_JSON " + json.dumps(rec))
"""


def _shed_serve(eng, reqs):
    """Step-clock driver that keeps shed requests out of the latency stats:
    ``submit`` returning a ``ShedEvent`` marks the request rejected (it
    never runs); TTFT is recorded per ADMITTED request in engine steps.
    Returns ``(ttfts, admitted, shed, steps, wall_s)``."""
    pend = sorted(reqs, key=lambda r: r.arrival)
    t, i, steps = 0.0, 0, 0
    first = {}
    admitted, shed = [], []
    t0 = time.perf_counter()
    while i < len(pend) or eng.queue or eng.num_active or eng.num_pending:
        while i < len(pend) and pend[i].arrival <= t:
            ev = eng.submit(pend[i])
            (shed if ev is not None else admitted).append(pend[i])
            i += 1
        busy = eng.step()
        steps += 1
        for r in admitted:
            if r.out_tokens and id(r) not in first:
                first[id(r)] = t
        if not busy and i < len(pend):
            t = max(t + 1.0, pend[i].arrival)
        else:
            t += 1.0
    wall = time.perf_counter() - t0
    ttfts = [first[id(r)] + 1.0 - r.arrival for r in admitted]
    return ttfts, admitted, shed, steps, wall


def bench_chaos(arch="phi3.5-moe-42b-a6.6b", n_devices=8, n_experts=8,
                n_requests=8, max_new=5, corrupt_step=2, kill_step=3,
                batch_slots=2, cache_cap=64, prompt_len=8, n_overload=12,
                deadline_steps=2.0, slack=3.0, seed=0):
    """Fault-tolerant serving: mid-stream failover and shed-mode admission.

    Two legs, two failure regimes:

    * **mesh** (subprocess, {n_devices}-way host-device EP mesh): one
      stream served twice — clean, and with a ``FaultPlan`` that corrupts
      an expert's weights at step ``corrupt_step`` and fail-stops a device
      at step ``kill_step``. The ``ChaosHarness`` must DETECT both (NaN
      guard + missing heartbeats), roll back and repair the corrupt step
      from a replica/pristine copy, re-queue the lost device's work, and
      adopt a survivor-only degraded plan (``plan_degraded`` →
      ``adopt_degraded`` mesh rebuild). Gates: both fault kinds detected,
      the engine finishes on fewer devices, every request completes, and
      the token streams are BYTE-IDENTICAL to the clean run — recovery is
      lossless.
    * **shed** (main process): an overload burst — ``n_overload``
      same-instant requests whose deadlines only ``deadline_steps`` steps
      out are provably unattainable for the queue's tail. Three runs: a
      no-overload reference (the SLO the admitted tail is held to), the
      burst under plain EDF (every request admitted, the tail blows the
      deadline), and the burst under ``EdfAdmission(shed=True)``. Gates:
      sheds happen, every shed carries a typed reason, every ADMITTED
      request still completes (shed never starves admitted work), and the
      shed leg's admitted p95 TTFT stays within ``slack`` x the
      no-overload reference on the deterministic step clock.
    """
    from repro.serving import (ContinuousEngine, EdfAdmission, EngineConfig,
                               Request)

    # -- mesh failover leg (subprocess: needs its own device mesh) ---------
    script = _CHAOS_WORKER.format(
        arch=arch, n_devices=n_devices, n_experts=n_experts,
        n_requests=n_requests, max_new=max_new, corrupt_step=corrupt_step,
        kill_step=kill_step)
    mesh_rec, err = _run_worker(script, _worker_env(n_devices), "chaos",
                                "CHAOS_JSON ", timeout=1200, retries=1)
    if mesh_rec is None:
        mesh_rec = {"ok": False, "error": err}
    else:
        print(f"== chaos mesh leg: {n_experts} experts EP-sharded over "
              f"{n_devices} host devices; corrupt expert @ step "
              f"{corrupt_step}, kill device @ step {kill_step} ==")
        print(f"detected {mesh_rec['detected']}, recoveries "
              f"{mesh_rec['recoveries']}, finished on "
              f"{mesh_rec['survivors']}/{n_devices} devices")
        print(f"{'leg':<10} {'tokens':>7} {'wall s':>8} {'tok/s':>9}")
        for leg in ("reference", "faulted"):
            r = mesh_rec[leg]
            print(f"{leg:<10} {r['tokens']:>7} {r['wall_s']:>8.2f} "
                  f"{r['tok_per_s']:>9.1f}")
        print("token streams byte-identical across clean/chaos runs"
              if mesh_rec["identical"] else
              "FAIL: recovery changed emitted tokens")

    # -- shed-mode admission leg (main process, step clock) ----------------
    cfg, model, params = _build(arch, seed=seed)
    rng = np.random.default_rng(seed)

    def burst(n, spacing):
        reqs = []
        for i in range(n):
            t = i * spacing
            reqs.append(Request(
                prompt=[int(x) for x in rng.integers(1, cfg.vocab,
                                                     prompt_len)],
                max_new_tokens=max_new, arrival=t,
                deadline=t + deadline_steps))
        return reqs

    def admission(shed):
        return EdfAdmission(chunk=prompt_len,
                            budget=prompt_len + batch_slots, shed=shed,
                            queue_cap=n_overload if shed else None)

    def run(reqs, shed):
        eng = ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(admission=admission(shed),
                                prefill_len=prompt_len))
        ttfts, admitted, sheds, steps, wall = _shed_serve(eng, reqs)
        tokens = sum(len(r.out_tokens) for r in admitted)
        rec = _leg(tokens, wall, steps=steps,
                   admitted=len(admitted), shed=len(sheds),
                   ttft_p95_steps=float(np.percentile(ttfts, 95)))
        return rec, admitted, eng.shed_events

    # No-overload reference: the same request shape, arrivals spread out so
    # the queue never backs up — its p95 TTFT is the SLO the shed leg's
    # admitted tail is held to.
    ref_rec, _, _ = run(burst(batch_slots * 2, spacing=4.0), shed=False)
    noshed_rec, _, _ = run(burst(n_overload, spacing=0.0), shed=False)
    shed_rec, shed_admitted, shed_events = run(burst(n_overload,
                                                     spacing=0.0),
                                               shed=True)
    reasons_typed = all(
        ev.reason.startswith(("deadline:", "queue_cap:"))
        for ev in shed_events)
    admitted_complete = all(len(r.out_tokens) == r.max_new_tokens
                            for r in shed_admitted)
    bound = ref_rec["ttft_p95_steps"] * slack
    shed = {
        "reference": ref_rec, "noshed": noshed_rec, "shed": shed_rec,
        "ttft_bound_steps": bound,
        "ok": bool(shed_rec["shed"] >= 1 and reasons_typed
                   and admitted_complete
                   and shed_rec["ttft_p95_steps"] <= bound),
    }
    print(f"== chaos shed leg: {n_overload}-request burst, deadlines "
          f"{deadline_steps:g} steps out, EDF budget "
          f"{prompt_len + batch_slots} ==")
    print(f"{'leg':<10} {'admit':>6} {'shed':>5} {'ttft p95':>9} "
          f"{'tok/s':>8}")
    for name, r in (("reference", ref_rec), ("noshed", noshed_rec),
                    ("shed", shed_rec)):
        print(f"{name:<10} {r['admitted']:>6} {r['shed']:>5} "
              f"{r['ttft_p95_steps']:>9.1f} {r['tok_per_s']:>8.1f}")
    for ev in shed_events[:3]:
        print(f"  shed[{ev.tenant}@{ev.arrival:g}]: {ev.reason}")
    print(f"admitted p95 TTFT {shed_rec['ttft_p95_steps']:.1f} steps vs "
          f"bound {bound:.1f} ({slack:g}x no-overload reference); "
          f"{shed_rec['shed']} shed, all admitted completed")

    return {"mesh": mesh_rec, "shed": shed,
            "ok": bool(mesh_rec.get("ok") and shed["ok"])}


# ---------------------------------------------------------------------------
# Section 7: telemetry — overhead, identity, and the step-timeline trace
# ---------------------------------------------------------------------------

_TRACE_WORKER = """
import dataclasses, json
import numpy as np
import jax
from repro.configs import get_config
from repro.core import synthetic_trace
from repro.launch.mesh import make_ep_mesh
from repro.models import Model
from repro.serving import (DistributedEngine, EngineConfig, FaultInjector,
                           FaultPlan, HealthMonitor, Request, Straggler,
                           Telemetry, rounds_from_trace)

n_dev = {n_devices}
cfg = get_config("{arch}").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts={n_experts}))
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_ep_mesh(n_dev)
rounds = rounds_from_trace(
    synthetic_trace("hist", n_experts={n_experts}, n_layers=2, seed=0),
    n_dev)
alt = rounds_from_trace(
    synthetic_trace("live", n_experts={n_experts}, n_layers=2, seed=1),
    n_dev)

def stream():
    rng = np.random.default_rng(0)
    return [Request(prompt=[int(x) for x in rng.integers(1, cfg.vocab, 6)],
                    max_new_tokens={max_new}, arrival=float(i))
            for i in range({n_requests})]

def drive(eng, reqs, pre=None, post=None):
    pend = sorted(reqs, key=lambda r: r.arrival)
    t, i, step = 0.0, 0, 0
    while i < len(pend) or eng.queue or eng.num_active or eng.num_pending:
        while i < len(pend) and pend[i].arrival <= t:
            eng.submit(pend[i])
            i += 1
        if pre is not None:
            pre(step)
        busy = eng.step()
        step += 1
        if post is not None:
            post(step)
        if not busy and i < len(pend):
            t = max(t + 1.0, pend[i].arrival)
        else:
            t += 1.0
    return [r.out_tokens for r in pend]

# Reference: same stream, no telemetry / injector / swap — the traced run
# below must emit byte-identical tokens (telemetry and rounds swaps are
# watch-only / placement-only).
ref_eng = DistributedEngine(model, params, 2, 32, mesh=mesh,
                            moe_impl="aurora", rounds=rounds, overlap=True,
                            config=EngineConfig(prefill_len=8))
out_ref = drive(ref_eng, stream())

tel = Telemetry()
health = HealthMonitor(n_devices=n_dev, straggler_ratio=2.0,
                       min_observations=2, halflife=4.0, telemetry=tel)
inj = FaultInjector(FaultPlan(faults=(Straggler(step={straggle_step},
                                                device=1, factor=16.0),),
                              name="trace"),
                    n_devices=n_dev, health=health)
eng = DistributedEngine(model, params, 2, 32, mesh=mesh,
                        moe_impl="aurora", rounds=rounds, overlap=True,
                        config=EngineConfig(prefill_len=8,
                                            step_wrapper=inj.wrap,
                                            telemetry=tel))
inj.attach(eng)
swapped = [False]

def post(step):
    health.check(step)                       # straggler -> fault event
    if step >= {swap_step} and not swapped[0]:
        swapped[0] = True
        eng.swap_rounds(alt)                 # -> adoption event

out = drive(eng, stream(), pre=lambda s: inj.tick(), post=post)

out_base = "{out_base}"
tel.write_jsonl(out_base + ".jsonl")
tel.write_chrome_trace(out_base + ".trace.json")

# Validate the exports by reading them BACK from disk: every line of the
# JSONL and the whole Chrome trace must round-trip json.loads.
recs = [json.loads(ln) for ln in open(out_base + ".jsonl")]
trace = json.load(open(out_base + ".trace.json"))
spans = [r for r in recs if r["type"] == "span"]
dispatch = [r for r in spans if r["name"] == "dispatch_round"]
evs = [r for r in recs if r["type"] == "event"]
faults = [e for e in evs if e["kind"] in ("fault", "fault_injected")]
adoptions = [e for e in evs if e["kind"] == "adoption"]
span_lo = min(s["ts"] for s in spans)
span_hi = max(s["ts"] + s["dur"] for s in spans)
interleaved = all(span_lo <= e["ts"] <= span_hi
                  for e in faults + adoptions)
ordered = all(recs[i]["ts"] <= recs[i + 1]["ts"]
              for i in range(len(recs) - 1))
rec = {{
    "n_devices": n_dev, "n_experts": {n_experts},
    "records": len(recs), "spans": len(spans),
    "dispatch_rounds": len(dispatch),
    "fault_events": len(faults), "adoptions": len(adoptions),
    "chrome_events": len(trace["traceEvents"]),
    "interleaved": interleaved, "ordered": ordered,
    "identical": out == out_ref,
    "files": [out_base + ".jsonl", out_base + ".trace.json"],
}}
rec["ok"] = bool(
    rec["dispatch_rounds"] >= 1 and rec["fault_events"] >= 1
    and rec["adoptions"] >= 1 and rec["interleaved"] and rec["ordered"]
    and rec["identical"] and rec["chrome_events"] >= rec["records"])
print("TRACE_JSON " + json.dumps(rec))
"""


def bench_trace(arch="qwen3-32b", mesh_arch="phi3.5-moe-42b-a6.6b",
                n_requests=12, batch_slots=4, prompt_len=8, max_new=16,
                rate=1.0, cache_cap=48, overhead_floor=0.98, seed=0,
                repeats=5, n_devices=8, n_experts=8, mesh_requests=6,
                mesh_max_new=4, straggle_step=2, swap_step=5,
                out_base="BENCH_trace_worker"):
    """Telemetry: zero overhead when off, token identity, and the timeline.

    Two legs:

    * **overhead** (main process): the SAME Poisson stream through three
      otherwise-identical engines — ``telemetry=None`` (the pre-telemetry
      code path, no wrapper composed), ``Telemetry(enabled=False)`` (the
      runtime off-switch), and an enabled hub. Gates: all three emit
      byte-identical tokens (telemetry only watches), the disabled leg's
      throughput stays within ``1 - overhead_floor`` of untraced (median
      of interleaved paired reps), and the enabled hub's
      ``serving_tokens_total`` counter agrees exactly with the tokens the
      stream actually emitted. The enabled leg's ratio is reported for
      the CI trend table (it pays for span records + ``block_until_ready``
      per step — honesty, not a regression).
    * **mesh** (subprocess, ``n_devices``-way host-device EP mesh): one
      stream through a round-pipelined ``--overlap``-style
      ``DistributedEngine`` with an enabled hub, a synthetic straggler
      (fault event via ``HealthMonitor``) and a mid-stream
      ``swap_rounds`` (adoption event). The worker writes the JSONL and
      Chrome-trace files and validates them FROM DISK: every record
      round-trips ``json.loads``, per-round ``dispatch_round`` spans are
      present, fault + adoption events interleave inside the span
      timeline in ``ts`` order, and tokens match a clean reference run.
    """
    from repro.serving import (ContinuousEngine, EngineConfig, Telemetry,
                               poisson_requests)

    # -- mesh timeline leg (subprocess: needs its own device mesh) ---------
    script = _TRACE_WORKER.format(
        arch=mesh_arch, n_devices=n_devices, n_experts=n_experts,
        n_requests=mesh_requests, max_new=mesh_max_new,
        straggle_step=straggle_step, swap_step=swap_step, out_base=out_base)
    mesh_rec, err = _run_worker(script, _worker_env(n_devices), "trace",
                                "TRACE_JSON ", timeout=1200, retries=1)
    if mesh_rec is None:
        mesh_rec = {"ok": False, "error": err}
    else:
        print(f"== trace mesh leg: {n_experts} experts EP-sharded over "
              f"{n_devices} host devices, overlap dispatch, straggler @ "
              f"step {straggle_step}, rounds swap @ step {swap_step} ==")
        print(f"{mesh_rec['records']} records ({mesh_rec['spans']} spans, "
              f"{mesh_rec['dispatch_rounds']} dispatch_round, "
              f"{mesh_rec['fault_events']} fault + "
              f"{mesh_rec['adoptions']} adoption events), "
              f"{mesh_rec['chrome_events']} Chrome trace events")
        print("events interleave in timeline order; tokens identical to "
              "the untraced reference" if mesh_rec["ok"] else
              "FAIL: trace timeline gates not met")

    # -- overhead + identity leg (main process) ----------------------------
    cfg, model, params = _build(arch)
    rng = np.random.default_rng(seed)
    stream = poisson_requests(rng, n_requests, rate, cfg.vocab, prompt_len,
                              max_new_lo=max_new // 2, max_new_hi=max_new)
    tel = Telemetry()
    engines = {
        "untraced": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len)),
        "disabled": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len,
                                telemetry=Telemetry(enabled=False))),
        "enabled": ContinuousEngine(
            model, params, batch_slots, cache_cap,
            config=EngineConfig(prefill_len=prompt_len, telemetry=tel)),
    }
    for eng in engines.values():
        eng.serve(_clone(stream))                   # warm-up compiles
    tok_counter = tel.metrics["serving_tokens_total"]
    counted0 = tok_counter.value(tenant="")
    runs = {name: [] for name in engines}
    outs = {}
    for _ in range(repeats):
        for name, eng in engines.items():           # interleaved pairs
            final = _clone(stream)
            t0 = time.perf_counter()
            eng.serve(final)
            wall = time.perf_counter() - t0
            runs[name].append((sum(len(r.out_tokens) for r in final), wall))
            outs[name] = [r.out_tokens for r in final]
    assert outs["untraced"] == outs["disabled"] == outs["enabled"], \
        "telemetry changed emitted tokens (watch-only violated)"

    tokens = runs["untraced"][-1][0]
    counted = tok_counter.value(tenant="") - counted0
    tokens_counted_ok = counted == tokens * repeats
    results = {}
    for name, reps in runs.items():
        results[name] = _leg(reps[-1][0],
                             float(np.median([w for _, w in reps])))
        results[name]["tok_per_s"] = float(
            np.median([t / w for t, w in reps]))
    ratios = {
        name: float(np.median(
            [(runs[name][i][0] / runs[name][i][1])
             / (runs["untraced"][i][0] / runs["untraced"][i][1])
             for i in range(repeats)]))
        for name in ("disabled", "enabled")}

    print(f"== trace overhead leg: {arch} (reduced), {n_requests} requests, "
          f"{batch_slots} slots, {repeats} interleaved reps ==")
    print(f"{'leg':<10} {'tokens':>7} {'wall s':>8} {'tok/s':>9} "
          f"{'vs untraced':>12}")
    for name in ("untraced", "disabled", "enabled"):
        r = results[name]
        ratio = ratios.get(name)
        print(f"{name:<10} {r['tokens']:>7} {r['wall_s']:>8.2f} "
              f"{r['tok_per_s']:>9.1f} "
              f"{'-' if ratio is None else format(ratio, '11.2f') + 'x':>12}")
    print(f"disabled hub costs {(1 - ratios['disabled']) * 100:+.1f}% "
          f"(floor {overhead_floor:g}); tokens identical across legs; "
          f"serving_tokens_total counted {counted:g} "
          f"(expected {tokens * repeats})")
    ok = bool(ratios["disabled"] >= overhead_floor and tokens_counted_ok
              and mesh_rec.get("ok"))
    return {
        "arch": arch, "n_requests": n_requests,
        "untraced": results["untraced"], "disabled": results["disabled"],
        "enabled": results["enabled"],
        "disabled_ratio": ratios["disabled"],
        "enabled_ratio": ratios["enabled"],
        "overhead_floor": overhead_floor,
        "tokens_counted_ok": bool(tokens_counted_ok),
        "spans_recorded": len(tel.spans),
        "events_published": sum(tel.bus.counts.values()),
        "mesh": mesh_rec, "ok": ok,
    }


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--moe-arch", default="phi3.5-moe-42b-a6.6b",
                    help="MoE arch for the drift section")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--rate", type=float, default=0.75)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunked", action="store_true",
                    help="run the chunked-prefill stall section only")
    ap.add_argument("--admission", action="store_true",
                    help="run the pooled-vs-serialized prefill admission "
                         "section (TTFT study)")
    ap.add_argument("--drift", action="store_true",
                    help="run the re-planning drift section (includes the "
                         "chunked stall comparison)")
    ap.add_argument("--skew", action="store_true",
                    help="run the Zipf-skew hot-expert replication section")
    ap.add_argument("--multi", action="store_true",
                    help="run the N-tenant colocation section")
    ap.add_argument("--kernels", action="store_true",
                    help="run the dense-vs-kernel dispatch section")
    ap.add_argument("--overlap", action="store_true",
                    help="run the sync-vs-pipelined distributed dispatch "
                         "section (subprocess with a host-device mesh)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the four-scenario SLO sweep (one stream "
                         "through exclusive/colocated x homo/hetero; not "
                         "part of --all — it has its own CI step)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-tolerance section: mid-stream "
                         "device kill + expert corruption with lossless "
                         "failover (subprocess mesh) and shed-mode EDF "
                         "under an overload burst; not part of --all — it "
                         "has its own CI step")
    ap.add_argument("--trace", action="store_true",
                    help="run the telemetry section: disabled-hub overhead "
                         "+ token identity in-process, and a subprocess "
                         "mesh leg that records and validates the JSONL / "
                         "Chrome-trace step timeline; not part of --all — "
                         "it has its own CI step")
    ap.add_argument("--all", action="store_true",
                    help="run every section (except --sweep and --chaos)")
    ap.add_argument("--small", action="store_true",
                    help="CI smoke sizes (fewer/shorter requests)")
    ap.add_argument("--json", default=None,
                    help="write section records to this JSON file")
    args = ap.parse_args()

    sections = {}
    run_classic = args.all or not (args.chunked or args.drift or args.multi
                                   or args.kernels or args.overlap
                                   or args.skew or args.admission
                                   or args.sweep or args.chaos
                                   or args.trace)
    run_chunked = args.all or args.chunked or args.drift
    run_admission = args.all or args.admission
    run_drift = args.all or args.drift
    run_skew = args.all or args.skew
    run_multi = args.all or args.multi
    run_kernels = args.all or args.kernels
    run_overlap = args.all or args.overlap

    # The chunked section runs FIRST: it judges step-latency tails, the
    # statistic most sensitive to heap/caches left by other sections.
    if run_chunked:
        # Even in --small the long prompt stays 8x the chunk AND the chunk
        # stays big enough to amortize per-step dispatch: on tiny CPU
        # configs the stall gap is the experiment, and an 8-token chunk's
        # fixed overhead would drown it in scheduler noise.
        # The 512-token prompt stays even in --small: on a quiet machine a
        # short prompt's one-shot prefill parallelizes into the same cost
        # band as a chunk step and the stall gap vanishes into noise — the
        # prompt must be structurally slow for the experiment to exist.
        kw = (dict(n_short=4, max_new=8, repeats=3) if args.small else {})
        sections["chunked"] = bench_chunked(arch=args.arch, seed=args.seed,
                                            **kw)
    if run_admission:
        # Runs right after chunked: it judges TTFT tails, the same
        # latency-sensitive statistic, before other sections litter the
        # heap. Smoke sizes trim the stream, never the pool width or the
        # chunks-per-prompt ratio — the queue of half-absorbed prefills IS
        # the experiment.
        kw = (dict(n_requests=8, max_new=6, repeats=2) if args.small else {})
        sections["admission"] = bench_admission(arch=args.arch,
                                                seed=args.seed, **kw)
    if run_classic:
        n = 8 if args.small else args.num_requests
        sections["continuous"] = bench(
            arch=args.arch, n_requests=n, batch_slots=args.batch,
            rate=args.rate, seed=args.seed)
    if run_kernels:
        # Decode throughput is a median of paired ratios (like the classic
        # section), so smoke sizes only trim the stream, not the expert
        # count — the widened expert dimension IS the experiment.
        kw = (dict(n_requests=6, max_new=16, repeats=3) if args.small else {})
        sections["kernels"] = bench_kernels(arch=args.moe_arch,
                                            seed=args.seed, **kw)
    if run_drift:
        kw = dict(n_phase=6, max_new=4) if args.small else {}
        sections["drift"] = bench_drift(arch=args.moe_arch, seed=args.seed,
                                        **kw)
    if run_skew:
        kw = (dict(n_phase=6, max_new=4, repeats=2) if args.small else {})
        sections["skew"] = bench_skew(arch=args.moe_arch, seed=args.seed,
                                      **kw)
    if run_multi:
        kw = (dict(n_reqs=4, max_new=4, rand_seeds=4) if args.small else {})
        sections["multi"] = bench_multi(arch=args.moe_arch, seed=args.seed,
                                        **kw)
    if run_overlap:
        # Subprocess with its own host-device mesh — isolated from this
        # process's single-device state, so --small only trims repetitions.
        kw = dict(reps=10) if args.small else {}
        sections["overlap"] = bench_overlap(**kw)
    if args.sweep:
        # Deliberately outside --all: four engines x two legs each is the
        # most expensive section, and its attainment metrics get their own
        # baseline-gated CI step.
        kw = (dict(n_phase=6, max_new=4) if args.small else {})
        sections["sweep"] = bench_sweep(arch=args.moe_arch, seed=args.seed,
                                        **kw)
    if args.chaos:
        # Deliberately outside --all (like --sweep): the mesh leg spawns an
        # 8-device subprocess and its recovery gates get their own CI step.
        kw = (dict(n_requests=6, max_new=4, n_overload=10)
              if args.small else {})
        sections["chaos"] = bench_chaos(arch=args.moe_arch, seed=args.seed,
                                        **kw)
    if args.trace:
        # Deliberately outside --all (like --sweep/--chaos): the mesh leg
        # spawns an 8-device subprocess and the overhead metric gets its
        # own baseline-gated CI step.
        kw = (dict(n_requests=8, max_new=10, repeats=3, mesh_requests=5)
              if args.small else {})
        sections["trace"] = bench_trace(arch=args.arch,
                                        mesh_arch=args.moe_arch,
                                        seed=args.seed, **kw)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(sections, f, indent=2)
        print(f"wrote {args.json}")

    failed = [k for k, v in sections.items() if not v["ok"]]
    if failed:
        print(f"FAIL: section(s) {failed} did not meet the win condition")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
