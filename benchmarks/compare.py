"""Bench trend gate: diff two serving-bench JSON records across CI runs.

  PYTHONPATH=src python -m benchmarks.compare BASELINE.json NEW.json \
      [--threshold 0.2] [--summary trend.md]

Reads two ``BENCH_serving.json`` files (``serving_bench.py --json`` output),
extracts a fixed set of named metrics, prints a trend table, and — for the
metrics marked *gated* (absolute throughputs, plus the sweep section's
step-clock SLO attainments) — exits non-zero when any one regressed by more
than ``--threshold`` (default 20%). Ratio metrics (speedups, stall cuts,
predicted-time gains) are reported but not gated: they compare two legs
measured in the same process and are already machine-normalized, while
run-to-run throughput is the trajectory the ROADMAP wants guarded.

A top-level section in the NEW record that this table does not know also
fails the gate — an unknown section is a set of silently-ungated metrics,
so adding a bench section must come with its METRICS entries (or an
explicit KNOWN_SECTIONS listing).

The markdown table is appended to ``--summary`` when given, else to
``$GITHUB_STEP_SUMMARY`` when set (the Actions job summary).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _get(record: dict, path: str):
    """Fetch a dotted path from nested dicts; None when any hop is missing."""
    cur = record
    for key in path.split("."):
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return cur


def _tok_per_s(section: str, engine_key: str):
    def extract(record: dict):
        tok = _get(record, f"{section}.{engine_key}.tokens")
        wall = _get(record, f"{section}.{engine_key}.wall_s")
        if tok is None or wall is None or wall <= 0:
            return None
        return tok / wall
    return extract


# (name, extractor, higher_is_better, gated). Gated metrics are absolute
# throughputs — the regression the CI gate exists to catch.
METRICS = [
    # Only the prefill leg is gated: it times a ~32x larger token window
    # than the 8-token decode dispatch, whose wall-clock on 8 virtual CPU
    # devices sharing 2 runner cores is jitter-dominated (the overlap
    # section's own hard gate is OUTPUT IDENTITY, enforced via its "ok").
    ("overlap pipelined prefill tok/s",
     lambda r: _get(r, "overlap.pipelined.prefill_tok_per_s"), True, True),
    ("overlap sync prefill tok/s",
     lambda r: _get(r, "overlap.sync.prefill_tok_per_s"), True, False),
    ("overlap pipelined decode tok/s",
     lambda r: _get(r, "overlap.pipelined.decode_tok_per_s"), True, False),
    ("overlap decode speedup",
     lambda r: _get(r, "overlap.decode_speedup"), True, False),
    ("overlap prefill speedup",
     lambda r: _get(r, "overlap.prefill_speedup"), True, False),
] + [
    ("continuous tok/s", _tok_per_s("continuous", "continuous"), True, True),
    ("static tok/s", _tok_per_s("continuous", "static"), True, False),
    ("continuous wall speedup",
     lambda r: _get(r, "continuous.wall_speedup"), True, False),
    ("continuous step efficiency",
     lambda r: _get(r, "continuous.step_efficiency"), True, False),
    ("chunked stall cut", lambda r: _get(r, "chunked.stall_cut"), True, False),
    ("admission pooled tok/s", _tok_per_s("admission", "pooled"), True, True),
    ("admission serial tok/s", _tok_per_s("admission", "serial"), True, False),
    # TTFT cut is a same-process paired ratio — reported, not gated, like
    # the other speedups.
    ("admission ttft p95 cut",
     lambda r: _get(r, "admission.ttft_p95_cut"), True, False),
    ("drift adaptive gain", lambda r: _get(r, "drift.improvement"),
     True, False),
    ("kernel-path tok/s", lambda r: _get(r, "kernels.kernel.tok_per_s"),
     True, True),
    ("dense-path tok/s", lambda r: _get(r, "kernels.dense.tok_per_s"),
     True, False),
    ("kernel decode speedup", lambda r: _get(r, "kernels.decode_speedup"),
     True, False),
    ("skew replicated tok/s",
     lambda r: _get(r, "skew.replicated.tok_per_s"), True, True),
    ("skew unreplicated tok/s",
     lambda r: _get(r, "skew.static.tok_per_s"), True, False),
    ("skew replication gain (simulated)",
     lambda r: _get(r, "skew.improvement"), True, False),
    ("skew throughput ratio",
     lambda r: _get(r, "skew.throughput_ratio"), True, False),
] + [
    (f"multi N={n} tok/s",
     lambda r, n=n: _get(r, f"multi.tenants.{n}.engine.tok_per_s"),
     True, True)
    for n in (2, 3, 4)
] + [
    (f"multi N={n} aurora-vs-random gain",
     lambda r, n=n: _get(r, f"multi.tenants.{n}.gain"), True, False)
    for n in (2, 3, 4)
] + [
    # Four-scenario SLO sweep: attainment is measured on the deterministic
    # step clock, so it only moves when the SCHEDULE changes — gate it.
    # The sweep's wall-clock throughput stays informational (eight engine
    # legs in one process are re-jit dominated on CI runners).
    metric
    for cell in ("exclusive+homogeneous", "exclusive+heterogeneous",
                 "colocated+homogeneous", "colocated+heterogeneous")
    for metric in [
        (f"sweep {cell} ttft attainment",
         lambda r, c=cell: _get(r, f"sweep.scenarios.{c}.ttft_attainment"),
         True, True),
        (f"sweep {cell} tpot attainment",
         lambda r, c=cell: _get(r, f"sweep.scenarios.{c}.tpot_attainment"),
         True, True),
        (f"sweep {cell} tok/s",
         lambda r, c=cell: _get(r, f"sweep.scenarios.{c}.tok_per_s"),
         True, False),
    ]
] + [
    # Chaos section: the hard gates (both faults detected, lossless
    # byte-identical recovery, typed shed reasons, admitted-TTFT bound) live
    # in the section's own "ok" — serving_bench exits non-zero when they
    # fail, before compare.py ever runs. Here the shed leg's ADMITTED
    # throughput is trend-gated (shedding must protect admitted work, so a
    # drop means recovery or admission got slower); the mesh legs are
    # informational (an 8-virtual-device subprocess on 2 runner cores is
    # jitter-dominated, and its identity gate is the "ok").
    ("chaos shed admitted tok/s", _tok_per_s("chaos", "shed.shed"),
     True, True),
    ("chaos mesh faulted tok/s",
     lambda r: _get(r, "chaos.mesh.faulted.tok_per_s"), True, False),
    ("chaos mesh clean tok/s",
     lambda r: _get(r, "chaos.mesh.reference.tok_per_s"), True, False),
    ("chaos shed admitted ttft p95 (steps)",
     lambda r: _get(r, "chaos.shed.shed.ttft_p95_steps"), False, False),
    ("chaos shed count",
     lambda r: _get(r, "chaos.shed.shed.shed"), True, False),
] + [
    # Trace section: the untraced leg's throughput is the pre-telemetry
    # baseline the PR must not move — gate it. The disabled-hub ratio is a
    # same-process paired ratio but it IS the section's headline claim
    # (disabled telemetry is free), so it is gated too; the enabled leg
    # pays for span records + block_until_ready by design and stays
    # informational. The mesh leg's structural gates (dispatch_round spans
    # present, events interleaved, token identity) live in the section's
    # own "ok".
    ("trace untraced tok/s",
     lambda r: _get(r, "trace.untraced.tok_per_s"), True, True),
    ("trace disabled/untraced ratio",
     lambda r: _get(r, "trace.disabled_ratio"), True, True),
    ("trace enabled tok/s",
     lambda r: _get(r, "trace.enabled.tok_per_s"), True, False),
    ("trace enabled/untraced ratio",
     lambda r: _get(r, "trace.enabled_ratio"), True, False),
    ("trace mesh dispatch_round spans",
     lambda r: _get(r, "trace.mesh.dispatch_rounds"), True, False),
]


# Sections the metric table knows how to read. Anything else appearing at
# the top level of a record FAILS the gate: a section this compare.py does
# not know is a section whose metrics are silently ungated, which is exactly
# the drift the gate exists to prevent — adding a bench section must come
# with its METRICS entries (or an explicit KNOWN_SECTIONS listing).
KNOWN_SECTIONS = {"admission", "chaos", "continuous", "chunked", "drift",
                  "kernels", "multi", "overlap", "skew", "sweep", "trace"}


def _section_rows(baseline: dict, new: dict):
    """Presence diff over top-level sections the metric table does NOT read.
    A section present in only the baseline is informational ("dropped" —
    the new run simply did not request it); a section the NEW run emits that
    this table cannot read is a hard failure row (its metrics would
    otherwise bypass the gate unreviewed). Known sections are covered
    metric-by-metric above, where one-sided values already render as
    "new"/"dropped"."""
    rows, unknown = [], []
    for key in sorted(set(baseline) | set(new)):
        if key in KNOWN_SECTIONS:
            continue
        if key not in new:
            rows.append((f"section '{key}'", None, None, None, "dropped"))
        else:
            rows.append((f"section '{key}'", None, None, None,
                         "UNRECOGNIZED"))
            unknown.append(key)
    return rows, unknown


def compare(baseline: dict, new: dict, threshold: float):
    """Returns (rows, regressions). rows: (name, old, new, delta, status)."""
    rows, regressions = [], []
    for name, extract, higher_better, gated in METRICS:
        old_v, new_v = extract(baseline), extract(new)
        if old_v is None and new_v is None:
            continue
        if old_v is None:
            rows.append((name, None, new_v, None, "new"))
            continue
        if new_v is None:
            rows.append((name, old_v, None, None, "dropped"))
            continue
        if old_v <= 0:
            # A non-positive baseline makes the relative delta meaningless
            # (sign flips); report the values without a trend verdict.
            rows.append((name, old_v, new_v, None, "n/a (baseline <= 0)"))
            continue
        delta = (new_v - old_v) / old_v
        change = delta if higher_better else -delta
        status = "ok"
        if gated and change < -threshold:
            status = "REGRESSED"
            regressions.append((name, old_v, new_v, delta))
        elif change < -threshold:
            status = "down (not gated)"
        rows.append((name, old_v, new_v, delta, status))
    section_rows, unknown = _section_rows(baseline, new)
    rows.extend(section_rows)
    for key in unknown:
        regressions.append((f"unrecognized section '{key}'",
                            None, None, None))
    return rows, regressions


def _fmt(v, width=10):
    return f"{'—':>{width}}" if v is None else f"{v:>{width}.3f}"


def render_text(rows) -> str:
    lines = [f"{'metric':<32} {'baseline':>10} {'current':>10} "
             f"{'Δ':>8}  status"]
    for name, old_v, new_v, delta, status in rows:
        d = "—" if delta is None else f"{delta:+.1%}"
        lines.append(f"{name:<32} {_fmt(old_v)} {_fmt(new_v)} {d:>8}  "
                     f"{status}")
    return "\n".join(lines)


def render_markdown(rows, threshold: float, regressions) -> str:
    lines = ["## Serving bench trend",
             "",
             f"Gate: >{threshold:.0%} regression on throughput metrics "
             "fails the job.",
             "",
             "| metric | baseline | current | Δ | status |",
             "|---|---:|---:|---:|---|"]
    for name, old_v, new_v, delta, status in rows:
        o = "—" if old_v is None else f"{old_v:.3f}"
        n = "—" if new_v is None else f"{new_v:.3f}"
        d = "—" if delta is None else f"{delta:+.1%}"
        badge = "❌" if status in ("REGRESSED", "UNRECOGNIZED") \
            else "✅" if status == "ok" else "ℹ️"
        lines.append(f"| {name} | {o} | {n} | {d} | {badge} {status} |")
    lines.append("")
    lines.append("**FAIL**: a gated check failed."
                 if regressions else "**PASS**: no gated regression.")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="previous run's BENCH_serving.json")
    ap.add_argument("new", help="this run's BENCH_serving.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative throughput drop that fails the gate "
                         "(default 0.2 = 20%%)")
    ap.add_argument("--summary", default=None,
                    help="append the markdown table to this file "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    rows, regressions = compare(baseline, new, args.threshold)
    print(render_text(rows))

    summary_path = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(render_markdown(rows, args.threshold, regressions))

    if regressions:
        print(f"\nFAIL: {len(regressions)} gated check(s) failed "
              f"(threshold {args.threshold:.0%}):")
        for name, old_v, new_v, delta in regressions:
            if delta is None:
                print(f"  {name}: add METRICS entries (or list it in "
                      "KNOWN_SECTIONS) before gating can pass")
            else:
                print(f"  {name}: {old_v:.3f} -> {new_v:.3f} ({delta:+.1%})")
        return 1
    print(f"\nPASS: no gated metric regressed past {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
