"""Benchmark harness (deliverable d): one entry per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all figures + kernels
  PYTHONPATH=src python -m benchmarks.run --only fig11a fig13

Each figure validates the paper's claim as a band; a failed band is a
non-zero exit. The roofline table is appended when dry-run records exist.
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> int:
    from benchmarks import figs, kernel_bench, roofline_table

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    figures = {
        "fig11a": figs.fig11a, "fig11b": figs.fig11b,
        "fig11c": figs.fig11c, "fig11d": figs.fig11d,
        "fig12": figs.fig12, "fig13": figs.fig13, "fig14": figs.fig14,
    }
    names = args.only or list(figures) + ["kernels", "roofline"]

    failures = []
    for name in names:
        t0 = time.time()
        if name == "kernels":
            print("== kernel microbench ==")
            for row in (kernel_bench.bench_moe_gmm()
                        + kernel_bench.bench_decode_attn()):
                print("  ", row)
                if row["max_abs_err"] > 1e-3:
                    failures.append(f"kernels: {row}")
            continue
        if name == "roofline":
            rows, md = roofline_table.table()
            print(f"== roofline baseline table ({len(rows)} rows) ==")
            print(md)
            continue
        rec = figures[name](seed=args.seed)
        ok = rec.get("band_ok", True)
        status = "OK" if ok else "BAND-FAIL"
        print(f"== {rec['figure']} [{status}] ({time.time()-t0:.1f}s) ==")
        print(json.dumps({k: v for k, v in rec.items() if k != "figure"},
                         indent=1))
        if not ok:
            failures.append(name)

    if failures:
        print(f"\nFAILED bands: {failures}")
        return 1
    print("\nall benchmark bands OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
