"""One benchmark per paper table/figure (§8), driven by synthetic
LIMoE-style traces (B/16 comm-heavy, B/32 compute-light; the Google
production traces are not redistributable — DESIGN.md §7).

Each function returns a record dict with the measured speedups and the
paper's claim band; ``run.py`` prints the table and validates the bands.
Bands are validated as *directional* claims (Aurora beats each baseline and
sits in a plausible range) — absolute ratios depend on the trace generator.
"""

from __future__ import annotations

import numpy as np

from repro.core import (AuroraPlanner, add_noise, aurora_pairing,
                        bruteforce_colocated, colocated_inference_time,
                        comm_time, exclusive_inference_time,
                        heterogeneous_cluster, homogeneous_cluster,
                        lina_inference_time, paper_eval_traces,
                        random_assignment, random_pairing, synthetic_trace)
from repro.core.assignment import aurora_assignment
from repro.core.simulator import mean_over_layers


def _speedup_band(name, speedups, lo, hi, claim):
    s = np.asarray(speedups, float)
    return {
        "figure": name,
        "speedups": [round(float(x), 3) for x in s],
        "min": round(float(s.min()), 3),
        "max": round(float(s.max()), 3),
        "paper_claim": claim,
        "band_ok": bool((s.min() >= lo) and (s.max() <= hi)),
        "band": (lo, hi),
    }


def fig11a(seed: int = 0) -> dict:
    """Scheduling policies, Exclusive+Homogeneous: Aurora vs SJF vs RCS.

    Paper: Aurora up to 1.38× faster than SJF; SJF ≈ RCS."""
    speed_sjf, speed_rcs = [], []
    for model_seed, trace in enumerate(paper_eval_traces(seed)):
        for layer in range(len(trace.layers)):
            d = trace.layer(layer)
            t_a = comm_time(d, "aurora")
            t_s = comm_time(d, "sjf")
            t_r = comm_time(d, "rcs", seed=seed)
            speed_sjf.append(t_s / t_a)
            speed_rcs.append(t_r / t_a)
    # Band note: the paper reports ≤1.38× on the (non-redistributable)
    # Google traces; our synthetic traces are skewier, so the fluid model
    # punishes SJF contention harder. Validated claim: Aurora is never
    # slower and the ordering Aurora ≤ SJF ≈ RCS holds.
    rec = _speedup_band("fig11a Aurora-vs-SJF (comm time)", speed_sjf,
                        1.0, 2.6, "up to 1.38x vs SJF (Google traces)")
    rec["vs_rcs"] = [round(float(x), 3) for x in speed_rcs]
    rec["band_ok"] = bool(rec["band_ok"]
                          and min(speed_sjf) >= 1.0 - 1e-9
                          and min(speed_rcs) >= 1.0 - 1e-9)
    return rec


def fig11b(seed: int = 0) -> dict:
    """GPU assignment, Exclusive+Heterogeneous: Aurora (Thm 5.1) vs RGA.

    Paper: 1.36–1.81× faster inference."""
    speeds = []
    for trace in paper_eval_traces(seed):
        n = trace.n
        cl = heterogeneous_cluster(n)
        for layer in range(len(trace.layers)):
            d = trace.layer(layer)
            e2d = aurora_assignment(d, cl)
            t_a = exclusive_inference_time(
                trace, layer, cl, e2d, policy="aurora").inference_time
            # RGA is a full-system baseline: random placement AND no
            # transmission-order optimization (RCS comm).
            t_r = np.mean([
                exclusive_inference_time(
                    trace, layer, cl, random_assignment(n, seed=s),
                    policy="rcs", seed=s).inference_time for s in range(5)])
            speeds.append(t_r / t_a)
    return _speedup_band("fig11b Aurora-vs-RGA (het inference)", speeds,
                         1.0, 2.5, "1.36-1.81x vs RGA")


def fig11c(seed: int = 0) -> dict:
    """Colocating+Homogeneous: Aurora cross-model colocation vs Lina
    (same-model packing) and REC. Paper: 1.25–2.38× vs Lina."""
    a, b = paper_eval_traces(seed)
    n = a.n
    cl = homogeneous_cluster(n)
    speeds_lina, speeds_rec = [], []
    for layer in range(len(a.layers)):
        pair = aurora_pairing(a.layer(layer), b.layer(layer))
        t_a = colocated_inference_time(a, b, layer, cl, pair).inference_time
        # Lina serves each model separately on n/2 devices; both models'
        # inference runs concurrently, so wall time is the max. Lina does
        # no transmission-order optimization → RCS comm.
        t_l = max(lina_inference_time(a, layer, cl,
                                      policy="rcs").inference_time,
                  lina_inference_time(b, layer, cl,
                                      policy="rcs").inference_time)
        t_r = np.mean([
            colocated_inference_time(
                a, b, layer, cl, random_pairing(n, seed=s),
                policy="rcs", seed=s).inference_time
            for s in range(5)])
        speeds_lina.append(t_l / t_a)
        speeds_rec.append(t_r / t_a)
    rec = _speedup_band("fig11c Aurora-vs-Lina (homog coloc)", speeds_lina,
                        1.0, 3.0, "1.25-2.38x vs Lina")
    rec["vs_rec"] = [round(float(x), 3) for x in speeds_rec]
    return rec


def fig11d(seed: int = 0) -> dict:
    """Colocating+Heterogeneous: Aurora (§7.2 decoupled matching) vs
    RGA+REC. Paper: 1.91–3.54× (vs Lina) / large gains vs random."""
    a, b = paper_eval_traces(seed)
    n = a.n
    cl = heterogeneous_cluster(n)
    planner = AuroraPlanner(cl)
    plan = planner.plan_colocated(a, b)
    speeds = []
    rng = np.random.default_rng(seed)
    for layer in range(len(a.layers)):
        t_a = colocated_inference_time(
            a, b, layer, cl, plan.pair, plan.expert_to_device).inference_time
        t_r = np.mean([
            colocated_inference_time(
                a, b, layer, cl, random_pairing(n, seed=s),
                np.asarray(rng.permutation(n)), policy="rcs",
                seed=s).inference_time
            for s in range(5)])
        speeds.append(t_r / t_a)
    return _speedup_band("fig11d Aurora-vs-RGA+REC (het coloc)", speeds,
                         1.0, 4.5, "1.91-3.54x")


def fig12(seed: int = 0) -> dict:
    """GPU utilization: Aurora colocation vs exclusive and vs Lina.

    Paper: 1.57–1.72× vs exclusive, 1.28–1.50× vs Lina."""
    a, b = paper_eval_traces(seed)
    n = a.n
    cl = homogeneous_cluster(n)
    nl = len(a.layers)
    pair = aurora_pairing(np.mean([a.layer(l) for l in range(nl)], 0),
                          np.mean([b.layer(l) for l in range(nl)], 0))
    util_coloc = mean_over_layers(
        lambda layer: colocated_inference_time(a, b, layer, cl, pair),
        nl).utilization
    util_excl = np.mean([
        mean_over_layers(
            lambda layer, t=t: exclusive_inference_time(t, layer, cl),
            nl).utilization
        for t in (a, b)])
    util_lina = np.mean([
        mean_over_layers(
            lambda layer, t=t: lina_inference_time(t, layer, cl,
                                                   policy="rcs"),
            nl).utilization
        for t in (a, b)])
    return {
        "figure": "fig12 GPU utilization (homog)",
        "aurora_coloc": round(float(util_coloc), 4),
        "exclusive": round(float(util_excl), 4),
        "lina": round(float(util_lina), 4),
        "vs_exclusive": round(float(util_coloc / util_excl), 3),
        "vs_lina": round(float(util_coloc / util_lina), 3),
        "paper_claim": "1.57-1.72x vs exclusive, 1.28-1.50x vs Lina",
        "band_ok": bool(util_coloc / util_excl >= 1.2
                        and util_coloc / util_lina >= 1.1),
        "band": ("vs_exclusive >= 1.2", "vs_lina >= 1.1"),
    }


def fig13(seed: int = 0, n: int = 6) -> dict:
    """Gap to brute-force optimum, Colocating+Heterogeneous.

    Paper: 1.07× on average (n=8; we use n=6 to keep brute force under a
    minute — 6!·assignment search via the decoupled matcher's own weights)."""
    gaps = []
    for s in range(3):
        a = synthetic_trace("a", n_experts=n, n_layers=1,
                            tokens_per_device=2048, skew=0.3,
                            ffn_per_token=0.002, ffn_fixed=3.0, seed=seed + s)
        b = synthetic_trace("b", n_experts=n, n_layers=1,
                            tokens_per_device=512, skew=0.25,
                            ffn_per_token=0.002, ffn_fixed=3.0,
                            seed=seed + 10 + s)
        from repro.core import PAPER_HET_TIERS
        cl = (heterogeneous_cluster(n) if n % 4 == 0 else
              heterogeneous_cluster(n, tiers=(PAPER_HET_TIERS[0],
                                              PAPER_HET_TIERS[2])))
        planner = AuroraPlanner(cl)
        plan = planner.plan_colocated(a, b)
        t_aurora = colocated_inference_time(
            a, b, 0, cl, plan.pair, plan.expert_to_device).inference_time
        t_opt, _, _ = bruteforce_colocated(a, b, 0, cl)
        gaps.append(t_aurora / t_opt)
    g = np.asarray(gaps)
    return {
        "figure": "fig13 gap to optimum (het coloc)",
        "gaps": [round(float(x), 4) for x in g],
        "mean_gap": round(float(g.mean()), 4),
        "paper_claim": "1.07x mean gap",
        "band_ok": bool(g.mean() <= 1.20 and (g >= 1.0 - 1e-9).all()),
        "band": (1.0, 1.20),
    }


def fig14(seed: int = 0) -> dict:
    """Robustness to imprecise traffic: plan on clean stats, serve noisy.

    Paper: ≤15.8% degradation at 75% noise."""
    a, b = paper_eval_traces(seed)
    n = a.n
    cl = heterogeneous_cluster(n)
    planner = AuroraPlanner(cl)
    plan = planner.plan_colocated(a, b)          # planned on clean stats
    base = np.mean([
        colocated_inference_time(a, b, l, cl, plan.pair,
                                 plan.expert_to_device).inference_time
        for l in range(len(a.layers))])
    rows = []
    for noise in (0.0, 0.25, 0.5, 0.75):
        an = add_noise(a, noise, seed=seed + 1)
        bn = add_noise(b, noise, seed=seed + 2)
        t = np.mean([
            colocated_inference_time(an, bn, l, cl, plan.pair,
                                     plan.expert_to_device).inference_time
            for l in range(len(a.layers))])
        rows.append({"noise": noise, "time": round(float(t), 3),
                     "degradation": round(float(t / base - 1.0), 4)})
    worst = max(r["degradation"] for r in rows)
    return {
        "figure": "fig14 noise robustness",
        "rows": rows,
        "worst_degradation": round(float(worst), 4),
        "paper_claim": "<=15.8% at 75% noise",
        "band_ok": bool(worst <= 0.30),
        "band": (0.0, 0.30),
    }
