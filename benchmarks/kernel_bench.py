"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled — interpret timing is NOT reported as perf).
What we measure here:
  1. correctness at benchmark shapes (allclose vs oracle), and
  2. the jnp reference path wall-time (the number the serving engine
     actually pays on CPU), plus the analytic VMEM working set of the
     chosen BlockSpecs — the quantity that matters on the TPU target.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn
from repro.kernels.moe_gmm import moe_gmm


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def bench_moe_gmm() -> list[dict]:
    rows = []
    for (e, c, d, f, bc, bf) in [(4, 256, 512, 1024, 128, 128),
                                 (8, 128, 1024, 2048, 128, 256)]:
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
        wg = jax.random.normal(ks[1], (e, d, f)) * d ** -0.5
        wu = jax.random.normal(ks[2], (e, d, f)) * d ** -0.5
        wd = jax.random.normal(ks[3], (e, f, d)) * f ** -0.5
        got = moe_gmm(x, wg, wu, wd, block_c=bc, block_f=bf, interpret=True)
        want = ref.moe_ffn_ref(x, wg, wu, wd, "swiglu")
        err = float(jnp.max(jnp.abs(got - want)))
        us = _time(jax.jit(lambda *a: ref.moe_ffn_ref(*a, "swiglu")),
                   x, wg, wu, wd)
        vmem = (bc * d + 2 * d * bf + bf * d) * 4 + bc * d * 4
        rows.append({"kernel": "moe_gmm", "shape": f"E{e} C{c} d{d} f{f}",
                     "blocks": f"bc{bc} bf{bf}",
                     "vmem_working_set_mib": round(vmem / 2**20, 2),
                     "max_abs_err": err, "ref_us_cpu": round(us, 1),
                     "flops": 6 * e * c * d * f})
    return rows


def bench_dispatch() -> list[dict]:
    """One-hot + cumsum dispatch vs sort-based ragged dispatch.

    The one-hot path materializes a (T·k, E) matrix and cumsums it over the
    token axis; the sort path is an argsort + searchsorted. Decode shapes
    (few tokens, many experts) are where the asymptotic gap lives.
    """
    from repro.models.moe import dispatch_indices, sort_dispatch

    rows = []
    for (t, k, e, cap) in [(4, 2, 64, 8),        # decode, production E
                           (8, 2, 32, 8),        # decode, mid E
                           (512, 2, 64, 16),     # prefill chunk
                           (4096, 8, 256, 256)]:  # deepseek-scale prefill
        rng = jax.random.PRNGKey(t * 1000 + e)
        idx = jax.random.randint(rng, (t, k), 0, e, jnp.int32)

        onehot = jax.jit(lambda i: dispatch_indices(i, e, cap))
        sort = jax.jit(lambda i: sort_dispatch(i, e, cap)[2:])
        s1, k1 = onehot(idx)
        s2, k2 = sort(idx)
        assert (s1 == s2).all() and (k1 == k2).all()
        us_onehot = _time(onehot, idx)
        us_sort = _time(sort, idx)
        rows.append({"kernel": "dispatch", "shape": f"T{t} k{k} E{e} C{cap}",
                     "onehot_us_cpu": round(us_onehot, 1),
                     "sort_us_cpu": round(us_sort, 1),
                     "sort_speedup": round(us_onehot / us_sort, 2)})
    return rows


def bench_decode_attn() -> list[dict]:
    rows = []
    for (b, h, hkv, s, d, bs) in [(4, 16, 4, 4096, 128, 512),
                                  (8, 8, 8, 8192, 64, 1024)]:
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
        valid = jnp.full((b,), s, jnp.int32)
        got = decode_attn(q, k, v, valid, block_s=bs, interpret=True)
        want = ref.decode_attn_ref(q, k, v, valid)
        err = float(jnp.max(jnp.abs(got - want)))
        us = _time(jax.jit(ref.decode_attn_ref), q, k, v, valid)
        vmem = (h * d + 2 * bs * hkv * d) * 4 + h * d * 4
        rows.append({"kernel": "decode_attn",
                     "shape": f"B{b} H{h}/{hkv} S{s} D{d}", "blocks": f"bs{bs}",
                     "vmem_working_set_mib": round(vmem / 2**20, 2),
                     "max_abs_err": err, "ref_us_cpu": round(us, 1),
                     "hbm_bytes": 2 * b * s * hkv * d * 4})
    return rows


def main() -> int:
    for row in bench_dispatch() + bench_moe_gmm() + bench_decode_attn():
        print(row)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
