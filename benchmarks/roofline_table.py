"""Collect dry-run JSONs into the §Roofline table (deliverable g).

Reads ``experiments/dryrun/*_pod16x16.json`` (the roofline table is
single-pod by spec) and emits one row per (arch × shape): the three terms,
the dominant bottleneck, MODEL_FLOPS ratio, and per-device memory.
"""

from __future__ import annotations

import glob
import json
import os


def load_records(out_dir: str = "experiments/dryrun",
                 mesh: str = "pod16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*_{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(out_dir: str = "experiments/dryrun") -> tuple[list[dict], str]:
    recs = load_records(out_dir)
    rows = []
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| useful FLOP ratio | mem/dev GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        row = {
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"].replace("_s", ""),
            "useful_flop_ratio": rf.get("useful_flop_ratio", 0.0),
            "mem_gib": r.get("memory", {}).get("per_device_total_gib"),
        }
        rows.append(row)
        lines.append(
            "| {arch} | {shape} | {compute_s:.2e} | {memory_s:.2e} "
            "| {collective_s:.2e} | {dominant} | {useful_flop_ratio:.3f} "
            "| {mem_gib} |".format(**row))
    return rows, "\n".join(lines)


def main() -> int:
    rows, md = table()
    print(md)
    print(f"\n{len(rows)} baseline rows")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
