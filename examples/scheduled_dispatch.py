"""Aurora-scheduled MoE dispatch on a multi-device mesh (Thm 4.2 runtime).

Runs the SAME expert-parallel MoE layer three ways on 8 CPU host devices:
  1. monolithic ``lax.all_to_all``          (production baseline),
  2. round-robin ppermute rounds           (traffic-blind, contention-free),
  3. Aurora BvN rounds from a planned schedule (traffic-aware ordering),
and verifies all three produce identical outputs — the schedule changes
WHEN bytes move, never WHAT arrives.

Must own the process (device count is locked at jax init):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/scheduled_dispatch.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
import numpy as np


def main():
    from repro.configs.base import MoEConfig
    from repro.core import aurora_schedule, synthetic_trace
    from repro.distributed import (aurora_rounds_from_schedule,
                                   round_robin_rounds)
    from repro.models.layers import ParallelContext
    from repro.models.moe import init_moe, moe_apply_ep

    n = 8
    mesh = jax.make_mesh((n,), ("model",))
    moe = MoEConfig(n_experts=n, top_k=2, d_ff=128, capacity_factor=4.0)
    params = init_moe(jax.random.PRNGKey(0), 64, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64))

    # Plan from historical routing statistics (paper §2.4).
    trace = synthetic_trace("hist", n_experts=n, n_layers=1, seed=42)
    sched = aurora_schedule(trace.layer(0))
    rounds = aurora_rounds_from_schedule(sched, n)
    print(f"planned schedule: {sched.n_slots} BvN slots, "
          f"b_max {sched.b_max:.1f} -> {len(rounds)} static ppermute rounds")

    def run(impl, aurora_rounds=None):
        pc = ParallelContext(mesh=mesh, data_axes=(), model_axis="model",
                             ep_axes=("model",), token_axes=("model",),
                             moe_impl=impl, aurora_rounds=aurora_rounds)
        with set_mesh(mesh):
            y, aux = moe_apply_ep(params, x, moe, "swiglu", pc)
        return np.asarray(y)

    y_base = run("ep")
    y_rr = run("aurora", round_robin_rounds(n))
    y_aurora = run("aurora", rounds)
    np.testing.assert_allclose(y_rr, y_base, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_aurora, y_base, rtol=1e-5, atol=1e-5)
    print("all three dispatch implementations agree "
          f"(max |Δ| = {np.abs(y_aurora - y_base).max():.2e})")
    print("on TPU the Aurora rounds avoid receiver contention for the "
          "planned traffic — see EXPERIMENTS.md §Perf")


if __name__ == "__main__":
    main()
