"""Aurora colocated serving (the paper's §6 in action).

Serves TWO reduced models on one host through a single interleaved XLA
program — model A (MoE, comm-heavy) and model B (dense, compute-heavy) —
after planning the expert colocation with AuroraPlanner on historical
routing statistics. Also prints what the plan predicts vs the baselines.

Usage: PYTHONPATH=src python examples/serve_colocated.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import (AuroraPlanner, homogeneous_cluster,
                        lina_inference_time, paper_eval_traces)
from repro.models import Model
from repro.serving import ColocatedEngine
from repro.serving.colocated import apply_pairing


def main():
    import jax

    # --- plan (host-side, from historical statistics) --------------------
    trace_a, trace_b = paper_eval_traces(seed=0)
    n = trace_a.n
    cluster = homogeneous_cluster(n)
    plan = AuroraPlanner(cluster).plan_colocated(trace_a, trace_b)
    t_aurora = plan.predicted.inference_time
    t_lina = max(
        np.mean([lina_inference_time(t, layer, cluster,
                                     policy="rcs").inference_time
                 for layer in range(len(t.layers))])
        for t in (trace_a, trace_b))
    print(f"planned pairing {plan.pair}")
    print(f"predicted inference: aurora {t_aurora:.1f} vs lina {t_lina:.1f} "
          f"({t_lina / t_aurora:.2f}x)")

    # --- serve (reduced models, CPU) --------------------------------------
    cfg_a = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg_b = get_config("phi4-mini-3.8b").reduced()
    model_a, model_b = Model(cfg_a), Model(cfg_b)
    params_a = model_a.init(jax.random.PRNGKey(0))
    params_b = model_b.init(jax.random.PRNGKey(1))
    # Apply the planner's pairing to model A's expert placement (reduced
    # config has 4 experts; re-plan at that size).
    from repro.core import synthetic_trace
    e = cfg_a.moe.n_experts
    pl = AuroraPlanner(homogeneous_cluster(e)).plan_colocated(
        synthetic_trace("a", n_experts=e, n_layers=2, seed=0),
        synthetic_trace("b", n_experts=e, n_layers=2, seed=1))
    params_a = apply_pairing(params_a, pl.pair, cfg_a)
    print(f"reduced-model pairing applied: {pl.pair}")

    eng = ColocatedEngine(model_a, model_b, params_a, params_b)
    rng = np.random.default_rng(0)
    prompts_a = rng.integers(1, cfg_a.vocab, (2, 8))
    prompts_b = rng.integers(1, cfg_b.vocab, (2, 8))
    out_a, out_b = eng.serve(prompts_a, prompts_b, max_new_tokens=8,
                             cache_cap=32)
    print("model A generated:", np.asarray(out_a).tolist())
    print("model B generated:", np.asarray(out_b).tolist())


if __name__ == "__main__":
    main()
