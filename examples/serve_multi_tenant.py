"""Multi-tenant Aurora colocation: N models interleaved on one device pool.

The paper colocates TWO models so one computes while the other communicates
(§6); nothing in the theory stops N-way interleaving. This example plans a
3-tenant expert grouping with ``AuroraPlanner.plan_multi`` (greedy repeated
bottleneck matching — §7.2's decoupling applied tenant-by-tenant), compares
its predicted inference time against random grouping, then serves three
reduced MoE models through one ``MultiTenantContinuousEngine`` — every
tenant's decode fused into a single XLA program, with the planner's grouping
physically realized by permuting each tenant's expert weights.

The pool membership is LIVE: after the first stream drains, a fourth tenant
joins mid-flight (``admit_tenant`` — its slot pool and colocation column are
created online) and is later evicted (``evict_tenant``), with the incumbent
tenants' serving state untouched throughout.

Usage: PYTHONPATH=src python examples/serve_multi_tenant.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import (AuroraPlanner, group_pairs, homogeneous_cluster,
                        random_grouping, synthetic_trace)
from repro.models import Model
from repro.serving import (MultiTenantContinuousEngine, Request,
                          apply_pairing)

N_TENANTS = 3


def main():
    import jax

    # --- plan (host-side, from historical statistics) ---------------------
    traces = [synthetic_trace(f"tenant{t}", n_experts=8, n_layers=2,
                              skew=0.3 + 0.5 * t, seed=17 * t)
              for t in range(N_TENANTS)]
    planner = AuroraPlanner(homogeneous_cluster(8))
    plan = planner.plan_multi(traces)
    t_rand = np.mean([planner.evaluate_multi(
        traces, random_grouping(8, N_TENANTS, seed=s)).inference_time
        for s in range(6)])
    print(f"scenario {plan.scenario}: groups (slot -> one expert per tenant)")
    for g, grp in enumerate(plan.groups):
        print(f"  slot {g}: {grp}")
    print(f"predicted inference: aurora {plan.predicted.inference_time:.2f} "
          f"vs random grouping {t_rand:.2f} "
          f"({t_rand / plan.predicted.inference_time:.2f}x)")

    # --- serve (reduced models, CPU) --------------------------------------
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    models = [Model(cfg) for _ in range(N_TENANTS)]
    params = [m.init(jax.random.PRNGKey(t)) for t, m in enumerate(models)]
    # Realize a grouping at the reduced expert count (4): re-plan small.
    small = [synthetic_trace(f"s{t}", n_experts=cfg.moe.n_experts,
                             n_layers=2, seed=t) for t in range(N_TENANTS)]
    sp = AuroraPlanner(homogeneous_cluster(cfg.moe.n_experts)).plan_multi(
        small)
    perms = group_pairs(list(sp.groups))
    params = [params[0]] + [apply_pairing(params[t], perms[t], cfg)
                            for t in range(1, N_TENANTS)]
    print(f"\nreduced-model grouping applied: {list(sp.groups)}")

    eng = MultiTenantContinuousEngine(models, params, batch_slots=2,
                                      cache_cap=32,
                                      groups=list(sp.groups))
    rng = np.random.default_rng(0)
    streams = [[Request(prompt=list(rng.integers(1, cfg.vocab, 8)),
                        max_new_tokens=6, arrival=float(i))
                for i in range(3)]
               for _ in range(N_TENANTS)]
    out = eng.serve(streams)
    for t, reqs in enumerate(out):
        print(f"tenant {t} generated: {[r.out_tokens for r in reqs]}")
    total = sum(len(r.out_tokens) for s in out for r in s)
    print(f"\n{total} tokens across {N_TENANTS} tenants in "
          f"{eng.decode_steps} fused decode steps "
          f"({total / eng.decode_steps:.2f} tok/step)")

    # --- live tenant churn ------------------------------------------------
    joiner = Model(cfg)
    t_new = eng.admit_tenant(joiner, joiner.init(jax.random.PRNGKey(99)))
    print(f"\ntenant {t_new} joined the live pool "
          f"(groups now {eng.n_tenants}-wide: {eng.groups})")
    late = [Request(prompt=list(rng.integers(1, cfg.vocab, 8)),
                    max_new_tokens=4, arrival=0.0) for _ in range(2)]
    eng.serve([[], [], [], late])
    print(f"joiner generated: {[r.out_tokens for r in late]}")
    eng.evict_tenant(t_new)
    print(f"tenant {t_new} evicted — back to {eng.n_tenants} tenants, "
          "incumbent pools untouched")


if __name__ == "__main__":
    main()
