"""Quickstart: plan an Aurora deployment, inspect it, and serve with it.

Runs on CPU in under a minute:
  1. Build routing statistics for two MoE models (the paper's §2.4 input).
  2. Plan all four scenarios with AuroraPlanner and print predicted
     inference times + the contention-free transmission schedule.
  3. Serve the reduced phi3.5-MoE with that schedule's ppermute rounds
     available to the runtime.

Usage: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (AuroraPlanner, heterogeneous_cluster,
                        homogeneous_cluster, paper_eval_traces)


def main():
    trace_a, trace_b = paper_eval_traces(seed=0)
    n = trace_a.n
    print(f"two models, {n} experts each, {len(trace_a.layers)} MoE layers")

    # --- scenario 1/2: exclusive deployments -----------------------------
    for cluster, name in ((homogeneous_cluster(n), "homogeneous"),
                          (heterogeneous_cluster(n), "heterogeneous")):
        plan = AuroraPlanner(cluster).plan_exclusive(trace_a)
        print(f"\n[exclusive + {name}] predicted inference time "
              f"{plan.predicted.inference_time:.2f} "
              f"(util {plan.predicted.utilization:.2%})")
        print(f"  expert→device map: {plan.expert_to_device.tolist()}")
        sched = plan.schedules[0]
        print(f"  layer-0 schedule: {sched.n_slots} permutation rounds, "
              f"total {sched.total_time:.2f} = b_max {sched.b_max:.2f}")

    # --- scenario 3/4: colocated deployments ------------------------------
    for cluster, name in ((homogeneous_cluster(n), "homogeneous"),
                          (heterogeneous_cluster(n), "heterogeneous")):
        plan = AuroraPlanner(cluster).plan_colocated(trace_a, trace_b)
        print(f"\n[colocating + {name}] predicted inference time "
              f"{plan.predicted.inference_time:.2f} "
              f"(util {plan.predicted.utilization:.2%})")
        print(f"  b-expert colocated with a-expert k: {plan.pair}")

    # --- the schedule as ppermute rounds (what the TPU runtime executes) --
    from repro.distributed import aurora_rounds_from_schedule
    plan = AuroraPlanner(homogeneous_cluster(n)).plan_exclusive(trace_a)
    rounds = aurora_rounds_from_schedule(plan.schedules[0], n)
    print(f"\nlayer-0 dispatch lowered to {len(rounds)} ppermute rounds; "
          f"first 3:")
    for r in rounds[:3]:
        print("  ", r)


if __name__ == "__main__":
    main()
