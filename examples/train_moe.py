"""End-to-end driver (deliverable b): train a ~100M-parameter MoE for a few
hundred steps on synthetic data and report the loss curve.

The model is a scaled phi3.5-MoE family member (8 experts, top-2) — the
same code path the production config lowers, including router aux loss and
capacity dispatch. Takes ~10–20 min on this CPU container with the default
200 steps; pass --steps 50 for a quick look.

Usage: PYTHONPATH=src python examples/train_moe.py [--steps N]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import Model
from repro.training import AdamWConfig, SyntheticLMData, train_loop


def make_100m_config():
    base = get_config("phi3.5-moe-42b-a6.6b")
    return dataclasses.replace(
        base,
        arch_id="phi-moe-100m",
        n_layers=4,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        vocab=8192,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=1024,
                      capacity_factor=1.25),
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = make_100m_config()
    model = Model(cfg)
    n_params = cfg.param_count()
    print(f"arch {cfg.arch_id}: {n_params/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active/token)")

    data = SyntheticLMData(cfg.vocab, seq_len=args.seq, batch=args.batch,
                           seed=0)
    state, hist = train_loop(
        model, data, steps=args.steps,
        opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10),
        log_every=max(args.steps // 20, 1))
    for h in hist:
        print(f"step {h['step']:4d}  ce {h['ce']:.4f}  aux {h['aux']:.4f}  "
              f"wall {h['wall']:.0f}s")
    first, last = hist[0]["ce"], hist[-1]["ce"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARNING: did not decrease'})")


if __name__ == "__main__":
    main()
