"""Continuous batching in action: watch the slot state machine.

Drives a ``ContinuousEngine`` step by step on a staggered request stream and
prints the per-step slot occupancy — requests flow through free slots as
they arrive and finish, instead of waiting for a whole batch to drain.

Usage: PYTHONPATH=src python examples/continuous_serving.py
"""

import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving import ContinuousEngine, EngineConfig, Request


def main():
    import jax

    cfg = get_config("qwen3-32b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousEngine(model, params, batch_slots=3, cache_cap=32,
                           config=EngineConfig(prefill_len=8))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab, 8)),
                    max_new_tokens=int(m), arrival=float(a))
            for a, m in [(0, 6), (0, 3), (1, 8), (2, 4), (5, 5), (6, 3)]]

    print(f"{len(reqs)} requests, {eng.batch_slots} slots "
          f"(arrival, max_new): "
          f"{[(r.arrival, r.max_new_tokens) for r in reqs]}\n")
    pending = sorted(reqs, key=lambda r: r.arrival)
    t, i = 0, 0
    while i < len(pending) or eng.queue or eng.num_active:
        while i < len(pending) and pending[i].arrival <= t:
            eng.submit(pending[i])
            i += 1
        busy = eng.step()
        occ = "".join("." if s is None else str(reqs.index(s))
                      for s in eng.slots)
        print(f"step {t:>2}  slots [{occ}]  queued {len(eng.queue)}"
              + ("" if busy else "  (idle)"))
        t += 1

    print()
    for k, r in enumerate(reqs):
        print(f"req {k} (t={r.arrival:.0f}): {r.out_tokens}")
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"\n{total} tokens in {eng.decode_steps} decode steps "
          f"({total / eng.decode_steps:.2f} tok/step); a static batch-3 "
          f"engine would have needed two full batches of max-length decodes.")


if __name__ == "__main__":
    main()
